"""Train a reduced llama3.2-1b on a 2x2 CPU mesh with checkpointing and an
injected node failure at step 12 — demonstrating the full distributed
runtime: sharded train step, atomic checkpoints, restart-on-failure with
exact data-pipeline resume.

    PYTHONPATH=src python examples/lm_train.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import tempfile                                       # noqa: E402

from repro.launch.train import train                  # noqa: E402


def main():
    with tempfile.TemporaryDirectory() as d:
        losses, final = train(
            "llama3.2-1b", reduced=True, steps=30, batch=8, seq=64,
            ckpt_dir=os.path.join(d, "ckpt"), ckpt_every=5,
            fail_at=[12],                   # inject a node failure
            data=2, model=2)                # 2x2 mesh on host devices
    print(f"\nfinal step {final}; loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    assert final == 30
    assert losses[-1] < losses[0] + 0.05      # random tokens: bound drift
    print("survived injected failure, resumed from checkpoint ✓")


if __name__ == "__main__":
    main()
