"""Quickstart: run a transposed convolution through the HUGE2 engine and
compare against the naive (DarkNet-style) zero-insertion engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import huge_conv_transpose2d, reference as ref

# DCGAN DC2: 8x8x512 -> 16x16x256, 5x5 kernel, stride 2
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (1, 8, 8, 512), jnp.float32)
k = jax.random.normal(key, (5, 5, 512, 256), jnp.float32)
strides, pad = (2, 2), ((2, 3), (2, 3))

huge = jax.jit(lambda x, k: huge_conv_transpose2d(x, k, strides, pad))
naive = jax.jit(lambda x, k: ref.naive_conv_transpose2d(
    x, k, strides=strides, padding=pad))
oracle = jax.jit(lambda x, k: ref.oracle_conv_transpose2d(
    x, k, strides=strides, padding=pad))

y_h, y_n, y_o = huge(x, k), naive(x, k), oracle(x, k)
np.testing.assert_allclose(np.asarray(y_h), np.asarray(y_o), rtol=2e-4,
                           atol=2e-4)
np.testing.assert_allclose(np.asarray(y_n), np.asarray(y_o), rtol=2e-4,
                           atol=2e-4)
print(f"output {y_h.shape} — HUGE2 == naive == XLA oracle  ✓")

for name, fn in (("naive(zero-insert+im2col)", naive), ("HUGE2", huge)):
    jax.block_until_ready(fn(x, k))
    t0 = time.perf_counter()
    for _ in range(10):
        jax.block_until_ready(fn(x, k))
    print(f"{name:28s} {(time.perf_counter() - t0) / 10 * 1e3:7.2f} ms/call")

# the same op through the Pallas TPU kernel (interpret mode on CPU)
y_p = huge_conv_transpose2d(x, k, strides, pad, "pallas")
np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_o), rtol=2e-4,
                           atol=2e-4)
print("Pallas kernel path (interpret=True) matches  ✓")
