"""Semantic segmentation on the engine: the paper's dilated-conv scenario
end-to-end.

Builds the DilatedNet-style SegNet (strided front-end + atrous context
module, ``models/segnet.py``), with every conv site planned once at load
and all weights held in the tap-major (R·S·C, N) superpack.  Runs one
jitted inference pass and one training step (the §3.2.3 custom VJPs on the
packed layout), printing plan-build cost and steady-state latency.

    PYTHONPATH=src python examples/segment.py [--steps N] [--full]

``--full`` uses the 64px/width-128 edge config; default is the tiny config
so the CI smoke step finishes in seconds.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import segnet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="64px width-128 config instead of the tiny one")
    args = ap.parse_args()
    cfg = segnet.SEGNET if args.full else segnet.SEGNET_TINY

    key = jax.random.PRNGKey(0)
    t0 = time.perf_counter()
    params, _ = segnet.segnet_init(key, cfg)
    plans = segnet.segnet_plans(cfg)
    load_ms = (time.perf_counter() - t0) * 1e3
    n_sites = len(plans)
    plan_ms = sum(p.build_ms for p in plans)
    print(f"[load] {cfg.name}: {n_sites} planned conv sites "
          f"({sum(1 for p in plans if p.spec.kind == 'dilated')} dilated), "
          f"plan build {plan_ms:.1f} ms, init total {load_ms:.1f} ms")
    print(f"[load] paths: {[p.path for p in plans]}")

    kx, kl = jax.random.split(key)
    x = jax.random.normal(kx, (2, cfg.in_hw, cfg.in_hw, cfg.in_c),
                          jnp.float32)
    labels = jax.random.randint(kl, (2, cfg.out_hw, cfg.out_hw), 0,
                                cfg.num_classes)

    fwd = jax.jit(lambda p, x: segnet.segnet_apply(p, x, cfg))
    logits = jax.block_until_ready(fwd(params, x))     # compile
    assert logits.shape == (2, cfg.out_hw, cfg.out_hw, cfg.num_classes)
    assert np.isfinite(np.asarray(logits)).all()
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(fwd(params, x))
    print(f"[infer] logits {tuple(logits.shape)} "
          f"(upsampled {tuple(segnet.upsample_logits(logits).shape)}), "
          f"{(time.perf_counter() - t0) / 5 * 1e3:.2f} ms/batch steady-state")

    step = jax.jit(jax.value_and_grad(
        lambda p: segnet.segnet_loss(p, x, labels, cfg)))
    loss0 = None
    for i in range(args.steps):
        loss, grads = step(params)
        params = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
        loss0 = loss0 if loss0 is not None else float(loss)
        print(f"[train] step {i}: loss {float(loss):.4f}")
    final = float(step(params)[0])
    assert np.isfinite(final)
    if args.steps >= 1:
        assert final < loss0, (final, loss0)
        print(f"[train] loss {loss0:.4f} -> {final:.4f} "
              f"(custom VJPs on the superpack)")


if __name__ == "__main__":
    main()
