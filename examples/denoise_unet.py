"""Latent-diffusion U-Net on the engine: every conv kind in one model.

Builds the diffusion U-Net (``models/unet.py``) — strided downsamples,
dilated bottleneck, transposed upsamples, skip-concat fuse convs — with
every site planned once at load and all weights in tap-major superpacks.
The k=4/s=2 upsample sites plan the **sub-pixel route**
(``Route.path='pixel_shuffle'``): the transposed conv is rewritten at plan
time into one dense ``dot_general`` plus a depth-to-space reshape.

Runs one denoising-score-matching training step (loss + grads through the
packed VJPs, including the skip-concat cotangent split) and an Euler
denoising loop, printing per-step latency.

    PYTHONPATH=src python examples/denoise_unet.py [--steps N] [--full]

``--full`` uses the 32px edge config; default is the tiny config so the
CI smoke step finishes in seconds.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.models import unet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=8,
                    help="Euler denoising steps (CI smoke uses 2)")
    ap.add_argument("--full", action="store_true",
                    help="32px base-32 config instead of the tiny one")
    args = ap.parse_args()
    cfg = unet.UNET if args.full else unet.UNET_TINY

    t0 = time.perf_counter()
    params, _ = unet.unet_init(jax.random.PRNGKey(0), cfg)
    t_build = time.perf_counter() - t0

    # one model, every route kind: the plan inspection the paper's
    # "untangled" claim rests on — no site falls back to lax conv
    routes = unet.unet_route_summary(cfg)
    kinds = {k for k, _ in routes.values()}
    paths = {p for _, p in routes.values()}
    assert kinds == {"conv", "dilated", "transposed"}, kinds
    assert "pixel_shuffle" in paths, paths
    for site, (kind, path) in routes.items():
        print(f"  {site:6s} {kind:10s} -> {path}")
    ps = [s for s, (_, p) in routes.items() if p == "pixel_shuffle"]
    print(f"{len(routes)} sites planned in {t_build:.2f}s; "
          f"sub-pixel route at {', '.join(ps)}")

    # one DSM training step through the packed VJPs
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (2, cfg.image_hw, cfg.image_hw, cfg.in_c),
                          jnp.float32)
    loss, grads = jax.value_and_grad(unet.unet_loss)(params, x, key, cfg)
    n_zero = sum(int(not jnp.any(g)) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(loss) and n_zero == 0, (loss, n_zero)
    print(f"DSM loss {float(loss):.4f}; all "
          f"{len(jax.tree.leaves(grads))} grad leaves nonzero ✓")

    # Euler denoising loop: args.steps sequential U-Net calls
    loop = jax.jit(lambda xt: unet.denoise_loop(params, xt, cfg, args.steps))
    xt = jax.random.normal(jax.random.PRNGKey(2), x.shape, jnp.float32)
    out = jax.block_until_ready(loop(xt))
    t0 = time.perf_counter()
    out = jax.block_until_ready(loop(xt))
    dt = time.perf_counter() - t0
    assert out.shape == x.shape and bool(jnp.all(jnp.isfinite(out)))
    print(f"denoised {out.shape} in {args.steps} steps "
          f"({dt / args.steps * 1e3:.1f} ms/step steady-state) ✓")


if __name__ == "__main__":
    main()
