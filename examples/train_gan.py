"""Train a (reduced) DCGAN for a few hundred steps through the HUGE2 engine
— every forward *and backward* convolution runs the paper's decomposition /
untangling formulation (custom VJPs, §3.2.3).

    PYTHONPATH=src python examples/train_gan.py [--steps 200]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import gan
from repro.models.gan import DeconvLayer
from repro.train.data import GANPipeline

# a reduced DCGAN (same family, CIFAR-scale 32x32 output) that trains in
# minutes on one CPU core
SMALL_LAYERS = (
    DeconvLayer(4, 128, 64, 5, 2),
    DeconvLayer(8, 64, 32, 5, 2),
    DeconvLayer(16, 32, 3, 5, 2),
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=2e-4)
    args = ap.parse_args()

    cfg = gan.GANConfig("dcgan-small", SMALL_LAYERS)
    key = jax.random.PRNGKey(0)
    kg, kd = jax.random.split(key)
    # load-time planning: generator weights are packed into the plans'
    # GEMM-ready layout; fwd AND bwd run on packed buffers from here on.
    g_plans = gan.generator_plans(cfg)
    d_plans = gan.discriminator_plans(cfg)
    gp, _ = gan.generator_init(kg, cfg)
    dp, _ = gan.discriminator_init(kd, cfg)
    print(f"planned {len(g_plans)} deconv + {len(d_plans)} conv sites "
          f"at model load "
          f"({sum(p.build_ms for p in g_plans + d_plans):.2f} ms plan build)")
    pipe = GANPipeline(cfg, args.batch, image_hw=32)

    @jax.jit
    def step(gp, dp, z, real):
        def d_loss_fn(dp):
            return gan.gan_losses(gp, dp, z, real, cfg)[1]

        def g_loss_fn(gp):
            return gan.gan_losses(gp, dp, z, real, cfg)[0]

        d_loss, d_grad = jax.value_and_grad(d_loss_fn)(dp)
        g_loss, g_grad = jax.value_and_grad(g_loss_fn)(gp)
        dp2 = jax.tree.map(lambda p, g: p - args.lr * g, dp, d_grad)
        gp2 = jax.tree.map(lambda p, g: p - args.lr * g, gp, g_grad)
        return gp2, dp2, g_loss, d_loss

    t0 = time.time()
    g_hist, d_hist = [], []
    for s in range(args.steps):
        b = pipe.batch_at(s)
        gp, dp, gl, dl = step(gp, dp, jnp.asarray(b["z"]),
                              jnp.asarray(b["real"]))
        g_hist.append(float(gl))
        d_hist.append(float(dl))
        if s % 25 == 0:
            print(f"step {s:4d}  g_loss {gl:.4f}  d_loss {dl:.4f}")
    dt = time.time() - t0
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({dt / args.steps * 1e3:.0f} ms/step)")
    print(f"d_loss {d_hist[0]:.4f} -> {d_hist[-1]:.4f} "
          f"(discriminator learning: {'yes' if d_hist[-1] < d_hist[0] else 'check'})")
    img = gan.generator_apply(gp, jnp.asarray(pipe.batch_at(0)["z"]), cfg)
    assert np.isfinite(np.asarray(img)).all()
    print(f"sample generation OK: {img.shape}")


if __name__ == "__main__":
    main()
