"""Continuous-batching LM serving: requests of different prompt lengths
join and leave the slot pool mid-flight (vLLM-style scheduler).

    PYTHONPATH=src python examples/serve_lm_continuous.py
"""
import numpy as np

import jax

from repro.configs import registry
from repro.models import transformer as tfm
from repro.serving.batcher import ContinuousBatcher, Request


def main():
    cfg = registry.get_reduced("llama3.2-1b")
    params, _ = tfm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    cb = ContinuousBatcher(cfg, params, slots=3, max_len=32)
    n_req = 9
    for i in range(n_req):
        plen = int(rng.integers(2, 8))
        cb.submit(Request(rid=i,
                          prompt=rng.integers(0, cfg.vocab_size,
                                              plen).astype(np.int32),
                          max_new=6))
    steps = cb.run()
    st = cb.stats()
    naive = sum(len(r.prompt) + 6 - 1 for r in cb.done)
    print(f"served {st['completed']} requests in {steps} scheduler steps "
          f"(sequential would take {naive})")
    print(f"latency p50 {st['p50_ms']:.0f} ms  p95 {st['p95_ms']:.0f} ms  "
          f"p99 {st['p99_ms']:.0f} ms, p50 TTFT "
          f"{st['p50_ttft_s'] * 1e3:.0f} ms")
    assert st["completed"] == n_req and steps < naive
    print("continuous batching beats sequential scheduling ✓")


if __name__ == "__main__":
    main()
