"""End-to-end serving driver (the paper's kind: an *inference engine*):
serve the DCGAN generator through the dynamic image batcher.

Latent requests arrive on an open loop (``--rate`` req/s; 0 = one burst)
and the ``DynamicImageBatcher`` coalesces them into the plan batch buckets
(1/4/16/64 — the sizes every ``ConvPlan`` routed at build time), padding
the tail and launching one jitted generator call per bucket.  Model load
builds every conv plan and packs the weights ONCE; the server then only
ever executes plan-time routes.

With ``--autotune cache|measure`` the plans use measured routes from the
per-host route cache (``--route-cache PATH``, default
``$HUGE2_ROUTE_CACHE`` or ``~/.cache/huge2/route_cache.json``); the same
cache persists the batcher's measured bucket costs, so a restarted server
skips both the route microbenchmarks and the bucket cost measurements.

    PYTHONPATH=src python examples/serve_dcgan.py [--requests 64]
        [--rate 0] [--max-wait-ms 2] [--backend xla] [--small]
        [--autotune off|cache|measure] [--route-cache PATH]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import autotune as at
from repro.models import gan
from repro.serving.image_batcher import DynamicImageBatcher
from repro.serving.metrics import format_stats

SMALL_LAYERS = (
    gan.DeconvLayer(4, 128, 64, 5, 2),
    gan.DeconvLayer(8, 64, 32, 5, 2),
    gan.DeconvLayer(16, 32, 3, 5, 2),
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="arrival rate in req/s (0 = submit all at once)")
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--backend", choices=("xla", "pallas"), default="xla")
    ap.add_argument("--small", action="store_true",
                    help="reduced 32px generator (CI smoke)")
    ap.add_argument("--autotune", choices=("off", "cache", "measure"),
                    default="off",
                    help="measured routes: 'cache' = use cached winners only,"
                         " 'measure' = microbenchmark on cache miss")
    ap.add_argument("--route-cache", default=None,
                    help="route/bucket-cost cache path (default "
                         "$HUGE2_ROUTE_CACHE or ~/.cache/huge2)")
    args = ap.parse_args()

    policy = None
    cache = None
    if args.autotune != "off":
        policy = at.AutotunePolicy(mode=args.autotune,
                                   cache_path=args.route_cache)
        cache = at.open_cache(args.route_cache)
    layers = SMALL_LAYERS if args.small else gan.DCGAN_LAYERS
    cfg = gan.GANConfig("dcgan", layers, backend=args.backend,
                        autotune=policy)
    key = jax.random.PRNGKey(0)
    # model load: build every conv plan + pack weights ONCE, serve forever
    t_load = time.perf_counter()
    plans = gan.generator_plans(cfg)
    params, _ = gan.generator_init(key, cfg)
    jax.block_until_ready(params)
    t_load = time.perf_counter() - t_load
    print(f"model load: {len(plans)} conv plans built + weights packed "
          f"in {t_load * 1e3:.1f} ms "
          f"(plan build {sum(p.build_ms for p in plans):.2f} ms)")

    cache_key = f"serve_dcgan/{cfg.name}{'-small' if args.small else ''}"
    batcher = DynamicImageBatcher(
        lambda z: gan.generator_apply(params, z, cfg),
        max_wait_ms=args.max_wait_ms, cache=cache, cache_key=cache_key)
    proto = np.zeros((cfg.z_dim,), np.float32)
    t0 = time.perf_counter()
    timed = batcher.warmup(proto)          # compile every bucket up front
    print(f"warmup: {len(batcher.buckets)} bucket executables compiled "
          f"in {time.perf_counter() - t0:.2f} s "
          f"(buckets {batcher.buckets}, "
          f"{len(timed)} timed / {len(batcher.buckets) - len(timed)} "
          f"from cache)")

    rng = np.random.default_rng(0)
    batcher.drive_open_loop(
        lambda i: rng.standard_normal(cfg.z_dim).astype(np.float32),
        args.requests, rate=args.rate)

    st = batcher.stats()
    imgs = batcher.done[-1].out
    print(f"served {st['completed']} requests over {st['launches']} launches "
          f"(bucket histogram {st['bucket_histogram']}, "
          f"pad fraction {st['pad_fraction']:.2f})")
    print(format_stats(st, unit="img"))
    print(f"output image shape: {imgs.shape} "
          f"({'32x32x3 reduced' if args.small else '64x64x3 from Table 1'})")
    assert all(np.isfinite(r.out).all() for r in batcher.done)


if __name__ == "__main__":
    main()
