"""End-to-end serving driver (the paper's kind: an *inference engine*):
serve the DCGAN generator with batched requests through the HUGE2 engine.

A tiny request queue feeds batches of latent vectors; the server jits one
batched generator call, drains the queue at a fixed batch size (padding the
tail), and reports throughput + per-request latency percentiles.

    PYTHONPATH=src python examples/serve_dcgan.py [--requests 64] [--batch 8]
"""
from __future__ import annotations

import argparse
import queue
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import gan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--backend", choices=("xla", "pallas"), default="xla")
    args = ap.parse_args()

    cfg = gan.GANConfig("dcgan", gan.DCGAN_LAYERS, backend=args.backend)
    key = jax.random.PRNGKey(0)
    # model load: build every conv plan + pack weights ONCE, serve forever
    t_load = time.perf_counter()
    plans = gan.generator_plans(cfg)
    params, _ = gan.generator_init(key, cfg)
    jax.block_until_ready(params)
    t_load = time.perf_counter() - t_load
    print(f"model load: {len(plans)} conv plans built + weights packed "
          f"in {t_load * 1e3:.1f} ms "
          f"(plan build {sum(p.build_ms for p in plans):.2f} ms)")
    serve = jax.jit(lambda p, z: gan.generator_apply(p, z, cfg))

    # warmup / compile
    z0 = jnp.zeros((args.batch, cfg.z_dim), jnp.float32)
    jax.block_until_ready(serve(params, z0))

    q: "queue.Queue[tuple[int, np.ndarray, float]]" = queue.Queue()
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        q.put((i, rng.standard_normal(cfg.z_dim, dtype=np.float32),
               time.perf_counter()))

    latencies = []
    done = 0
    t_start = time.perf_counter()
    while done < args.requests:
        reqs = []
        while len(reqs) < args.batch and not q.empty():
            reqs.append(q.get())
        ids = [r[0] for r in reqs]
        zs = np.stack([r[1] for r in reqs])
        if len(reqs) < args.batch:                       # pad the tail batch
            zs = np.concatenate(
                [zs, np.zeros((args.batch - len(reqs), cfg.z_dim),
                              np.float32)])
        imgs = jax.block_until_ready(serve(params, jnp.asarray(zs)))
        now = time.perf_counter()
        for (i, _, t_in) in reqs:
            latencies.append(now - t_in)
        done += len(reqs)
        assert np.isfinite(np.asarray(imgs[:len(reqs)])).all()

    dt = time.perf_counter() - t_start
    lat = np.array(latencies) * 1e3
    print(f"served {args.requests} requests, batch={args.batch}, "
          f"backend={args.backend}")
    print(f"throughput {args.requests / dt:8.1f} img/s   "
          f"latency p50 {np.percentile(lat, 50):6.1f} ms  "
          f"p95 {np.percentile(lat, 95):6.1f} ms")
    print(f"output image shape: {imgs.shape[1:]} (64x64x3 from Table 1)")


if __name__ == "__main__":
    main()
