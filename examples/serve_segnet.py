"""Serve semantic segmentation through the dynamic image batcher: the
second image workload on the same serving path as the DCGAN generator.

Image requests coalesce into the plan batch buckets (1/4/16/64) with a
max-wait deadline; each launch is one jitted SegNet forward + argmax on a
plan-time route — the whole model is planned conv sites on superpacked
weights, so serving never re-slices a kernel.

    PYTHONPATH=src python examples/serve_segnet.py [--requests 32]
        [--rate 0] [--max-wait-ms 2] [--full]
        [--autotune off|cache|measure] [--route-cache PATH]

``--full`` serves the 64px/width-128 edge config; default is the tiny
config so the CI smoke step finishes in seconds.  ``--autotune`` switches
the plans to measured routes backed by the per-host route cache
(``--route-cache``), which also persists the batcher's bucket costs — a
restarted server re-measures nothing.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune as at
from repro.models import segnet
from repro.serving.image_batcher import DynamicImageBatcher
from repro.serving.metrics import format_stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="arrival rate in req/s (0 = submit all at once)")
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--full", action="store_true",
                    help="64px width-128 config instead of the tiny one")
    ap.add_argument("--autotune", choices=("off", "cache", "measure"),
                    default="off",
                    help="measured routes: 'cache' = use cached winners only,"
                         " 'measure' = microbenchmark on cache miss")
    ap.add_argument("--route-cache", default=None,
                    help="route/bucket-cost cache path (default "
                         "$HUGE2_ROUTE_CACHE or ~/.cache/huge2)")
    args = ap.parse_args()

    policy = None
    cache = None
    if args.autotune != "off":
        policy = at.AutotunePolicy(mode=args.autotune,
                                   cache_path=args.route_cache)
        cache = at.open_cache(args.route_cache)
    base = segnet.SEGNET if args.full else segnet.SEGNET_TINY
    cfg = dataclasses.replace(base, autotune=policy)

    key = jax.random.PRNGKey(0)
    t0 = time.perf_counter()
    params, _ = segnet.segnet_init(key, cfg)
    plans = segnet.segnet_plans(cfg)
    jax.block_until_ready(params)
    print(f"model load: {cfg.name}, {len(plans)} planned conv sites "
          f"({sum(1 for p in plans if p.spec.kind == 'dilated')} dilated) "
          f"in {(time.perf_counter() - t0) * 1e3:.1f} ms")

    def serve_fn(x):
        # logits -> per-pixel class ids; argmax rides inside the jit
        return jnp.argmax(segnet.segnet_apply(params, x, cfg), axis=-1)

    cache_key = f"serve_segnet/{cfg.name}"
    batcher = DynamicImageBatcher(serve_fn, max_wait_ms=args.max_wait_ms,
                                  cache=cache, cache_key=cache_key)
    proto = np.zeros((cfg.in_hw, cfg.in_hw, cfg.in_c), np.float32)
    t0 = time.perf_counter()
    timed = batcher.warmup(proto)
    print(f"warmup: {len(batcher.buckets)} bucket executables compiled "
          f"in {time.perf_counter() - t0:.2f} s "
          f"(buckets {batcher.buckets}, "
          f"{len(timed)} timed / {len(batcher.buckets) - len(timed)} "
          f"from cache)")

    rng = np.random.default_rng(0)
    batcher.drive_open_loop(
        lambda i: rng.uniform(-1, 1, (cfg.in_hw, cfg.in_hw,
                                      cfg.in_c)).astype(np.float32),
        args.requests, rate=args.rate)

    st = batcher.stats()
    seg = batcher.done[-1].out
    print(f"served {st['completed']} requests over {st['launches']} launches "
          f"(bucket histogram {st['bucket_histogram']}, "
          f"pad fraction {st['pad_fraction']:.2f})")
    print(format_stats(st, unit="img"))
    print(f"segmentation map: {seg.shape} int{seg.dtype.itemsize * 8}, "
          f"classes used {np.unique(seg).size}/{cfg.num_classes}")
    assert seg.shape == (cfg.out_hw, cfg.out_hw)
    assert (seg >= 0).all() and (seg < cfg.num_classes).all()


if __name__ == "__main__":
    main()
