"""Serve semantic segmentation through the SLO-aware control plane: the
second image workload on the same admission/scheduling path as the DCGAN
generator.

Image requests arrive on an open loop (``--rate`` req/s; 0 = one burst)
with a priority class and an optional deadline; the control plane admits
(or rejects) them against the measured backlog, coalesces them into the
plan batch buckets (1/4/16/64) via its ``DynamicImageBatcher`` backend,
and sheds anything whose deadline passed before launch.  Each launch is
one jitted SegNet forward + argmax on a plan-time route — the whole model
is planned conv sites on superpacked weights, so serving never re-slices
a kernel.

The break-it-on-purpose path is runnable by hand: ``--inject-fault-at N``
kills the N-th launch mid-batch with a ``NodeFailure`` — the control
plane re-queues the launch's live requests and replays them, and the
driver proves zero drops/duplicates and bit-equal outputs against a
fault-free reference pass.  This is the CI fault-injection smoke.

    PYTHONPATH=src python examples/serve_segnet.py [--requests 32]
        [--rate 0] [--max-wait-ms 2] [--full]
        [--slo-ms 0] [--priority interactive] [--inject-fault-at 0]
        [--autotune off|cache|measure] [--route-cache PATH]

``--full`` serves the 64px/width-128 edge config; default is the tiny
config so the CI smoke step finishes in seconds.  ``--autotune`` switches
the plans to measured routes backed by the per-host route cache
(``--route-cache``), which also persists the batcher's bucket costs — a
restarted server re-measures nothing.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune as at
from repro.models import segnet
from repro.runtime.fault import FailureInjector
from repro.serving.control_plane import ControlPlane, ServeRequest
from repro.serving.metrics import format_stats


def build_control_plane(serve_fn, proto, *, max_wait_ms, cache, cache_key,
                        fault_at=0):
    injector = FailureInjector((fault_at,)) if fault_at > 0 else None
    cp = ControlPlane(injector=injector)
    be = cp.register_image_model("segnet", serve_fn, proto,
                                 max_wait_ms=max_wait_ms, cache=cache,
                                 cache_key=cache_key)
    return cp, be


def drive(cp, payloads, *, rate, priority, slo_ms):
    gap = 1.0 / rate if rate > 0 else 0.0
    for i, x in enumerate(payloads):
        if gap:
            time.sleep(gap)
        cp.submit(ServeRequest(rid=i, model="segnet", payload=x,
                               priority=priority,
                               slo_ms=slo_ms if slo_ms > 0 else None))
        cp.pump()
    cp.run()                       # drain
    return cp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="arrival rate in req/s (0 = submit all at once)")
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--full", action="store_true",
                    help="64px width-128 config instead of the tiny one")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="per-request SLO in ms (0 = no deadline); "
                         "blown backlogs reject at admission, expired "
                         "requests shed before launch")
    ap.add_argument("--priority", choices=("interactive", "batch"),
                    default="interactive")
    ap.add_argument("--inject-fault-at", type=int, default=0,
                    help="kill the N-th launch mid-batch with a "
                         "NodeFailure (0 = off) and prove replay")
    ap.add_argument("--autotune", choices=("off", "cache", "measure"),
                    default="off",
                    help="measured routes: 'cache' = use cached winners only,"
                         " 'measure' = microbenchmark on cache miss")
    ap.add_argument("--route-cache", default=None,
                    help="route/bucket-cost cache path (default "
                         "$HUGE2_ROUTE_CACHE or ~/.cache/huge2)")
    ap.add_argument("--wdtype", choices=("float32", "int8"),
                    default="float32",
                    help="weight storage dtype: 'int8' serves quantized "
                         "superpacks (~0.26x weight bytes) and asserts the "
                         "logit error vs an f32 twin under the documented "
                         "bound before serving")
    args = ap.parse_args()

    policy = None
    cache = None
    if args.autotune != "off":
        policy = at.AutotunePolicy(mode=args.autotune,
                                   cache_path=args.route_cache)
        cache = at.open_cache(args.route_cache)
    base = segnet.SEGNET if args.full else segnet.SEGNET_TINY
    cfg = dataclasses.replace(base, autotune=policy, wdtype=args.wdtype)

    key = jax.random.PRNGKey(0)
    t0 = time.perf_counter()
    params, _ = segnet.segnet_init(key, cfg)
    plans = segnet.segnet_plans(cfg)
    jax.block_until_ready(params)
    print(f"model load: {cfg.name} (wdtype={cfg.wdtype}), "
          f"{len(plans)} planned conv sites "
          f"({sum(1 for p in plans if p.spec.kind == 'dilated')} dilated) "
          f"in {(time.perf_counter() - t0) * 1e3:.1f} ms")

    if args.wdtype == "int8":
        # quantized-serving gate: same init key through an f32 twin config,
        # logits compared on one random batch.  Documented bound: each of
        # the L conv layers contributes at most ~1/2 an int8 grid step of
        # relative weight error (0.5/127 ≈ 0.4%), and the ReLU cascade
        # compounds at worst additively — rel L∞ ≤ L/127 with ~3x measured
        # headroom on the zoo configs (see docs/BENCHMARKS.md).
        twin = dataclasses.replace(cfg, name=cfg.name + "-f32twin",
                                   wdtype="float32")
        params_f, _ = segnet.segnet_init(key, twin)
        xq = jax.random.uniform(jax.random.PRNGKey(7),
                                (4, cfg.in_hw, cfg.in_hw, cfg.in_c),
                                minval=-1.0, maxval=1.0)
        lq = segnet.segnet_apply(params, xq, cfg)
        lf = segnet.segnet_apply(params_f, xq, twin)
        rel = float(jnp.max(jnp.abs(lq - lf)) / jnp.max(jnp.abs(lf)))
        bound = len(plans) / 127.0
        qb = sum(w.nbytes() for k, w in params.items() if k.startswith("w"))
        fb = sum(int(w.nbytes) for k, w in params_f.items()
                 if k.startswith("w"))
        print(f"int8 weights: {qb / fb:.2f}x f32 bytes "
              f"({qb} vs {fb}); logit rel err {rel:.4f} "
              f"(bound {bound:.4f} = {len(plans)} layers / 127)")
        assert rel <= bound, (rel, bound)
        del params_f

    def serve_fn(x):
        # logits -> per-pixel class ids; argmax rides inside the jit
        return jnp.argmax(segnet.segnet_apply(params, x, cfg), axis=-1)

    cache_key = f"serve_segnet/{cfg.name}/{cfg.wdtype}"
    proto = np.zeros((cfg.in_hw, cfg.in_hw, cfg.in_c), np.float32)
    cp, be = build_control_plane(serve_fn, proto,
                                 max_wait_ms=args.max_wait_ms, cache=cache,
                                 cache_key=cache_key,
                                 fault_at=args.inject_fault_at)
    t0 = time.perf_counter()
    timed = be.warmup()            # compile every bucket up front
    print(f"warmup: {len(be.batcher.buckets)} bucket executables compiled "
          f"in {time.perf_counter() - t0:.2f} s "
          f"(buckets {be.batcher.buckets}, "
          f"{len(timed)} timed / {len(be.batcher.buckets) - len(timed)} "
          f"from cache)")

    rng = np.random.default_rng(0)
    payloads = [rng.uniform(-1, 1, (cfg.in_hw, cfg.in_hw,
                                    cfg.in_c)).astype(np.float32)
                for _ in range(args.requests)]
    drive(cp, payloads, rate=args.rate, priority=args.priority,
          slo_ms=args.slo_ms)

    st = cp.stats()
    cls = st["per_class"][args.priority]
    print(f"served {st['served']} / rejected {st['rejected']} / "
          f"shed {st['shed']} of {st['submitted']} submitted "
          f"({st['per_model']['segnet']['launches']} launches, pad fraction "
          f"{st['per_model']['segnet']['pad_fraction']:.2f}, goodput "
          f"{st['goodput_under_slo']:.2f})")
    print(format_stats(cls, unit="img"))
    assert st["submitted"] == st["served"] + st["rejected"] + st["shed"]
    rids = [r.rid for r in cp.done]
    assert len(rids) == len(set(rids)), "a request was answered twice"

    if args.inject_fault_at > 0:
        assert st["faults"]["events"] >= 1, "fault never fired"
        assert st["replayed_requests"] >= 1, "no request was replayed"
        if args.rate == 0:
            # fault-free reference pass on the same burst + measured costs:
            # launch grouping is deterministic, so replayed responses must
            # be bit-equal (replay restores the exact pre-launch queue)
            ref, ref_be = build_control_plane(
                serve_fn, proto, max_wait_ms=args.max_wait_ms, cache=cache,
                cache_key=cache_key)
            ref_be.batcher.bucket_cost_s = dict(be.batcher.bucket_cost_s)
            drive(ref, payloads, rate=0.0, priority=args.priority,
                  slo_ms=0.0)
            got, want = cp.results(), ref.results()
            assert set(got) <= set(want), "faulted run served unknown rids"
            if args.slo_ms <= 0:
                assert sorted(got) == sorted(want), "served sets differ"
            assert all(np.array_equal(got[rid], want[rid]) for rid in got)
            print(f"fault at launch {args.inject_fault_at}: "
                  f"{st['faults']['records'][0]['live']} live requests "
                  f"re-queued + replayed; zero dropped, zero duplicated, "
                  f"outputs bit-equal to the fault-free pass ✓")
        else:
            print(f"fault at launch {args.inject_fault_at}: "
                  f"{st['faults']['records'][0]['live']} live requests "
                  f"re-queued + replayed; zero dropped, zero duplicated ✓ "
                  f"(bit-equal reference pass needs --rate 0: open-loop "
                  f"arrival timing changes the launch grouping)")

    if cp.done:
        seg = cp.done[-1].out
        print(f"segmentation map: {seg.shape} int{seg.dtype.itemsize * 8}, "
              f"classes used {np.unique(seg).size}/{cfg.num_classes}")
        assert seg.shape == (cfg.out_hw, cfg.out_hw)
        assert (seg >= 0).all() and (seg < cfg.num_classes).all()


if __name__ == "__main__":
    main()
