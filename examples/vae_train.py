"""Train the convolutional VAE through the HUGE² engine — encoder strided
convs AND decoder transposed convs run the planned/packed formulation in
both directions (forward single-launch routes, §3.2.3 custom VJPs on the
superpacked weights).

    PYTHONPATH=src python examples/vae_train.py [--steps 100] [--full]

``--full`` trains the 32px width-(64,128) config; default is the tiny
16px config so the CI one-step smoke finishes in seconds.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import vae


def batch_at(cfg, batch: int, step: int, seed: int = 0) -> np.ndarray:
    """Synthetic smooth images in [-1, 1] (low-frequency mixtures, so the
    ELBO has structure to learn), deterministic by (seed, step)."""
    rng = np.random.default_rng((seed, step))
    hw = cfg.image_hw
    yy, xx = np.mgrid[0:hw, 0:hw] / hw
    freq = rng.uniform(1.0, 4.0, (batch, cfg.in_c, 2, 1, 1))
    phase = rng.uniform(0, 2 * np.pi, (batch, cfg.in_c, 2, 1, 1))
    img = np.sin(2 * np.pi * freq[:, :, 0] * yy + phase[:, :, 0]) \
        * np.sin(2 * np.pi * freq[:, :, 1] * xx + phase[:, :, 1])
    return np.moveaxis(img, 1, -1).astype(np.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true",
                    help="32px width-(64,128) config instead of the tiny one")
    args = ap.parse_args()
    cfg = vae.VAE if args.full else vae.VAE_TINY

    key = jax.random.PRNGKey(0)
    t0 = time.perf_counter()
    params, _ = vae.vae_init(key, cfg)
    plans = vae.vae_plans(cfg)
    jax.block_until_ready(params)
    print(f"[load] {cfg.name}: {len(plans)} planned conv sites "
          f"({sum(1 for p in plans if p.spec.kind == 'transposed')} "
          f"transposed in the decoder), "
          f"plan build {sum(p.build_ms for p in plans):.2f} ms, "
          f"init total {(time.perf_counter() - t0) * 1e3:.1f} ms")
    print(f"[load] paths: {[p.path for p in plans]}")

    @jax.jit
    def step(p, x, k):
        loss, grads = jax.value_and_grad(
            lambda p: vae.elbo_loss(p, x, k, cfg))(p)
        p = jax.tree.map(lambda a, g: a - args.lr * g, p, grads)
        return p, loss

    # fixed-eval comparison: same batch, same reparameterization key before
    # and after training, so the improvement check measures the params only
    x0 = jnp.asarray(batch_at(cfg, args.batch, 0))
    eval_loss = jax.jit(lambda p: vae.elbo_loss(p, x0, jax.random.PRNGKey(1),
                                                cfg))
    before = float(eval_loss(params))
    losses = []
    t0 = time.perf_counter()
    for i in range(args.steps):
        key, sub = jax.random.split(key)
        x = jnp.asarray(batch_at(cfg, args.batch, i))
        params, loss = step(params, x, sub)
        losses.append(float(loss))
        if i % 20 == 0:
            print(f"[train] step {i:4d}: -ELBO {losses[-1]:.2f}")
    dt = time.perf_counter() - t0
    print(f"[train] {args.steps} steps in {dt:.1f}s "
          f"({dt / max(1, args.steps) * 1e3:.0f} ms/step)")

    assert np.isfinite(losses).all()
    # one step must already move the ELBO; longer runs must keep improving
    final = float(eval_loss(params))
    assert final < before, (final, before)
    print(f"[train] -ELBO {before:.2f} -> {final:.2f} (fixed eval batch; "
          f"packed VJPs through encoder AND decoder)")
    imgs = vae.sample(params, jax.random.PRNGKey(2), cfg, n=4)
    assert np.isfinite(np.asarray(imgs)).all()
    print(f"[sample] prior draws decoded: {tuple(imgs.shape)}")


if __name__ == "__main__":
    main()
