"""Docs tree check: markdown lint (fence balance, tab ban, trailing-space
ban on link lines) + relative-link existence, for README.md and docs/*.md.

    python tools/check_docs.py

Exits non-zero listing every violation; run by the CI docs step.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)\s]*)?\)")


def md_files():
    return [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]


def check_file(path: pathlib.Path) -> list[str]:
    errs = []
    text = path.read_text(encoding="utf-8")
    rel = path.relative_to(ROOT)

    if text.count("```") % 2 != 0:
        errs.append(f"{rel}: unbalanced code fences")
    for i, line in enumerate(text.splitlines(), 1):
        if "\t" in line:
            errs.append(f"{rel}:{i}: literal tab")

    # relative links must resolve (http(s) and mailto are out of scope)
    in_fence = False
    for i, line in enumerate(text.splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        matches = list(LINK_RE.finditer(line))
        if matches and line != line.rstrip():
            errs.append(f"{rel}:{i}: trailing whitespace on link line")
        for m in matches:
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if not (path.parent / target).resolve().exists():
                errs.append(f"{rel}:{i}: broken link -> {target}")
    return errs


def main() -> int:
    errs = []
    for f in md_files():
        if not f.exists():
            errs.append(f"missing required doc: {f.relative_to(ROOT)}")
            continue
        errs.extend(check_file(f))
    for e in errs:
        print(e)
    if not errs:
        print(f"docs OK: {len(md_files())} files, all links resolve")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
