"""Golden route-table generator: snapshot every model-zoo conv site's
per-bucket ``route_for_batch`` decision into a checked-in fixture.

The fixture (``tests/fixtures/route_table.json``) makes execution-path
changes an **explicit reviewable diff** instead of a silent perf cliff:
``tests/test_route_table.py`` rebuilds the table in-process and fails with
the drifted entries if it no longer matches.  After an *intentional* route
policy change, regenerate and commit the diff::

    PYTHONPATH=src python tools/gen_route_table.py

Covered sites: the fig7 suite (Table-1 DCGAN + cGAN generators, the VAE
decoder), the VAE encoder, every SegNet layer (strided front-end, atrous
context, 1x1 head), the BENCH_dilated layer suite, and the plane-parallel
convplane sites (``launch.dryrun.CONVPLANE_SITES``) under explicit device
tilings — pinning their per-bucket ``dev_tiles`` verdicts — each planned
under both explicit backends ('xla' and 'pallas'; 'auto' is excluded
because its verdict depends on the host's jax.default_backend()).

The committed fixture snapshots **heuristic** routes ONLY: those are pure
plan-time arithmetic over the spec constants, so *that* table is identical
on every host.  Measured (autotuned) routes are explicitly per-host — they
live in the ``repro.core.autotune`` route cache, never in this fixture.
``--measured`` runs the autotuner's microbenchmarks over the same sites
and *reports* the measured winners and their deltas against the fixture's
heuristic picks (nothing is written)::

    PYTHONPATH=src python tools/gen_route_table.py --measured [--buckets 1,4]

The spec/route JSON records are ``autotune.spec_to_json`` /
``autotune.route_to_json`` — ONE schema shared by this fixture and the
per-host cache file.
"""
from __future__ import annotations

import json
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
if str(_ROOT) not in sys.path:          # benchmarks.* lives at the repo root
    sys.path.insert(0, str(_ROOT))

FIXTURE = _ROOT / "tests" / "fixtures" / "route_table.json"

BACKENDS = ("xla", "pallas")


def route_specs():
    """(name, ConvSpec) for every covered conv site (backend-less)."""
    from repro.core.plan import ConvSpec
    from repro.models.gan import CGAN_LAYERS, DCGAN_LAYERS, deconv_padding
    from repro.models.segnet import SEGNET, atrous_padding
    from repro.models.vae import VAE

    specs = []

    def transposed(name, l):
        specs.append((name, ConvSpec(
            kind="transposed", in_hw=(l.in_hw, l.in_hw), in_c=l.in_c,
            out_c=l.out_c, kernel_hw=(l.kernel, l.kernel),
            strides=(l.stride, l.stride),
            padding=deconv_padding(l.kernel, l.stride))))

    for gan, layers in (("DCGAN", DCGAN_LAYERS), ("cGAN", CGAN_LAYERS),
                        ("VAEdec", VAE.decoder_layers)):
        for i, l in enumerate(layers):
            transposed(f"fig7_{gan}_DC{i + 1}", l)

    for i, l in enumerate(VAE.encoder_layers):
        k = l.kernel
        specs.append((f"vae_enc_L{i}", ConvSpec(
            kind="conv", in_hw=(l.in_hw, l.in_hw), in_c=l.in_c,
            out_c=l.out_c, kernel_hw=(k, k), strides=(l.stride, l.stride),
            padding=((k // 2, (k - 1) // 2), (k // 2, (k - 1) // 2)))))

    for i, l in enumerate(SEGNET.layers):
        specs.append((f"segnet_L{i}_{l.kind}_d{l.dilation}", ConvSpec(
            kind=l.kind, in_hw=(l.in_hw, l.in_hw), in_c=l.in_c,
            out_c=l.out_c, kernel_hw=(l.kernel, l.kernel),
            strides=(l.stride, l.stride),
            padding=atrous_padding(l.kernel, l.dilation),
            dilation=(l.dilation, l.dilation))))

    from benchmarks.dilated_conv import LAYERS as DILATED_BENCH
    for i, (h, c, n, k, d) in enumerate(DILATED_BENCH):
        specs.append((f"dilated_bench_L{i}_{h}x{h}x{c}_d{d}", ConvSpec(
            kind="dilated", in_hw=(h, h), in_c=c, out_c=n,
            kernel_hw=(k, k), padding=atrous_padding(k, d),
            dilation=(d, d))))

    # an engineered large-plane context site whose Pallas verdict provably
    # improves under 1-byte weights (spatial tiles (128, 16) → (128, 32)):
    # pins that the quantized VMEM accounting actually moves a verdict, not
    # just that equal-verdict twins stay equal
    specs.append(("quantflip_ctx385_c64n256k7", ConvSpec(
        kind="conv", in_hw=(385, 385), in_c=64, out_c=256,
        kernel_hw=(7, 7), padding=((3, 3), (3, 3)))))

    # the diffusion U-Net: every conv kind in one model — strided downs,
    # dilated bottleneck, transposed ups (pixel_shuffle-eligible k=4 s=2
    # geometry), skip-fuse convs — pinning the sub-pixel route verdicts
    from repro.models.unet import UNET, unet_sites
    for site, spec in unet_sites(UNET):
        specs.append((f"unet_{site}", spec))

    # quantized twins of every model-zoo site: int8 superpacks change only
    # the *weight* itemsize in the VMEM accounting, so any Route flip the
    # 1-byte tiles cause (taps/tiled → whole-plane, bigger sp_tiles) is
    # pinned here exactly like the f32 verdicts
    import dataclasses
    specs += [(f"{name}_w8", dataclasses.replace(spec, wdtype="int8"))
              for name, spec in specs]

    # plane-parallel requests: the dryrun convplane sites under their device
    # tilings — pins every ``dev_tiles`` verdict per site/bucket (like every
    # other column, pure plan-time arithmetic, identical on all hosts)
    from repro.launch.dryrun import convplane_spec
    for site, tiles in (("dilated_context_385", (4, 1)),
                        ("dilated_context_385", (2, 2)),
                        ("decoder_96", (2, 2)),
                        ("encoder_512", (4, 1))):
        specs.append((f"convplane_{site}_{tiles[0]}x{tiles[1]}",
                      convplane_spec(site, tiles)))
    return specs


def build_route_table():
    """The full table as a JSON-ready dict (deterministic ordering)."""
    import dataclasses

    from repro.core.autotune import route_to_json, spec_to_json
    from repro.core.plan import BATCH_BUCKETS, plan_conv

    entries = []
    for name, spec in route_specs():
        for backend in BACKENDS:
            plan = plan_conv(dataclasses.replace(spec, backend=backend))
            entries.append({
                "name": name,
                "backend": backend,
                "spec": spec_to_json(spec),
                "routes": [route_to_json(r) for r in plan.routes],
            })
    return {
        "generated_by": "PYTHONPATH=src python tools/gen_route_table.py",
        "buckets": list(BATCH_BUCKETS),
        "backends": list(BACKENDS),
        "entries": entries,
    }


def report_measured(buckets=(1,), iters=5, warmup=2):
    """``--measured``: microbenchmark the same sites and print the measured
    winner vs the heuristic pick, per (site, backend, bucket).  Reporting
    only — measured routes are per-host and belong in the autotune cache,
    never in the committed fixture."""
    import dataclasses

    from repro.core.autotune import (AutotunePolicy, measure_bucket,
                                     route_label)
    from repro.core.plan import plan_conv

    policy = AutotunePolicy(mode="measure", cache_path="", buckets=buckets,
                            iters=iters, warmup=warmup)
    n_flipped = 0
    for name, spec in route_specs():
        for backend in BACKENDS:
            plan = plan_conv(dataclasses.replace(spec, backend=backend))
            for b in buckets:
                heur = plan.route_for_batch(b)
                winner, timings = measure_bucket(plan, b, policy)
                flip = winner != heur
                n_flipped += flip
                h_t = timings.get(route_label(heur))
                w_t = timings.get(route_label(winner))
                delta = (f" {h_t / w_t:.2f}x"
                         if h_t and w_t and flip else "")
                print(f"{name}/{backend} B={b}: "
                      f"heuristic={route_label(heur)} "
                      f"measured={route_label(winner)}"
                      f"{' (FLIP' + delta + ')' if flip else ' (same)'}")
    print(f"# {n_flipped} measured flips vs fixture (host-specific; "
          f"NOT written to {FIXTURE.name})")


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--measured", action="store_true",
                    help="report (don't commit) microbenchmarked winners "
                         "vs the fixture's heuristic routes")
    ap.add_argument("--buckets", default="1",
                    help="comma-separated batch buckets for --measured")
    args = ap.parse_args(argv)
    if args.measured:
        report_measured(tuple(int(b) for b in args.buckets.split(",")))
        return
    table = build_route_table()
    FIXTURE.parent.mkdir(parents=True, exist_ok=True)
    FIXTURE.write_text(json.dumps(table, indent=1) + "\n")
    n_pallas = sum(1 for e in table["entries"] for r in e["routes"]
                   if r["path"] == "pallas")
    n_tiled = sum(1 for e in table["entries"] for r in e["routes"]
                  if r["sp_tiles"])
    n_dev = sum(1 for e in table["entries"] for r in e["routes"]
                if r.get("dev_tiles"))
    print(f"wrote {FIXTURE} ({len(table['entries'])} entries, "
          f"{n_pallas} pallas routes of which {n_tiled} tiled, "
          f"{n_dev} device-tiled)")


if __name__ == "__main__":
    main()
