"""Golden route-table generator: snapshot every model-zoo conv site's
per-bucket ``route_for_batch`` decision into a checked-in fixture.

The fixture (``tests/fixtures/route_table.json``) makes execution-path
changes an **explicit reviewable diff** instead of a silent perf cliff:
``tests/test_route_table.py`` rebuilds the table in-process and fails with
the drifted entries if it no longer matches.  After an *intentional* route
policy change, regenerate and commit the diff::

    PYTHONPATH=src python tools/gen_route_table.py

Covered sites: the fig7 suite (Table-1 DCGAN + cGAN generators, the VAE
decoder), the VAE encoder, every SegNet layer (strided front-end, atrous
context, 1x1 head), and the BENCH_dilated layer suite — each planned under
both explicit backends ('xla' and 'pallas'; 'auto' is excluded because its
verdict depends on the host's jax.default_backend()).  Routes are pure
plan-time arithmetic over the spec constants, so the table is identical on
every host.
"""
from __future__ import annotations

import json
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
if str(_ROOT) not in sys.path:          # benchmarks.* lives at the repo root
    sys.path.insert(0, str(_ROOT))

FIXTURE = _ROOT / "tests" / "fixtures" / "route_table.json"

BACKENDS = ("xla", "pallas")


def route_specs():
    """(name, ConvSpec) for every covered conv site (backend-less)."""
    from repro.core.plan import ConvSpec
    from repro.models.gan import CGAN_LAYERS, DCGAN_LAYERS, deconv_padding
    from repro.models.segnet import SEGNET, atrous_padding
    from repro.models.vae import VAE

    specs = []

    def transposed(name, l):
        specs.append((name, ConvSpec(
            kind="transposed", in_hw=(l.in_hw, l.in_hw), in_c=l.in_c,
            out_c=l.out_c, kernel_hw=(l.kernel, l.kernel),
            strides=(l.stride, l.stride),
            padding=deconv_padding(l.kernel, l.stride))))

    for gan, layers in (("DCGAN", DCGAN_LAYERS), ("cGAN", CGAN_LAYERS),
                        ("VAEdec", VAE.decoder_layers)):
        for i, l in enumerate(layers):
            transposed(f"fig7_{gan}_DC{i + 1}", l)

    for i, l in enumerate(VAE.encoder_layers):
        k = l.kernel
        specs.append((f"vae_enc_L{i}", ConvSpec(
            kind="conv", in_hw=(l.in_hw, l.in_hw), in_c=l.in_c,
            out_c=l.out_c, kernel_hw=(k, k), strides=(l.stride, l.stride),
            padding=((k // 2, (k - 1) // 2), (k // 2, (k - 1) // 2)))))

    for i, l in enumerate(SEGNET.layers):
        specs.append((f"segnet_L{i}_{l.kind}_d{l.dilation}", ConvSpec(
            kind=l.kind, in_hw=(l.in_hw, l.in_hw), in_c=l.in_c,
            out_c=l.out_c, kernel_hw=(l.kernel, l.kernel),
            strides=(l.stride, l.stride),
            padding=atrous_padding(l.kernel, l.dilation),
            dilation=(l.dilation, l.dilation))))

    from benchmarks.dilated_conv import LAYERS as DILATED_BENCH
    for i, (h, c, n, k, d) in enumerate(DILATED_BENCH):
        specs.append((f"dilated_bench_L{i}_{h}x{h}x{c}_d{d}", ConvSpec(
            kind="dilated", in_hw=(h, h), in_c=c, out_c=n,
            kernel_hw=(k, k), padding=atrous_padding(k, d),
            dilation=(d, d))))
    return specs


def build_route_table():
    """The full table as a JSON-ready dict (deterministic ordering)."""
    import dataclasses

    from repro.core.plan import BATCH_BUCKETS, plan_conv

    entries = []
    for name, spec in route_specs():
        for backend in BACKENDS:
            plan = plan_conv(dataclasses.replace(spec, backend=backend))
            entries.append({
                "name": name,
                "backend": backend,
                "spec": {
                    "kind": spec.kind, "in_hw": list(spec.in_hw),
                    "in_c": spec.in_c, "out_c": spec.out_c,
                    "kernel_hw": list(spec.kernel_hw),
                    "strides": list(spec.strides),
                    "padding": [list(p) for p in spec.padding],
                    "dilation": list(spec.dilation),
                },
                "routes": [{
                    "batch": r.batch,
                    "path": r.path,
                    "tiles": list(r.tiles) if r.tiles else None,
                    "sp_tiles": list(r.sp_tiles) if r.sp_tiles else None,
                    "fused_bwd": r.fused_bwd,
                } for r in plan.routes],
            })
    return {
        "generated_by": "PYTHONPATH=src python tools/gen_route_table.py",
        "buckets": list(BATCH_BUCKETS),
        "backends": list(BACKENDS),
        "entries": entries,
    }


def main():
    table = build_route_table()
    FIXTURE.parent.mkdir(parents=True, exist_ok=True)
    FIXTURE.write_text(json.dumps(table, indent=1) + "\n")
    n_pallas = sum(1 for e in table["entries"] for r in e["routes"]
                   if r["path"] == "pallas")
    n_tiled = sum(1 for e in table["entries"] for r in e["routes"]
                  if r["sp_tiles"])
    print(f"wrote {FIXTURE} ({len(table['entries'])} entries, "
          f"{n_pallas} pallas routes of which {n_tiled} tiled)")


if __name__ == "__main__":
    main()
