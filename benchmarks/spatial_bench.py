"""Plane-parallel conv bench: single-device vs shard_map halo exchange.

One conv plane spread across a device mesh (``core.spatial``): the plan's
``dev_tiles`` route shards H/W over 'sp_h'/'sp_w', each shard runs the
SAME superpack schedule on its slab, and boundaries arrive by one-hop
``ppermute`` halo exchange.  This bench times both executions of the
geometries the ISSUE names — the 385x385 dilated-context site and the
large transposed decoder — checks they agree with each other to float
round-off, and counts the collectives in the sharded jaxpr (halo traffic
must lower to ``ppermute`` only; an ``all_gather`` would mean the plane
was silently replicated).

Multi-device CPU meshes need ``--xla_force_host_platform_device_count``
set BEFORE jax initializes, and ``benchmarks.run`` has long since imported
jax — so ``main()`` re-execs this module in a child process with the flag
forced and the child writes the JSON.  Run standalone:

    PYTHONPATH=src python -m benchmarks.spatial_bench --emit BENCH_spatial.json

Timing caveat (docs/BENCHMARKS.md): on a dev host the 8 "devices" are
threads of one CPU, so ``speedup`` measures shard_map + halo *overhead*,
not the paper's multi-chip scaling — CI gates structure and parity, not
the ratio.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

_FLAG = "--xla_force_host_platform_device_count=8"

# site -> device tilings benched (full mode benches all, --quick the first)
BENCH_TILES = {
    "dilated_context_385": ((4, 1), (2, 2)),
    "decoder_96": ((2, 2), (4, 1)),
}


def _records(quick: bool) -> list[dict]:
    import jax
    import jax.numpy as jnp

    from benchmarks.util import time_fn
    from repro.core import spatial
    from repro.core.plan import plan_conv
    from repro.launch.dryrun import CONVPLANE_SITES, convplane_spec
    from repro.launch.mesh import make_spatial_mesh

    iters, warmup = (3, 1) if quick else (5, 2)
    out = []
    for site, tilings in BENCH_TILES.items():
        geom = CONVPLANE_SITES[site]
        batch = 1 if quick else geom["batch"]
        for dev_tiles in tilings[:1] if quick else tilings:
            spec1 = convplane_spec(site, (1, 1))
            specd = convplane_spec(site, dev_tiles)
            plan1, pland = plan_conv(spec1), plan_conv(specd)
            h, w = spec1.in_hw
            kx, kk = jax.random.split(jax.random.PRNGKey(0))
            x = jax.random.normal(kx, (batch, h, w, spec1.in_c), jnp.float32)
            pk = jax.random.normal(
                kk, (plan1.total_taps * spec1.in_c, spec1.out_c),
                jnp.float32) * 0.1

            f1 = jax.jit(lambda a, k: plan1.apply(a, k))
            y1 = jax.block_until_ready(f1(x, pk))
            single_us = time_fn(f1, x, pk, iters=iters, warmup=warmup) * 1e6

            mesh = make_spatial_mesh(*dev_tiles)
            fd = jax.jit(lambda a, k: pland.apply(a, k))
            with spatial.use_spatial_mesh(mesh):
                text = str(jax.make_jaxpr(lambda a, k: pland.apply(a, k))(
                    x, pk))
                yd = jax.block_until_ready(fd(x, pk))
                sharded_us = time_fn(fd, x, pk, iters=iters,
                                     warmup=warmup) * 1e6

            err = float(jnp.max(jnp.abs(yd - y1))
                        / (jnp.max(jnp.abs(y1)) + 1e-30))
            route = pland.route_for_batch(batch)
            rec = {
                "name": f"{site}@{dev_tiles[0]}x{dev_tiles[1]}",
                "site": site, "kind": geom["kind"],
                "in_hw": list(geom["in_hw"]), "in_c": geom["c"],
                "out_c": geom["n"], "kernel": list(geom["kernel"]),
                "strides": list(geom["strides"]),
                "dilation": list(geom["dilation"]), "batch": batch,
                "dev_tiles": list(dev_tiles),
                "route_path": route.path,
                "route_dev_tiles": (list(route.dev_tiles)
                                    if route.dev_tiles else None),
                "single_us": single_us, "sharded_us": sharded_us,
                "speedup": single_us / sharded_us,
                "max_rel_err": err,
                "ppermute": text.count("ppermute"),
                "all_gather": text.count("all_gather"),
            }
            out.append(rec)
            print(f"{rec['name']},{sharded_us:.1f},"
                  f"single={single_us:.1f}us x{rec['speedup']:.2f} "
                  f"err={err:.2e} pp={rec['ppermute']} "
                  f"ag={rec['all_gather']}", flush=True)
    return out


def child_main(quick: bool, json_path: str) -> None:
    import jax
    doc = {
        "schema": "huge2-bench-spatial/v1",
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "quick": quick,
        "sites": _records(quick),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {json_path}", flush=True)


def main(quick: bool = False, json_path: str | None = "BENCH_spatial.json"):
    """Re-exec under the forced-device-count flag (parent entry point)."""
    env = dict(os.environ)
    if "xla_force_host_platform_device_count" not in env.get("XLA_FLAGS", ""):
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + _FLAG).strip()
    cmd = [sys.executable, "-m", "benchmarks.spatial_bench", "--emit",
           json_path or ""]
    if quick:
        cmd.append("--quick")
    subprocess.run(cmd, env=env, check=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--emit", default="BENCH_spatial.json")
    args = ap.parse_args()
    if "xla_force_host_platform_device_count" in os.environ.get(
            "XLA_FLAGS", ""):
        child_main(args.quick, args.emit)
    else:
        main(args.quick, args.emit or None)
