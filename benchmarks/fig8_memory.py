"""Paper Fig. 8 (left): memory-access reduction of HUGE2 vs the naive
zero-insertion + im2col engine, per DCGAN / cGAN layer — analytic byte
counts from the traffic model in core/reference.py (paper reports 30-70%)."""
from __future__ import annotations

from benchmarks.util import csv_row
from repro.core.reference import memory_reduction_transpose
from repro.models.gan import CGAN_LAYERS, DCGAN_LAYERS

BATCH = 1


def main(print_csv=True):
    rows = []
    for gan, layers in (("DCGAN", DCGAN_LAYERS), ("cGAN", CGAN_LAYERS)):
        for i, l in enumerate(layers):
            m = memory_reduction_transpose(
                BATCH, l.in_hw, l.in_hw, l.in_c, l.kernel, l.kernel, l.out_c,
                l.stride)
            rows.append(csv_row(
                f"fig8_mem_{gan}_DC{i + 1}", 0.0,
                f"naive_bytes={int(m['naive_bytes'])} "
                f"huge_bytes={int(m['huge_bytes'])} "
                f"reduction={m['reduction'] * 100:.1f}%"))
    if print_csv:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    main()
