"""Paper Fig. 8 (left): memory-access reduction of HUGE2 vs the naive
zero-insertion + im2col engine, per DCGAN / cGAN layer (paper reports
30-70%).

Routed through planned execution: each layer's ``ConvPlan`` is built and
the analytic byte counts come from the actual plan geometry
(``bytes_planned_transpose``) — the fused single-launch executor's one
plane residency + one superpack stream + one interleaved output write —
next to the naive-engine model and the PR-1 per-phase executor's traffic.
"""
from __future__ import annotations

from benchmarks.util import csv_row
from repro.core.plan import ConvSpec, plan_conv
from repro.core.reference import bytes_naive_transpose, bytes_planned_transpose
from repro.models.gan import CGAN_LAYERS, DCGAN_LAYERS, deconv_padding

BATCH = 1


def main(print_csv=True):
    rows = []
    for gan, layers in (("DCGAN", DCGAN_LAYERS), ("cGAN", CGAN_LAYERS)):
        for i, l in enumerate(layers):
            plan = plan_conv(ConvSpec(
                kind="transposed", in_hw=(l.in_hw, l.in_hw), in_c=l.in_c,
                out_c=l.out_c, kernel_hw=(l.kernel, l.kernel),
                strides=(l.stride, l.stride),
                padding=deconv_padding(l.kernel, l.stride)))
            naive = bytes_naive_transpose(
                BATCH, l.in_hw, l.in_hw, l.in_c, l.kernel, l.kernel, l.out_c,
                l.stride)
            m = bytes_planned_transpose(plan, b=BATCH)
            rows.append(csv_row(
                f"fig8_mem_{gan}_DC{i + 1}", 0.0,
                f"naive_bytes={int(naive)} "
                f"fused_bytes={int(m['fused_bytes'])} "
                f"per_phase_bytes={int(m['per_phase_bytes'])} "
                f"reduction={(1 - m['fused_bytes'] / naive) * 100:.1f}% "
                f"fused_vs_per_phase="
                f"{(1 - m['fused_bytes'] / m['per_phase_bytes']) * 100:.1f}%"))
    if print_csv:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    main()
