"""Paper §3.2.2: untangled dilated (atrous) convolution vs the naive engine
that materializes the zero-inserted kernel.  Layer shapes follow DeepLab-v3
atrous blocks (the paper's semantic-segmentation motivation): 3x3 kernels,
dilation 2/4, CIFAR-scale feature maps on the edge budget.

Routed through planned execution: each site's ``ConvPlan`` is built once at
load (reported as ``plan_ms``), the steady-state loop times
``jax.jit(plan.apply)`` — the same entry the serving path uses — against
the naive engine.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import csv_row, time_fn
from repro.core import reference as ref
from repro.core.plan import conv_spec, plan_conv

BATCH = 1

LAYERS = (
    # (H, C, N, k, dilation)
    (33, 256, 256, 3, 2),
    (33, 256, 256, 3, 4),
    (17, 512, 512, 3, 2),
    (65, 128, 128, 3, 4),
)


def main(print_csv=True):
    rows = []
    for (h, c, n, k, d) in LAYERS:
        key = jax.random.PRNGKey(h)
        x = jax.random.normal(key, (BATCH, h, h, c), jnp.float32)
        kern = jax.random.normal(key, (k, k, c, n), jnp.float32)
        pad = ((d, d), (d, d))

        # model-load: one plan per site (identity pack for dilated kernels)
        t0 = time.perf_counter()
        plan = plan_conv(conv_spec("dilated", x.shape, kern.shape,
                                   dilation=(d, d), padding=pad))
        plan_ms = (time.perf_counter() - t0) * 1e3

        naive = jax.jit(functools.partial(ref.naive_dilated_conv2d,
                                          dilation=(d, d), padding=pad))
        planned = jax.jit(plan.apply)
        want = ref.oracle_dilated_conv2d(x, kern, dilation=(d, d),
                                         padding=pad)
        np.testing.assert_allclose(np.asarray(planned(x, kern)),
                                   np.asarray(want), rtol=2e-4, atol=2e-4)
        tn = time_fn(naive, x, kern, iters=5)
        th = time_fn(planned, x, kern, iters=5)
        rows.append(csv_row(f"dilated_{h}x{h}x{c}_d{d}", th * 1e6,
                            f"naive_us={tn * 1e6:.1f} "
                            f"speedup={tn / th:.2f}x "
                            f"plan_ms={plan_ms:.2f}"))
    if print_csv:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    main()
