"""Paper §3.2.2: untangled dilated (atrous) convolution, benchmarked on the
semantic-segmentation block suite.

Engines per layer, all jitted, min-of-N wall-clock (the same measurement
convention as fig7: the paper's comparison is against the baseline *engine*
that executes the zero-inserted formulation, with ``lax`` kept as the
correctness oracle):

- ``untangled_us``     — the planned single-correlation executor
  (``plan.apply`` on the (R·S·C, N) superpack: one wide GEMM / one Pallas
  launch / per-tap fallback, chosen at plan time).
- ``rhs_dilation_us``  — the rhs-dilation baseline engine: materialize the
  rhs-dilated (zero-inserted) kernel, then im2col GEMM at the dilated
  extent (``reference.naive_dilated_conv2d`` — DarkNet's pipeline, the
  engine the paper measured against).  The headline geomean is against
  this.
- ``lax_oracle_us``    — XLA's own fused ``conv_general_dilated`` with
  ``rhs_dilation``, reported for transparency: on CPU XLA's Eigen conv is
  itself zero-free and equal-FLOP, so the untangled executor trades within
  noise of it (see ``geomean_untangled_vs_lax_oracle``); the engine's win
  is against engines that *execute* the zero-inserted formulation, plus
  the load-time packed-weight layout the oracle cannot hold.

An ``autotuned_us`` column runs the same site through a measure-mode
``AutotunePolicy`` (memory-only cache, benched bucket only) and reports the
measured route + its speedup over the heuristic pick
(``autotune_vs_heuristic``; ``route_flipped`` when they differ).

Layer shapes are the SegNet context blocks (``models/segnet.py`` — constant
resolution, dilation 1..8) plus DeepLab-v3-style atrous heads at CIFAR/edge
scale.  Emits machine-readable ``BENCH_dilated.json`` (per-layer µs +
``geomean_untangled_vs_rhs_dilation``) next to ``BENCH_fig7.json``.
"""
from __future__ import annotations

import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import csv_row, geomean, pallas_tiled_record, time_fn
from repro.core import reference as ref
from repro.core.autotune import AutotunePolicy
from repro.core.plan import conv_spec, plan_conv
from repro.models.segnet import SEGNET, atrous_padding

BATCH = 1
JSON_PATH = "BENCH_dilated.json"

# segmentation block suite: the SEGNET context module measured end-to-end
# (16x16 plane at width 128, d = 1,2,4,8) + DeepLab-v3-style atrous blocks
CONTEXT = tuple(
    (SEGNET.in_hw // 4, SEGNET.width, SEGNET.width, 3, l.dilation)
    for l in SEGNET.layers if l.kind == "dilated")
LAYERS = CONTEXT + (
    # (H, C, N, k, dilation)
    (33, 256, 256, 3, 2),
    (33, 256, 256, 3, 4),
    (17, 512, 512, 3, 2),
    (65, 128, 128, 3, 4),
    # DeepLab-v3 decoder-grid scale: the plane is too big for whole-plane
    # VMEM residency *and* the fused tap-stack busts _PLANE_BYTES_MAX, so at
    # HEAD this geometry routed to 'taps' even under backend='pallas' — the
    # spatially tiled kernel reclaims it (the ``pallas_tiled`` column)
    (385, 32, 32, 3, 2),
)


def bench_layer(h, c, n, k, d, iters=5, warmup=2):
    key = jax.random.PRNGKey(h * 7 + d)
    x = jax.random.normal(key, (BATCH, h, h, c), jnp.float32)
    kern = jax.random.normal(key, (k, k, c, n), jnp.float32)
    pad = atrous_padding(k, d)

    # model-load: one plan per site, superpacked weights
    t0 = time.perf_counter()
    plan = plan_conv(conv_spec("dilated", x.shape, kern.shape,
                               dilation=(d, d), padding=pad))
    plan_ms = (time.perf_counter() - t0) * 1e3
    packed = jax.block_until_ready(plan.pack(kern))
    # pallas_tiled column: the same site under backend='pallas' — big
    # planes land on the spatially tiled kernel instead of leaving Pallas
    plan_p = plan_conv(conv_spec("dilated", x.shape, kern.shape,
                                 dilation=(d, d), padding=pad,
                                 backend="pallas"))
    # autotuned column: routes measured for the benched bucket only, on a
    # memory-only cache (the bench is the measurement, not a cache client)
    plan_at = plan_conv(conv_spec("dilated", x.shape, kern.shape,
                                  dilation=(d, d), padding=pad),
                        autotune=AutotunePolicy(
                            mode="measure", cache_path="", buckets=(BATCH,),
                            iters=iters, warmup=warmup))

    untangled = jax.jit(plan.apply)
    autotuned = jax.jit(plan_at.apply)
    baseline = jax.jit(functools.partial(ref.naive_dilated_conv2d,
                                         dilation=(d, d), padding=pad))
    oracle = jax.jit(functools.partial(ref.oracle_dilated_conv2d,
                                       dilation=(d, d), padding=pad))
    want = oracle(x, kern)
    np.testing.assert_allclose(np.asarray(untangled(x, packed)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(baseline(x, kern)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(autotuned(x, packed)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)
    bytes_model = ref.bytes_planned_single(plan, b=BATCH)
    return {
        "path": plan.path,
        "autotuned_path": plan_at.route_for_batch(BATCH).path,
        "route_flipped": (plan_at.route_for_batch(BATCH)
                          != plan.route_for_batch(BATCH)),
        "autotuned_us": time_fn(autotuned, x, packed, iters=iters,
                                warmup=warmup) * 1e6,
        "pallas_tiled": pallas_tiled_record(
            plan_p, apply_fn=plan_p.apply, args=(x, packed),
            iters=iters, warmup=warmup),
        "plan_ms": plan_ms,
        "untangled_us": time_fn(untangled, x, packed, iters=iters,
                                warmup=warmup) * 1e6,
        "rhs_dilation_us": time_fn(baseline, x, kern, iters=iters,
                                   warmup=warmup) * 1e6,
        "lax_oracle_us": time_fn(oracle, x, kern, iters=iters,
                                 warmup=warmup) * 1e6,
        "bytes_reduction_vs_naive": bytes_model["reduction"],
    }


def main(print_csv=True, quick=False, json_path=JSON_PATH):
    iters, warmup = (3, 1) if quick else (5, 2)
    rows, records = [], []
    for i, (h, c, n, k, d) in enumerate(LAYERS):
        t = bench_layer(h, c, n, k, d, iters=iters, warmup=warmup)
        # L<i> suffix: the context module legitimately repeats d=1 (the
        # DilatedNet schedule), so the position disambiguates JSON records
        # (and the repeat's plan_ms is a cache hit, not a second build)
        rec = dict(name=f"dilated_L{i}_{h}x{h}x{c}_d{d}", in_hw=h, in_c=c,
                   out_c=n, kernel=k, dilation=d, **t)
        rec["speedup_vs_rhs_dilation"] = (t["rhs_dilation_us"]
                                         / t["untangled_us"])
        rec["speedup_vs_lax_oracle"] = t["lax_oracle_us"] / t["untangled_us"]
        rec["autotune_vs_heuristic"] = t["untangled_us"] / t["autotuned_us"]
        records.append(rec)
        pt = t["pallas_tiled"]
        rows.append(csv_row(
            rec["name"], t["untangled_us"],
            f"rhs_dilation_us={t['rhs_dilation_us']:.1f} "
            f"speedup={rec['speedup_vs_rhs_dilation']:.2f}x "
            f"lax_oracle_us={t['lax_oracle_us']:.1f} "
            f"vs_lax={rec['speedup_vs_lax_oracle']:.2f}x "
            f"path={t['path']} "
            f"pallas_tiled={pt['path']}"
            + (f"@sp{tuple(pt['sp_tiles'])}" if pt["tiled"] else "")
            + f" autotuned={t['autotuned_path']}"
            + ("*" if t["route_flipped"] else "")
            + f"@{rec['autotune_vs_heuristic']:.2f}x"
            + f" plan_ms={t['plan_ms']:.2f}"))

    geo = geomean([r["speedup_vs_rhs_dilation"] for r in records])
    geo_lax = geomean([r["speedup_vs_lax_oracle"] for r in records])
    geo_at = geomean([r["autotune_vs_heuristic"] for r in records])
    flipped = [r["name"] for r in records if r["route_flipped"]]
    reclaimed = [r["name"] for r in records if r["pallas_tiled"]["tiled"]]
    payload = {
        "bench": "dilated", "batch": BATCH, "quick": quick,
        "backend": jax.default_backend(),
        "layers": records,
        "geomean_untangled_vs_rhs_dilation": geo,
        "geomean_untangled_vs_lax_oracle": geo_lax,
        "geomean_autotuned_vs_heuristic": geo_at,
        "routes_flipped": flipped,
        # geometries only the spatially tiled kernel keeps on the Pallas
        # route (whole-plane VMEM residency is infeasible for them)
        "pallas_tiled_reclaimed": reclaimed,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
    if print_csv:
        for r in rows:
            print(r)
        print(f"# geomean_untangled_vs_rhs_dilation={geo:.2f}x "
              f"(vs_lax_oracle={geo_lax:.2f}x) "
              f"geomean_autotuned_vs_heuristic={geo_at:.2f}x "
              f"routes_flipped={flipped} "
              f"pallas_tiled_reclaimed={reclaimed}"
              + (f" -> {json_path}" if json_path else ""))
    return payload


if __name__ == "__main__":
    main()
