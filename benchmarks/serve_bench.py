"""Serving benchmark: the dynamic image batcher vs the fixed-batch PR-1
serve loop, on the cGAN generator (paper Table 1), writing
``BENCH_serve.json``.

Workload: a seeded trace of request *bursts* (geometric sizes, mostly 1-4
requests — the edge-serving shape: many devices, small coincident queues —
capped at 16, with two full-16 bursts for coverage), served closed-loop:
each burst arrives when the server is free, and every request's latency is
wall-clock from burst arrival to its launch completing.  Both servers run
the identical jitted generator; only scheduling differs:

- ``fixed``   — the PR-1 loop: every launch is a fixed batch (default 8),
  tail-padded, regardless of queue depth.
- ``dynamic`` — ``serving.image_batcher.DynamicImageBatcher``: launches on
  plan batch buckets (1/4/16/64), covering the queue with the bucket
  multiset that minimizes *measured* per-bucket launch cost.

The whole trace is repeated and the best run per server kept (min-of-N —
the same noise-robust statistic as ``util.time_fn``).  Percentiles come
from the one shared implementation in ``repro.serving.metrics``.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import format_stats, latency_stats
from repro.models import gan
from repro.serving.image_batcher import DynamicImageBatcher, ImageRequest

JSON_PATH = "BENCH_serve.json"
FIXED_BATCH = 8            # the PR-1 serve_dcgan default
BURSTS = 24
BURST_CAP = 16


def make_trace(rng) -> list[int]:
    sizes = [min(BURST_CAP, int(k)) for k in rng.geometric(0.5, BURSTS)]
    return sizes + [BURST_CAP, BURST_CAP]      # coverage of the big bucket


def serve_fixed(serve, z_dim, trace, rng) -> dict:
    """The PR-1 loop: drain each burst in fixed-size tail-padded launches."""
    latencies, launches = [], 0
    t_start = time.perf_counter()
    for k in trace:
        zs = rng.standard_normal((k, z_dim)).astype(np.float32)
        t_burst = time.perf_counter()
        for off in range(0, k, FIXED_BATCH):
            chunk = zs[off:off + FIXED_BATCH]
            if len(chunk) < FIXED_BATCH:
                chunk = np.concatenate([chunk, np.zeros(
                    (FIXED_BATCH - len(chunk), z_dim), np.float32)])
            jax.block_until_ready(serve(jnp.asarray(chunk)))
            launches += 1
            now = time.perf_counter()
            latencies += [now - t_burst] * min(FIXED_BATCH, k - off)
    st = latency_stats(latencies, window_s=time.perf_counter() - t_start)
    st["launches"] = launches
    st["batch"] = FIXED_BATCH
    return st


def serve_dynamic(batcher, z_dim, trace, rng) -> dict:
    rid = 0
    for k in trace:
        for _ in range(k):
            batcher.submit(ImageRequest(
                rid=rid,
                payload=rng.standard_normal(z_dim).astype(np.float32)))
            rid += 1
        while batcher.queue:                   # closed loop: drain the burst
            batcher.pump(drain=True)
    return batcher.stats()


def pallas_route_table(cfg) -> list:
    """The ``pallas_tiled`` column for the served model: every generator
    conv site's per-bucket route under backend='pallas'.  Proves the big
    buckets (the B=64 launch the batcher coalesces into) stay on the Pallas
    route — whole-plane where it fits, spatially tiled where it doesn't —
    instead of degrading to 'taps'."""
    import dataclasses
    table = []
    for i, plan in enumerate(gan.generator_plans(cfg)):
        plan_p = gan.plan_conv(dataclasses.replace(plan.spec,
                                                   backend="pallas"))
        table.append({
            "layer": i + 1,
            "routes": [{"batch": r.batch, "path": r.path,
                        "tiles": list(r.tiles) if r.tiles else None,
                        "sp_tiles": list(r.sp_tiles) if r.sp_tiles else None}
                       for r in plan_p.routes],
        })
    return table


def main(print_csv=True, quick=False, json_path=JSON_PATH):
    repeats = 2 if quick else 4
    cfg = gan.CGAN
    params, _ = gan.generator_init(jax.random.PRNGKey(0), cfg)
    serve_fn = lambda z: gan.generator_apply(params, z, cfg)   # noqa: E731
    serve = jax.jit(serve_fn)
    jax.block_until_ready(serve(jnp.zeros((FIXED_BATCH, cfg.z_dim))))

    trace = make_trace(np.random.default_rng(7))
    n_req = sum(trace)
    # one batcher, warmed once: repeats measure scheduling, not recompiles
    batcher = DynamicImageBatcher(serve_fn)
    batcher.warmup(np.zeros((cfg.z_dim,), np.float32))
    bucket_cost = {b: t * 1e3 for b, t in batcher.bucket_cost_s.items()}
    best_fixed = best_dyn = None
    for _ in range(repeats):
        st_f = serve_fixed(serve, cfg.z_dim, trace,
                           np.random.default_rng(1))
        if best_fixed is None or st_f["throughput_rps"] \
                > best_fixed["throughput_rps"]:
            best_fixed = st_f
        batcher.reset_stats()
        st_d = serve_dynamic(batcher, cfg.z_dim, trace,
                             np.random.default_rng(1))
        if best_dyn is None or st_d["throughput_rps"] \
                > best_dyn["throughput_rps"]:
            best_dyn = st_d

    payload = {
        "bench": "serve", "quick": quick, "backend": jax.default_backend(),
        "model": "cgan", "requests": n_req,
        "trace": {"bursts": len(trace), "sizes": trace},
        "buckets": list(batcher.buckets),
        "bucket_cost_ms": bucket_cost,
        "pallas_tiled": pallas_route_table(cfg),
        "fixed": best_fixed,
        "dynamic": best_dyn,
        "throughput_ratio":
            best_dyn["throughput_rps"] / best_fixed["throughput_rps"],
        "p95_ratio": best_dyn["p95_ms"] / best_fixed["p95_ms"],
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
    if print_csv:
        print(f"serve_fixed_b{FIXED_BATCH},{best_fixed['mean_ms'] * 1e3:.1f},"
              f"{format_stats(best_fixed, unit='img')}")
        print(f"serve_dynamic,{best_dyn['mean_ms'] * 1e3:.1f},"
              f"{format_stats(best_dyn, unit='img')}")
        print(f"# dynamic_vs_fixed throughput {payload['throughput_ratio']:.2f}x "
              f"p95 {payload['p95_ratio']:.2f}x"
              + (f" -> {json_path}" if json_path else ""))
    return payload


if __name__ == "__main__":
    main()
