"""Serving benchmarks: (1) the dynamic image batcher vs the fixed-batch
PR-1 serve loop (closed loop, ``BENCH_serve.json``) and (2) the open-loop
SLO/tail-latency harness over the serving control plane
(``BENCH_slo.json``), both on the cGAN generator (paper Table 1).

**Closed loop** (``main``): a seeded trace of request *bursts* (geometric
sizes, mostly 1-4 requests — the edge-serving shape: many devices, small
coincident queues — capped at 16, with two full-16 bursts for coverage);
each burst arrives when the server is free, and every request's latency is
wall-clock from burst arrival to its launch completing.  Both servers run
the identical jitted generator; only scheduling differs:

- ``fixed``   — the PR-1 loop: every launch is a fixed batch (default 8),
  tail-padded, regardless of queue depth.
- ``dynamic`` — ``serving.image_batcher.DynamicImageBatcher``: launches on
  plan batch buckets (1/4/16/64), covering the queue with the bucket
  multiset that minimizes *measured* per-bucket launch cost.

The whole trace is repeated and the best run per server kept (min-of-N —
the same noise-robust statistic as ``util.time_fn``).  Percentiles come
from the one shared implementation in ``repro.serving.metrics``.

**Open loop** (``slo_main``): rate-controlled Poisson arrivals — requests
arrive on a wall-clock schedule *regardless* of server progress, so queue
growth and tail latency are measured rather than hidden (the closed loop
can never observe overload: it only offers work when the server is free).
Traffic is 10x (``--quick``) / 100x the closed-loop trace's request count,
split 70/30 into ``interactive``/``batch`` priority classes with
SLOs scaled from the *measured* largest-bucket launch cost (so the bench
means the same thing on any host speed).  Two phases run: ``nominal``
(offered load 0.6x the measured capacity) and ``overload`` (1.6x — by
construction the control plane must reject at admission and/or shed
expired requests; those are counted separately from served ones, never
silently dropped).  Per class, ``BENCH_slo.json`` reports p50/p95/p99 and
**goodput under SLO**; every scheduler change is gated on these tails,
not just throughput.  See docs/BENCHMARKS.md for every field.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import format_stats, latency_stats
from repro.models import gan, unet
from repro.serving.control_plane import ControlPlane, ServeRequest
from repro.serving.image_batcher import DynamicImageBatcher, ImageRequest

JSON_PATH = "BENCH_serve.json"
SLO_JSON_PATH = "BENCH_slo.json"
UNET_JSON_PATH = "BENCH_unet.json"
FIXED_BATCH = 8            # the PR-1 serve_dcgan default
BURSTS = 24
BURST_CAP = 16
# open-loop harness knobs: class mix, SLO multiples of the measured
# largest-bucket launch cost, offered-load factors vs measured capacity
CLASS_MIX = {"interactive": 0.7, "batch": 0.3}
# SLO = multiple x measured largest-bucket launch cost.  The overload
# backlog after N arrivals at load L is N*(1-1/L) requests ~= that many
# service units over capacity; capacity cancels against the SLO's own
# cost scaling, so these multiples put the overload phase past the
# interactive deadline on ANY host speed while nominal stays inside it.
SLO_COST_MULTIPLE = {"interactive": 3.0, "batch": 12.0}
PHASES = {"nominal": 0.6, "overload": 1.6}


def make_trace(rng) -> list[int]:
    sizes = [min(BURST_CAP, int(k)) for k in rng.geometric(0.5, BURSTS)]
    return sizes + [BURST_CAP, BURST_CAP]      # coverage of the big bucket


def serve_fixed(serve, z_dim, trace, rng) -> dict:
    """The PR-1 loop: drain each burst in fixed-size tail-padded launches."""
    latencies, launches = [], 0
    t_start = time.perf_counter()
    for k in trace:
        zs = rng.standard_normal((k, z_dim)).astype(np.float32)
        t_burst = time.perf_counter()
        for off in range(0, k, FIXED_BATCH):
            chunk = zs[off:off + FIXED_BATCH]
            if len(chunk) < FIXED_BATCH:
                chunk = np.concatenate([chunk, np.zeros(
                    (FIXED_BATCH - len(chunk), z_dim), np.float32)])
            jax.block_until_ready(serve(jnp.asarray(chunk)))
            launches += 1
            now = time.perf_counter()
            latencies += [now - t_burst] * min(FIXED_BATCH, k - off)
    st = latency_stats(latencies, window_s=time.perf_counter() - t_start)
    st["launches"] = launches
    st["batch"] = FIXED_BATCH
    return st


def serve_dynamic(batcher, z_dim, trace, rng) -> dict:
    rid = 0
    for k in trace:
        for _ in range(k):
            batcher.submit(ImageRequest(
                rid=rid,
                payload=rng.standard_normal(z_dim).astype(np.float32)))
            rid += 1
        while batcher.queue:                   # closed loop: drain the burst
            batcher.pump(drain=True)
    return batcher.stats()


def pallas_route_table(cfg) -> list:
    """The ``pallas_tiled`` column for the served model: every generator
    conv site's per-bucket route under backend='pallas'.  Proves the big
    buckets (the B=64 launch the batcher coalesces into) stay on the Pallas
    route — whole-plane where it fits, spatially tiled where it doesn't —
    instead of degrading to 'taps'."""
    import dataclasses
    table = []
    for i, plan in enumerate(gan.generator_plans(cfg)):
        plan_p = gan.plan_conv(dataclasses.replace(plan.spec,
                                                   backend="pallas"))
        table.append({
            "layer": i + 1,
            "routes": [{"batch": r.batch, "path": r.path,
                        "tiles": list(r.tiles) if r.tiles else None,
                        "sp_tiles": list(r.sp_tiles) if r.sp_tiles else None}
                       for r in plan_p.routes],
        })
    return table


def main(print_csv=True, quick=False, json_path=JSON_PATH):
    repeats = 2 if quick else 4
    cfg = gan.CGAN
    params, _ = gan.generator_init(jax.random.PRNGKey(0), cfg)
    serve_fn = lambda z: gan.generator_apply(params, z, cfg)   # noqa: E731
    serve = jax.jit(serve_fn)
    jax.block_until_ready(serve(jnp.zeros((FIXED_BATCH, cfg.z_dim))))

    trace = make_trace(np.random.default_rng(7))
    n_req = sum(trace)
    # one batcher, warmed once: repeats measure scheduling, not recompiles
    batcher = DynamicImageBatcher(serve_fn)
    batcher.warmup(np.zeros((cfg.z_dim,), np.float32))
    bucket_cost = {b: t * 1e3 for b, t in batcher.bucket_cost_s.items()}
    best_fixed = best_dyn = None
    for _ in range(repeats):
        st_f = serve_fixed(serve, cfg.z_dim, trace,
                           np.random.default_rng(1))
        if best_fixed is None or st_f["throughput_rps"] \
                > best_fixed["throughput_rps"]:
            best_fixed = st_f
        batcher.reset_stats()
        st_d = serve_dynamic(batcher, cfg.z_dim, trace,
                             np.random.default_rng(1))
        if best_dyn is None or st_d["throughput_rps"] \
                > best_dyn["throughput_rps"]:
            best_dyn = st_d

    payload = {
        "bench": "serve", "quick": quick, "backend": jax.default_backend(),
        "model": "cgan", "requests": n_req,
        "trace": {"bursts": len(trace), "sizes": trace},
        "buckets": list(batcher.buckets),
        "bucket_cost_ms": bucket_cost,
        "pallas_tiled": pallas_route_table(cfg),
        "fixed": best_fixed,
        "dynamic": best_dyn,
        "throughput_ratio":
            best_dyn["throughput_rps"] / best_fixed["throughput_rps"],
        "p95_ratio": best_dyn["p95_ms"] / best_fixed["p95_ms"],
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
    if print_csv:
        print(f"serve_fixed_b{FIXED_BATCH},{best_fixed['mean_ms'] * 1e3:.1f},"
              f"{format_stats(best_fixed, unit='img')}")
        print(f"serve_dynamic,{best_dyn['mean_ms'] * 1e3:.1f},"
              f"{format_stats(best_dyn, unit='img')}")
        print(f"# dynamic_vs_fixed throughput {payload['throughput_ratio']:.2f}x "
              f"p95 {payload['p95_ratio']:.2f}x"
              + (f" -> {json_path}" if json_path else ""))
    return payload


def drive_open_loop(cp: ControlPlane, model: str, z_dim: int, *,
                    n_req: int, rate_rps: float, slo_ms: dict,
                    seed: int = 0) -> float:
    """Submit ``n_req`` Poisson arrivals at ``rate_rps`` on a wall-clock
    schedule (open loop: arrivals never wait for the server), pumping the
    control plane between arrivals, then drain.  Because one pump can
    block for a whole launch, every arrival whose scheduled time passed
    while the server was busy is flushed before the next pump, stamped
    with its *scheduled* ``t_arrival`` — latency is measured from when
    the request arrived, not from when the busy server got around to
    noticing it (the difference IS the queueing delay an open-loop
    harness exists to expose).  Returns the measured duration (first
    scheduled arrival -> last completion)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, n_req)
    classes = rng.choice(list(CLASS_MIX), n_req, p=list(CLASS_MIX.values()))
    payloads = rng.standard_normal((n_req, z_dim)).astype(np.float32)
    t0 = time.perf_counter()
    arrivals = t0 + np.cumsum(gaps)
    i = 0
    while i < n_req or cp.pending():
        now = time.perf_counter()
        while i < n_req and arrivals[i] <= now:
            cls = str(classes[i])
            cp.submit(ServeRequest(rid=i, model=model,
                                   payload=payloads[i], priority=cls,
                                   slo_ms=slo_ms[cls],
                                   t_arrival=float(arrivals[i])))
            i += 1
        cp.pump(drain=i == n_req)           # drain once arrivals stop
    return time.perf_counter() - t0


def slo_main(print_csv=True, quick=False, json_path=SLO_JSON_PATH):
    """Open-loop tail-latency harness over the serving control plane."""
    cfg = gan.CGAN
    params, _ = gan.generator_init(jax.random.PRNGKey(0), cfg)
    serve_fn = lambda z: gan.generator_apply(params, z, cfg)   # noqa: E731

    # measured capacity: one warmed-up control plane per phase shares the
    # bucket costs measured here (same jitted fn => same executables)
    probe = DynamicImageBatcher(serve_fn)
    probe.warmup(np.zeros((cfg.z_dim,), np.float32))
    big = probe.buckets[-1]
    unit_s = probe.bucket_cost_s[big]          # one largest-bucket launch
    capacity_rps = big / unit_s
    slo_ms = {c: m * unit_s * 1e3 for c, m in SLO_COST_MULTIPLE.items()}

    n_pr4 = sum(make_trace(np.random.default_rng(7)))
    mult = 10 if quick else 100
    n_req = n_pr4 * mult

    phases = {}
    for phase, load in PHASES.items():
        cp = ControlPlane(starvation_ms=50.0)
        be = cp.register_image_model("cgan", serve_fn,
                                     np.zeros((cfg.z_dim,), np.float32))
        # reuse the probe's measured costs: phases measure scheduling and
        # queueing, not re-measurement noise
        be.batcher.bucket_cost_s = dict(probe.bucket_cost_s)
        be.batcher._sched_memo = {0: (0.0, 0)}
        be.warmup()                            # compile only, no timing
        offered = load * capacity_rps
        dur = drive_open_loop(cp, "cgan", cfg.z_dim, n_req=n_req,
                              rate_rps=offered, slo_ms=slo_ms, seed=11)
        st = cp.stats()
        assert st["queued"] == 0, "drain left work behind"
        assert (st["submitted"]
                == st["served"] + st["rejected"] + st["shed"]), st
        phases[phase] = {
            "load_factor": load,
            "offered_rps": offered,
            "duration_s": dur,
            "submitted": st["submitted"],
            "served": st["served"],
            "rejected": st["rejected"],
            "shed": st["shed"],
            "replayed_requests": st["replayed_requests"],
            "goodput_rps": st["goodput_rps"],
            "goodput_under_slo": st["goodput_under_slo"],
            "per_class": st["per_class"],
            "launches": st["per_model"]["cgan"]["launches"],
            "pad_fraction": st["per_model"]["cgan"]["pad_fraction"],
        }

    payload = {
        "bench": "slo", "quick": quick, "backend": jax.default_backend(),
        "model": "cgan",
        "requests_per_phase": n_req,
        "requests_multiplier_vs_pr4_trace": mult,
        "class_mix": CLASS_MIX,
        "buckets": list(probe.buckets),
        "bucket_cost_ms": {b: t * 1e3 for b, t in
                           probe.bucket_cost_s.items()},
        "capacity_rps_est": capacity_rps,
        "slo_ms": slo_ms,
        "phases": phases,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
    if print_csv:
        for phase, ph in phases.items():
            inter = ph["per_class"]["interactive"]
            print(f"slo_{phase},{inter['p99_ms'] * 1e3:.1f},"
                  f"load {ph['load_factor']:.1f}x  "
                  f"goodput {ph['goodput_under_slo']:.2f} "
                  f"({ph['served']} served / {ph['rejected']} rejected / "
                  f"{ph['shed']} shed)  interactive "
                  f"p50 {inter['p50_ms']:.1f} p95 {inter['p95_ms']:.1f} "
                  f"p99 {inter['p99_ms']:.1f} ms")
        print(f"# slo capacity {capacity_rps:.0f} req/s, slo "
              f"interactive {slo_ms['interactive']:.1f} ms / batch "
              f"{slo_ms['batch']:.1f} ms"
              + (f" -> {json_path}" if json_path else ""))
    return payload


def unet_main(print_csv=True, quick=False, json_path=UNET_JSON_PATH):
    """Denoising-loop serving (``BENCH_unet.json``): N diffusion chains,
    each ``steps`` *sequential* U-Net calls, driven through the control
    plane.  Every chain hop is its own request (payload = image + a
    timestep plane), so in-flight chains at different steps coalesce into
    shared bucket launches — the sequential-calls-per-request pattern that
    stresses the batcher and admission estimates in a way one-shot
    generation doesn't (a chain's end-to-end latency compounds ``steps``
    queueing delays).  The final image of chain 0 is checked against
    ``models.unet.denoise_loop`` run offline, so the scheduling never
    changes the math."""
    cfg = unet.UNET_TINY
    steps = 2 if quick else 8
    n_req = 16 if quick else 64
    hw, c = cfg.image_hw, cfg.in_c
    dt = 1.0 / steps
    params, _ = unet.unet_init(jax.random.PRNGKey(0), cfg)

    def step_fn(payload):
        """One Euler refinement of a (B, H, W, C+1) batch: image channels
        plus a constant timestep plane, re-emitted with t - dt."""
        x, t = payload[..., :c], payload[:, 0, 0, c]
        x = x - unet.unet_apply(params, x, t, cfg) * dt
        tp = jnp.broadcast_to(
            jnp.maximum(t - dt, 0.0)[:, None, None, None],
            x.shape[:3] + (1,))
        return jnp.concatenate([x, tp], axis=-1)

    cp = ControlPlane()
    proto = np.zeros((hw, hw, c + 1), np.float32)
    be = cp.register_image_model("unet", step_fn, proto,
                                 buckets=(1, 4, 16), max_wait_ms=1.0)
    be.warmup()

    rng = np.random.default_rng(3)
    x0s = rng.standard_normal((n_req, hw, hw, c)).astype(np.float32)
    t_start, t_end, finals = {}, {}, {}
    t0 = time.perf_counter()
    for r in range(n_req):                       # burst: chains start hot
        pay = np.concatenate([x0s[r], np.ones((hw, hw, 1), np.float32)],
                             axis=-1)
        t_start[r] = time.perf_counter()
        cp.submit(ServeRequest(rid=r * steps, model="unet", payload=pay))
    while len(t_end) < n_req:
        finished = cp.pump(drain=True)
        if not finished and not cp.pending():
            raise AssertionError("denoising chains stalled with empty queues")
        for d in finished:
            r, hop = divmod(d.rid, steps)
            if hop + 1 < steps:                  # next hop of the chain
                cp.submit(ServeRequest(rid=r * steps + hop + 1,
                                       model="unet",
                                       payload=np.asarray(d.out)))
            else:
                t_end[r] = time.perf_counter()
                finals[r] = np.asarray(d.out)[..., :c]
    dur = time.perf_counter() - t0

    st = cp.stats()
    assert st["served"] == n_req * steps, st
    want = np.asarray(unet.denoise_loop(params, jnp.asarray(x0s[:1]), cfg,
                                        steps))[0]
    max_dev = float(np.max(np.abs(finals[0] - want)))
    chain_ms = [(t_end[r] - t_start[r]) * 1e3 for r in range(n_req)]
    chain_st = latency_stats([m / 1e3 for m in chain_ms])
    routes = {site: {"kind": kind, "path": path}
              for site, (kind, path) in
              unet.unet_route_summary(cfg).items()}
    ps_sites = sorted(s for s, r in routes.items()
                      if r["path"] == "pixel_shuffle")

    payload = {
        "bench": "unet_denoise", "quick": quick,
        "backend": jax.default_backend(),
        "model": cfg.name, "image_hw": hw, "steps": steps,
        "requests": n_req,
        "hops_submitted": n_req * steps,
        "hops_served": st["served"],
        "buckets": list(be.batcher.buckets),
        "bucket_cost_ms": {b: v * 1e3
                           for b, v in be.batcher.bucket_cost_s.items()},
        "launches": st["per_model"]["unet"]["launches"],
        "pad_fraction": st["per_model"]["unet"]["pad_fraction"],
        "duration_s": dur,
        "throughput_steps_per_s": n_req * steps / dur,
        "chain_p50_ms": chain_st["p50_ms"],
        "chain_p95_ms": chain_st["p95_ms"],
        "routes": routes,
        "pixel_shuffle_sites": ps_sites,
        "max_dev_vs_offline_loop": max_dev,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
    if print_csv:
        print(f"serve_unet,{n_req * steps / dur:.1f},"
              f"{n_req} chains x {steps} steps in {dur:.2f}s  "
              f"chain p50 {chain_st['p50_ms']:.1f} "
              f"p95 {chain_st['p95_ms']:.1f} ms  "
              f"({payload['launches']} launches, pad "
              f"{payload['pad_fraction']:.2f}, sub-pixel sites "
              f"{','.join(ps_sites)}, max dev vs offline loop "
              f"{max_dev:.1e})"
              + (f" -> {json_path}" if json_path else ""))
    return payload


if __name__ == "__main__":
    main()
    slo_main()
    unet_main()
