"""Paper Table 1: deconvolution layer configurations of DCGAN / cGAN, with
the analytic MAC counts of the naive (zero-inserted) engine vs HUGE2
decomposition — the s^2 arithmetic advantage the engine exploits."""
from __future__ import annotations

from repro.core.decompose import plan_phases_1d
from repro.models.gan import CGAN_LAYERS, DCGAN_LAYERS, deconv_padding


def layer_macs(l):
    pad = deconv_padding(l.kernel, l.stride)[0]
    out = l.in_hw * l.stride
    hd = (l.in_hw - 1) * l.stride + 1 + pad[0] + pad[1]
    naive = out * out * l.kernel * l.kernel * l.in_c * l.out_c
    huge = 0
    plans = plan_phases_1d(l.in_hw, l.kernel, l.stride, pad)
    for ph in plans:
        for pw in plans:
            huge += ph.out_size * pw.out_size * ph.taps * pw.taps \
                * l.in_c * l.out_c
    return naive, huge


def main(print_csv=True):
    rows = []
    for gan, layers in (("DCGAN", DCGAN_LAYERS), ("cGAN", CGAN_LAYERS)):
        for i, l in enumerate(layers):
            naive, huge = layer_macs(l)
            rows.append((f"table1_{gan}_DC{i + 1}", 0.0,
                         f"in={l.in_hw}x{l.in_hw}x{l.in_c} "
                         f"k={l.kernel}x{l.kernel}x{l.in_c}x{l.out_c} "
                         f"s={l.stride} naive_MACs={naive} huge_MACs={huge} "
                         f"ratio={naive / huge:.2f}"))
    if print_csv:
        for name, us, d in rows:
            print(f"{name},{us:.1f},{d}")
    return rows


if __name__ == "__main__":
    main()
