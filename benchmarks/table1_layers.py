"""Paper Table 1: deconvolution layer configurations of DCGAN / cGAN.

Per layer this reports
- the analytic MAC counts of the naive (zero-inserted) engine vs the HUGE2
  decomposition — the s^2 arithmetic advantage the engine exploits, and
- measured wall-clock: one-time plan-build + weight-pack cost (``plan_ms``,
  paid at model load) kept strictly separate from the steady-state per-call
  latency of the planned executor (``planned_us``) vs the unplanned path
  (``unplanned_us`` — same executor, but the raw kernel is a call argument
  so the phase re-slicing is traced into every invocation).

The planned forward is asserted against the XLA oracle on every layer.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import csv_row, time_fn
from repro.core import huge_conv_transpose2d
from repro.core import reference as ref
from repro.core.decompose import plan_phases_1d
from repro.core.plan import ConvSpec, plan_conv
from repro.models.gan import CGAN_LAYERS, DCGAN_LAYERS, deconv_padding

BATCH = 1


def layer_macs(l):
    pad = deconv_padding(l.kernel, l.stride)[0]
    out = l.in_hw * l.stride
    naive = out * out * l.kernel * l.kernel * l.in_c * l.out_c
    huge = 0
    plans = plan_phases_1d(l.in_hw, l.kernel, l.stride, pad)
    for ph in plans:
        for pw in plans:
            huge += ph.out_size * pw.out_size * ph.taps * pw.taps \
                * l.in_c * l.out_c
    return naive, huge


def layer_walltime(l):
    """(plan_build_ms, planned_us, unplanned_us) for one Table-1 layer."""
    pad = deconv_padding(l.kernel, l.stride)
    strides = (l.stride, l.stride)
    spec = ConvSpec(kind="transposed", in_hw=(l.in_hw, l.in_hw),
                    in_c=l.in_c, out_c=l.out_c,
                    kernel_hw=(l.kernel, l.kernel), strides=strides,
                    padding=pad)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (BATCH, l.in_hw, l.in_hw, l.in_c), jnp.float32)
    k = jax.random.normal(key, (l.kernel, l.kernel, l.in_c, l.out_c),
                          jnp.float32)

    # model-load cost, measured separately from the per-call numbers
    t0 = time.perf_counter()
    plan = plan_conv(spec)
    packed = jax.block_until_ready(plan.pack(k))
    plan_ms = (time.perf_counter() - t0) * 1e3

    planned = jax.jit(plan.apply)
    unplanned = jax.jit(lambda x, k: huge_conv_transpose2d(
        x, k, strides, pad))
    want = np.asarray(ref.oracle_conv_transpose2d(x, k, strides=strides,
                                                  padding=pad))
    # <= 1e-4 relative to the layer's output scale (fp32 accumulation-order
    # noise on the 25k-term DC1 contractions sits well below this)
    np.testing.assert_allclose(np.asarray(planned(x, packed)), want,
                               rtol=1e-4, atol=1e-4 * np.abs(want).max())
    t_planned = time_fn(planned, x, packed)
    t_unplanned = time_fn(unplanned, x, k)
    return plan_ms, t_planned * 1e6, t_unplanned * 1e6


def main(print_csv=True, walltime=True):
    rows = []
    for gan, layers in (("DCGAN", DCGAN_LAYERS), ("cGAN", CGAN_LAYERS)):
        for i, l in enumerate(layers):
            naive, huge = layer_macs(l)
            derived = (f"in={l.in_hw}x{l.in_hw}x{l.in_c} "
                       f"k={l.kernel}x{l.kernel}x{l.in_c}x{l.out_c} "
                       f"s={l.stride} naive_MACs={naive} huge_MACs={huge} "
                       f"ratio={naive / huge:.2f}")
            us = 0.0
            if walltime:
                plan_ms, planned_us, unplanned_us = layer_walltime(l)
                us = planned_us
                derived += (f" plan_ms={plan_ms:.2f} "
                            f"planned_us={planned_us:.1f} "
                            f"unplanned_us={unplanned_us:.1f} "
                            f"plan_gain={unplanned_us / planned_us:.2f}x")
            rows.append(csv_row(f"table1_{gan}_DC{i + 1}", us, derived))
    if print_csv:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    main()
