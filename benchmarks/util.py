"""Benchmark timing helpers (single-host CPU)."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, iters: int = 10, warmup: int = 3) -> float:
    """Best (min) wall-time in seconds of a jitted callable.

    Min-of-N is the noise-robust latency statistic on shared hosts: every
    source of interference (scheduler preemption, turbo/thermal shifts,
    co-tenant load) only ever adds time, so the minimum is the closest
    observable to the uncontended cost being compared.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))


def csv_row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"


def geomean(vals) -> float:
    """Geometric mean in log space (overflow-robust, shared by the
    JSON-emitting benches)."""
    vals = list(vals)
    return float(np.exp(np.mean(np.log(vals))))
