"""Benchmark timing helpers (single-host CPU).

Percentile/throughput reporting is NOT implemented here: the one shared
implementation lives in ``repro.serving.metrics`` (used by the LM slot
scheduler, the image batcher, and the serve examples alike) and is
re-exported so benches import it from the same place as their timers.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.autotune import Timing, measure_fn  # noqa: F401
from repro.serving.metrics import format_stats, latency_stats  # noqa: F401


def time_fn(fn, *args, iters: int = 10, warmup: int = 3) -> float:
    """Best (min) wall-time in seconds of a jitted callable.

    Min-of-N is the noise-robust latency statistic on shared hosts: every
    source of interference (scheduler preemption, turbo/thermal shifts,
    co-tenant load) only ever adds time, so the minimum is the closest
    observable to the uncontended cost being compared.

    The loop itself (block-until-ready inside the timed region, min +
    median recorded) is ``repro.core.autotune.measure_fn`` — ONE
    implementation shared between bench-time wall-clocks and the
    autotuner's plan-time microbenchmarks; use ``time_stats`` when the
    median is wanted alongside the min.
    """
    return measure_fn(fn, *args, iters=iters, warmup=warmup).min_s


def time_stats(fn, *args, iters: int = 10, warmup: int = 3) -> Timing:
    """Full ``Timing`` (min + median) from the shared measurement loop."""
    return measure_fn(fn, *args, iters=iters, warmup=warmup)


def csv_row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"


def geomean(vals) -> float:
    """Geometric mean in log space (overflow-robust, shared by the
    JSON-emitting benches)."""
    vals = list(vals)
    return float(np.exp(np.mean(np.log(vals))))


def pallas_tiled_record(plan_pallas, apply_fn=None, args=(),
                        iters: int = 5, warmup: int = 2) -> dict:
    """The shared ``pallas_tiled`` bench column: what the site's
    backend='pallas' plan routes to at the benched batch (taken from
    ``args`` so the verdict always describes the same launch any timing
    measures; B=1 when no inputs are given).

    ``tiled`` is True when the route is the spatially tiled kernel
    (``sp_tiles`` set) — i.e. a geometry the whole-plane verdict used to
    bounce off the Pallas route.  ``pallas_us`` is wall-clock **only on a
    real TPU backend**; on CPU hosts Pallas runs in interpret mode, whose
    timing says nothing about the kernel, so the column records the route
    verdict and leaves ``pallas_us`` null (docs/BENCHMARKS.md spells this
    out)."""
    batch = int(args[0].shape[0]) if args else 1
    route = plan_pallas.route_for_batch(batch)
    rec = {
        "path": route.path,
        "tiles": list(route.tiles) if route.tiles else None,
        "sp_tiles": list(route.sp_tiles) if route.sp_tiles else None,
        "tiled": route.sp_tiles is not None,
        "pallas_us": None,
    }
    if apply_fn is not None and jax.default_backend() == "tpu":
        rec["pallas_us"] = time_fn(jax.jit(apply_fn), *args, iters=iters,
                                   warmup=warmup) * 1e6
    return rec
