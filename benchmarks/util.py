"""Benchmark timing helpers (single-host CPU).

Percentile/throughput reporting is NOT implemented here: the one shared
implementation lives in ``repro.serving.metrics`` (used by the LM slot
scheduler, the image batcher, and the serve examples alike) and is
re-exported so benches import it from the same place as their timers.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.serving.metrics import format_stats, latency_stats  # noqa: F401


def time_fn(fn, *args, iters: int = 10, warmup: int = 3) -> float:
    """Best (min) wall-time in seconds of a jitted callable.

    Min-of-N is the noise-robust latency statistic on shared hosts: every
    source of interference (scheduler preemption, turbo/thermal shifts,
    co-tenant load) only ever adds time, so the minimum is the closest
    observable to the uncontended cost being compared.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))


def csv_row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"


def geomean(vals) -> float:
    """Geometric mean in log space (overflow-robust, shared by the
    JSON-emitting benches)."""
    vals = list(vals)
    return float(np.exp(np.mean(np.log(vals))))
