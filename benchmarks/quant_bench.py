"""Quantized superpacks bench: int8 weight bytes, route verdicts, parity.

Per site, an f32 plan and its int8 twin (``ConvSpec.wdtype='int8'``) are
built from the SAME HWIO kernel and compared on three axes:

- **bytes** — ``plan.pack(kernel)`` superpack footprint.  The int8
  superpack stores 1-byte codes plus one f32 scale per tap-row, so the
  ratio is ~(0.25 + 1/N); the bench *gates* ratio <= 0.5 on every site
  (pure layout arithmetic, identical on all hosts).
- **routes** — the plan-time Pallas verdict per batch bucket.  1-byte
  weight tiles shrink the VMEM working set, so some geometries earn a
  bigger c-tile or a bigger spatial tile (``route_improved``); the bench
  gates that at least one covered geometry actually flips (otherwise the
  quantized VMEM accounting is dead code).  Plan-time arithmetic only —
  host-independent, and the big-plane sites never execute here.
- **parity + wall-clock** — on the small (executable) sites, forward
  outputs vs the f32 twin under the per-site quantization bound
  (rel L-inf <= 2/127: one layer, at most ~half an int8 grid step of
  relative weight error with measured ~2x headroom), and min-of-N
  wall-clock of both plans.  ``int8_vs_f32`` is *recorded, not gated*:
  on a CPU host the dequant-on-the-fly XLA route adds a convert before
  the one GEMM, so the ratio hovers near (or below) 1.0 — the win this
  bench pins is bytes + route verdicts, not CPU wall-clock (see
  docs/BENCHMARKS.md).

Emits ``BENCH_quant.json``.  Run standalone:

    PYTHONPATH=src python -m benchmarks.quant_bench
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp

from benchmarks.util import csv_row, time_fn
from repro.core.autotune import route_label
from repro.core.plan import BATCH_BUCKETS, ConvSpec, plan_conv
from repro.models.gan import deconv_padding
from repro.models.segnet import atrous_padding

JSON_PATH = "BENCH_quant.json"

# per-site parity gate: one conv layer quantizes each weight to within
# half an int8 grid step of its tap-row max, giving ~0.5/127 relative
# weight error; 2/127 leaves ~2x headroom over the measured rel L-inf
REL_BOUND = 2.0 / 127.0

# (name, spec, execute): execute=False sites are plan/bytes-only — the
# 385px context plane is ~240 GFLOP/call, far too slow for a CPU smoke,
# and its value here is the sp_tiles verdict, which is plan arithmetic
SITES = (
    # model-zoo scale: parity + wall-clock on every executable kind
    ("segnet_ctx16_c128_d2", ConvSpec(
        kind="dilated", in_hw=(16, 16), in_c=128, out_c=128,
        kernel_hw=(3, 3), padding=atrous_padding(3, 2),
        dilation=(2, 2)), True),
    ("dcgan_dec8_c256n128k4", ConvSpec(
        kind="transposed", in_hw=(8, 8), in_c=256, out_c=128,
        kernel_hw=(4, 4), strides=(2, 2),
        padding=deconv_padding(4, 2)), True),
    ("vaeenc_conv32_c64n128k3s2", ConvSpec(
        kind="conv", in_hw=(32, 32), in_c=64, out_c=128,
        kernel_hw=(3, 3), strides=(2, 2),
        padding=((1, 1), (1, 1))), True),
    # route-flip geometries: the 1-byte weight tiles provably move the
    # Pallas verdict (grid-searched; the first is also pinned in
    # tests/fixtures/route_table.json as quantflip_ctx385_c64n256k7)
    ("quantflip_ctx385_c64n256k7", ConvSpec(
        kind="conv", in_hw=(385, 385), in_c=64, out_c=256,
        kernel_hw=(7, 7), padding=((3, 3), (3, 3))), False),
    ("quantflip_conv64_c128n256k5", ConvSpec(
        kind="conv", in_hw=(64, 64), in_c=128, out_c=256,
        kernel_hw=(5, 5), padding=((2, 2), (2, 2))), False),
    ("quantflip_tr32_c256n256k4", ConvSpec(
        kind="transposed", in_hw=(32, 32), in_c=256, out_c=256,
        kernel_hw=(4, 4), strides=(2, 2),
        padding=deconv_padding(4, 2)), False),
)


def _route_records(spec: ConvSpec):
    """Per-bucket pallas-backend verdicts, f32 vs int8 (plan-time only)."""
    import dataclasses
    pf = plan_conv(dataclasses.replace(spec, backend="pallas"))
    pq = plan_conv(dataclasses.replace(spec, backend="pallas",
                                       wdtype="int8"))
    recs = []
    for b in BATCH_BUCKETS:
        rf, rq = pf.route_for_batch(b), pq.route_for_batch(b)
        recs.append({"batch": b, "f32": route_label(rf),
                     "int8": route_label(rq),
                     "flipped": route_label(rf) != route_label(rq)})
    return recs


def bench_site(name, spec, execute, iters=5, warmup=2):
    import dataclasses
    r, s = spec.kernel_hw
    key = jax.random.PRNGKey(spec.in_hw[0] * 31 + spec.in_c)
    kern = jax.random.normal(
        key, (r, s, spec.in_c, spec.out_c), jnp.float32) * 0.1

    pf = plan_conv(spec)
    pq = plan_conv(dataclasses.replace(spec, wdtype="int8"))
    wf, wq = pf.pack(kern), pq.pack(kern)
    f32_bytes = int(wf.nbytes)
    int8_bytes = int(wq.nbytes())
    rec = {
        "name": name, "kind": spec.kind, "in_hw": spec.in_hw[0],
        "in_c": spec.in_c, "out_c": spec.out_c, "kernel": r,
        "f32_bytes": f32_bytes, "int8_bytes": int8_bytes,
        "bytes_ratio": int8_bytes / f32_bytes,
        "routes": _route_records(spec),
    }
    rec["route_improved"] = any(rr["flipped"] for rr in rec["routes"])
    if execute:
        x = jax.random.normal(key, (4, *spec.in_hw, spec.in_c), jnp.float32)
        ff = jax.jit(pf.apply)
        fq = jax.jit(pq.apply)
        yf = jax.block_until_ready(ff(x, wf))
        yq = jax.block_until_ready(fq(x, wq))
        rel = float(jnp.max(jnp.abs(yq - yf)) / jnp.max(jnp.abs(yf)))
        rec["rel_err_vs_f32"] = rel
        assert rel <= REL_BOUND, (name, rel, REL_BOUND)
        rec["f32_us"] = time_fn(ff, x, wf, iters=iters, warmup=warmup) * 1e6
        rec["int8_us"] = time_fn(fq, x, wq, iters=iters, warmup=warmup) * 1e6
        rec["int8_vs_f32"] = rec["f32_us"] / rec["int8_us"]
    assert rec["bytes_ratio"] <= 0.5, (name, rec["bytes_ratio"])
    return rec


def main(print_csv=True, quick=False, json_path=JSON_PATH):
    iters, warmup = (3, 1) if quick else (5, 2)
    records, rows = [], []
    for name, spec, execute in SITES:
        rec = bench_site(name, spec, execute, iters=iters, warmup=warmup)
        records.append(rec)
        flips = [f"B{rr['batch']}:{rr['f32']}->{rr['int8']}"
                 for rr in rec["routes"] if rr["flipped"]]
        derived = (f"bytes_ratio={rec['bytes_ratio']:.2f} "
                   + (f"rel_err={rec['rel_err_vs_f32']:.1e} "
                      f"int8_vs_f32={rec['int8_vs_f32']:.2f}x "
                      if execute else "plan-only ")
                   + (f"flips={';'.join(flips)}" if flips else "no-flip"))
        rows.append(csv_row(name, rec.get("int8_us", 0.0), derived))

    improved = [r["name"] for r in records if r["route_improved"]]
    worst_ratio = max(r["bytes_ratio"] for r in records)
    worst_rel = max(r["rel_err_vs_f32"] for r in records
                    if "rel_err_vs_f32" in r)
    assert improved, "no covered geometry's Route verdict improved"
    payload = {
        "schema": "huge2-bench-quant/v1",
        "bench": "quant", "quick": quick,
        "backend": jax.default_backend(),
        "rel_bound": REL_BOUND,
        "sites": records,
        "bytes_ratio_worst": worst_ratio,
        "rel_err_worst": worst_rel,
        "routes_improved": improved,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
    if print_csv:
        for row in rows:
            print(row)
        print(f"# bytes_ratio_worst={worst_ratio:.2f} "
              f"rel_err_worst={worst_rel:.1e} (bound {REL_BOUND:.1e}) "
              f"routes_improved={improved}"
              + (f" -> {json_path}" if json_path else ""))
    return payload


if __name__ == "__main__":
    main()
