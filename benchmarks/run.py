"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes machine-readable
``BENCH_fig7.json`` (per-layer planned/naive/per-phase µs + the
fused-vs-per-phase speedup of the single-launch executor),
``BENCH_dilated.json`` (segmentation block suite: untangled vs the
rhs-dilation baseline engine + the lax oracle), ``BENCH_serve.json``
(dynamic image batcher vs the fixed-batch serve loop), and
``BENCH_slo.json`` (open-loop Poisson load through the SLO-aware control
plane: per-class tail latency + goodput-under-SLO), and
``BENCH_spatial.json`` (plane-parallel shard_map halo-exchange executor vs
single-device on the 385x385 dilated-context and transposed-decoder
geometries — run in a forced-8-device child process), and
``BENCH_quant.json`` (int8 quantized superpacks vs their f32 twins: weight
bytes, per-bucket route verdicts, forward parity), and ``BENCH_unet.json``
(diffusion U-Net denoising chains — many *sequential* decoder calls per
request — driven through the control plane, plus the sub-pixel route
verdicts per site) so the perf trajectory is tracked run over run.  See
``docs/BENCHMARKS.md`` for what every field means.  Run:

    PYTHONPATH=src python -m benchmarks.run [--quick] [--json PATH]
                                           [--dilated-json PATH]
                                           [--serve-json PATH]
                                           [--slo-json PATH]
                                           [--spatial-json PATH]
                                           [--quant-json PATH]
                                           [--unet-json PATH]

``--quick`` keeps the oracle-checked Fig.-7, dilated, and serving
wall-clocks (with short timing loops and 10x instead of 100x open-loop
traffic) so CI smoke still produces every JSON, and skips the remaining
slow benches.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="short timing loops; skip the slowest benches")
    ap.add_argument("--json", default="BENCH_fig7.json",
                    help="where to write the fig7 JSON ('' disables)")
    ap.add_argument("--dilated-json", default="BENCH_dilated.json",
                    help="where to write the dilated JSON ('' disables)")
    ap.add_argument("--serve-json", default="BENCH_serve.json",
                    help="where to write the serving JSON ('' disables)")
    ap.add_argument("--slo-json", default="BENCH_slo.json",
                    help="where to write the open-loop SLO JSON "
                         "('' disables)")
    ap.add_argument("--spatial-json", default="BENCH_spatial.json",
                    help="where to write the plane-parallel JSON "
                         "('' disables)")
    ap.add_argument("--quant-json", default="BENCH_quant.json",
                    help="where to write the quantized-superpack JSON "
                         "('' disables)")
    ap.add_argument("--unet-json", default="BENCH_unet.json",
                    help="where to write the U-Net denoising-chain JSON "
                         "('' disables)")
    args = ap.parse_args()

    from benchmarks import (dilated_conv, fig7_speedup, fig8_memory,
                            serve_bench, table1_layers)
    print("# paper Table 1 — layer configs + MAC reduction")
    table1_layers.main(walltime=not args.quick)
    print("# paper Fig 8 (left) — memory-access reduction (plan-derived bytes)")
    fig8_memory.main()
    print("# paper Fig 7 — inference speedup vs naive engine (CPU wall-clock)")
    fig7_speedup.main(quick=args.quick, json_path=args.json or None)
    print("# paper §3.2.2 — dilated (atrous) conv, segmentation block suite")
    dilated_conv.main(quick=args.quick,
                      json_path=args.dilated_json or None)
    print("# serving — dynamic image batcher vs fixed-batch loop")
    serve_bench.main(quick=args.quick, json_path=args.serve_json or None)
    print("# serving — open-loop SLO/tail-latency harness (control plane)")
    serve_bench.slo_main(quick=args.quick, json_path=args.slo_json or None)
    print("# serving — U-Net denoising chains (sequential hops, "
          "sub-pixel routes)")
    serve_bench.unet_main(quick=args.quick, json_path=args.unet_json or None)
    if args.spatial_json:
        from benchmarks import spatial_bench
        print("# plane-parallel — shard_map halo exchange vs single device")
        spatial_bench.main(quick=args.quick, json_path=args.spatial_json)
    if args.quant_json:
        from benchmarks import quant_bench
        print("# quantized superpacks — int8 bytes / routes / parity "
              "vs f32 twins")
        quant_bench.main(quick=args.quick, json_path=args.quant_json)
    if not args.quick:
        from benchmarks import fig8_training
        print("# paper Fig 8 (right) — GAN training speedup (engine VJPs)")
        fig8_training.main()


if __name__ == "__main__":
    main()
