"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Run:
    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slow wall-clock benches")
    args = ap.parse_args()

    from benchmarks import table1_layers, fig8_memory
    print("# paper Table 1 — layer configs + MAC reduction")
    table1_layers.main()
    print("# paper Fig 8 (left) — memory-access reduction (analytic bytes)")
    fig8_memory.main()
    if not args.quick:
        from benchmarks import dilated_conv, fig7_speedup, fig8_training
        print("# paper Fig 7 — inference speedup vs naive engine (CPU wall-clock)")
        fig7_speedup.main()
        print("# paper Fig 8 (right) — GAN training speedup (engine VJPs)")
        fig8_training.main()
        print("# paper §3.2.2 — dilated (atrous) conv, untangled vs naive")
        dilated_conv.main()


if __name__ == "__main__":
    main()
