"""Paper Fig. 7: inference speedup of HUGE2 (decomposition + untangling)
over the DarkNet-style naive engine (zero-insertion + im2col GEMM), per
DCGAN / cGAN deconvolution layer.  Wall-clock on this host's CPU — the same
comparison the paper ran on the Jetson CPU (batch=1 edge inference)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.util import csv_row, time_fn
from repro.core import huge_conv_transpose2d
from repro.core import reference as ref
from repro.models.gan import CGAN_LAYERS, DCGAN_LAYERS, deconv_padding

BATCH = 1


def bench_layer(l, backend="xla"):
    """Both engines get offline weight prep (the paper's engine decomposes
    kernels at model load; DarkNet reshapes to the GEMM layout at load)."""
    from repro.core.engine import (huge_conv_transpose2d_pre,
                                   precompute_transposed_weights)
    pad = deconv_padding(l.kernel, l.stride)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (BATCH, l.in_hw, l.in_hw, l.in_c), jnp.float32)
    k = jax.random.normal(key, (l.kernel, l.kernel, l.in_c, l.out_c),
                          jnp.float32)
    strides = (l.stride, l.stride)
    khw = (l.kernel, l.kernel)

    w_flat = k.reshape(l.kernel * l.kernel * l.in_c, l.out_c)   # offline
    subs = precompute_transposed_weights(k, strides, pad)        # offline

    naive = jax.jit(functools.partial(ref.naive_conv_transpose2d_pre,
                                      kernel_hw=khw, strides=strides,
                                      padding=pad))
    huge = jax.jit(functools.partial(huge_conv_transpose2d_pre,
                                     kernel_hw=khw, strides=strides,
                                     padding=pad))
    # correctness guard: both paths match the XLA oracle
    import numpy as np
    want = ref.oracle_conv_transpose2d(x, k, strides=strides, padding=pad)
    np.testing.assert_allclose(np.asarray(huge(x, subs)), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(naive(x, w_flat)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)
    t_naive = time_fn(naive, x, w_flat)
    t_huge = time_fn(huge, x, subs)
    return t_naive, t_huge


def main(print_csv=True):
    rows = []
    for gan, layers in (("DCGAN", DCGAN_LAYERS), ("cGAN", CGAN_LAYERS)):
        for i, l in enumerate(layers):
            tn, th = bench_layer(l)
            rows.append(csv_row(f"fig7_{gan}_DC{i + 1}", th * 1e6,
                                f"naive_us={tn * 1e6:.1f} "
                                f"speedup={tn / th:.2f}x"))
    if print_csv:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    main()
