"""Paper Fig. 7: inference speedup of HUGE2 (decomposition + untangling)
over the DarkNet-style naive engine (zero-insertion + im2col GEMM), per
DCGAN / cGAN / VAE-decoder deconvolution layer.  Wall-clock on this host's
CPU — the same comparison the paper ran on the Jetson CPU (batch=1 edge
inference).

Engines measured per layer:

- ``naive_us``     — DarkNet pipeline with load-time weight reshape.
- ``planned_us``   — the fused single-launch executor (``plan.apply`` on the
  superpacked weights: one wide GEMM / one Pallas launch per conv site).
- ``per_phase_us`` — the PR-1 per-phase planned executor (one pad + GEMM
  chain per phase, stack/transpose interleave) on the same superpack; the
  ``fused_vs_per_phase`` column is the speedup of fusing all phases into
  one pass over one input residency.
- ``unplanned_us`` — the planned executor with the raw kernel as a call
  argument (re-packing traced into every call) — the load-time-vs-call-time
  gap the plan/executor refactor removes.
- ``autotuned_us`` — the same site planned with a measure-mode
  ``AutotunePolicy`` (memory-only cache, benched bucket only): the route is
  whatever the microbenchmarks crowned, which may differ from the heuristic
  pick (``route_flipped``); ``autotune_vs_heuristic`` is the measured
  speedup of the tuned route over the heuristic one.

``main`` also emits machine-readable ``BENCH_fig7.json`` so CI tracks the
perf trajectory; ``quick=True`` shrinks the timing loop for smoke runs.
"""
from __future__ import annotations

import functools
import json

import jax
import jax.numpy as jnp

from benchmarks.util import (csv_row, geomean as geo_mean,
                             pallas_tiled_record, time_fn)
from repro.core import huge_conv_transpose2d
from repro.core import reference as ref
from repro.core.autotune import AutotunePolicy
from repro.core.plan import ConvSpec, plan_conv
from repro.models.gan import CGAN_LAYERS, DCGAN_LAYERS, deconv_padding
from repro.models.vae import VAE

BATCH = 1
JSON_PATH = "BENCH_fig7.json"


def bench_layer(l, backend="xla", iters=10, warmup=3):
    pad = deconv_padding(l.kernel, l.stride)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (BATCH, l.in_hw, l.in_hw, l.in_c), jnp.float32)
    k = jax.random.normal(key, (l.kernel, l.kernel, l.in_c, l.out_c),
                          jnp.float32)
    strides = (l.stride, l.stride)
    khw = (l.kernel, l.kernel)

    spec = ConvSpec(
        kind="transposed", in_hw=(l.in_hw, l.in_hw), in_c=l.in_c,
        out_c=l.out_c, kernel_hw=khw, strides=strides, padding=pad,
        backend=backend)
    plan = plan_conv(spec)                                       # offline
    packed = jax.block_until_ready(plan.pack(k))                 # offline
    # autotuned column: same spec, routes measured (memory-only cache so
    # the bench never reads a stale per-host file; benched bucket only)
    plan_at = plan_conv(spec, autotune=AutotunePolicy(
        mode="measure", cache_path="", buckets=(BATCH,),
        iters=iters, warmup=warmup))
    w_flat = k.reshape(l.kernel * l.kernel * l.in_c, l.out_c)    # offline
    # the pallas_tiled column: the same site planned under backend='pallas'
    # (whole-plane or spatially tiled route; timed on TPU hosts only)
    plan_p = plan_conv(ConvSpec(
        kind="transposed", in_hw=(l.in_hw, l.in_hw), in_c=l.in_c,
        out_c=l.out_c, kernel_hw=khw, strides=strides, padding=pad,
        backend="pallas"))

    naive = jax.jit(functools.partial(ref.naive_conv_transpose2d_pre,
                                      kernel_hw=khw, strides=strides,
                                      padding=pad))
    planned = jax.jit(plan.apply)
    autotuned = jax.jit(plan_at.apply)
    per_phase = jax.jit(plan.apply_per_phase)
    unplanned = jax.jit(functools.partial(huge_conv_transpose2d,
                                          strides=strides, padding=pad))
    # correctness guard: every path matches the XLA oracle
    import numpy as np
    want = ref.oracle_conv_transpose2d(x, k, strides=strides, padding=pad)
    np.testing.assert_allclose(np.asarray(planned(x, packed)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(per_phase(x, packed)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(naive(x, w_flat)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(unplanned(x, k)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(autotuned(x, packed)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)
    return {
        "path": plan.path,
        "autotuned_path": plan_at.route_for_batch(BATCH).path,
        "route_flipped": (plan_at.route_for_batch(BATCH)
                          != plan.route_for_batch(BATCH)),
        "autotuned_us": time_fn(autotuned, x, packed, iters=iters,
                                warmup=warmup) * 1e6,
        "pallas_tiled": pallas_tiled_record(
            plan_p, apply_fn=plan_p.apply, args=(x, packed),
            iters=iters, warmup=warmup),
        "naive_us": time_fn(naive, x, w_flat, iters=iters, warmup=warmup) * 1e6,
        "planned_us": time_fn(planned, x, packed, iters=iters,
                              warmup=warmup) * 1e6,
        "per_phase_us": time_fn(per_phase, x, packed, iters=iters,
                                warmup=warmup) * 1e6,
        "unplanned_us": time_fn(unplanned, x, k, iters=iters,
                                warmup=warmup) * 1e6,
    }


def main(print_csv=True, quick=False, json_path=JSON_PATH):
    iters, warmup = (3, 1) if quick else (10, 3)
    rows, records = [], []
    # the VAE decoder is the paper's other upsampling-bound workload: its
    # transposed stages ride the same bench (abstract: GANs *and* VAEs)
    for gan, layers in (("DCGAN", DCGAN_LAYERS), ("cGAN", CGAN_LAYERS),
                        ("VAE", VAE.decoder_layers)):
        for i, l in enumerate(layers):
            t = bench_layer(l, iters=iters, warmup=warmup)
            rec = dict(name=f"fig7_{gan}_DC{i + 1}", gan=gan, layer=i + 1,
                       in_hw=l.in_hw, in_c=l.in_c, out_c=l.out_c,
                       kernel=l.kernel, stride=l.stride, **t)
            rec["speedup_vs_naive"] = t["naive_us"] / t["planned_us"]
            rec["fused_vs_per_phase"] = t["per_phase_us"] / t["planned_us"]
            rec["plan_gain"] = t["unplanned_us"] / t["planned_us"]
            rec["autotune_vs_heuristic"] = (t["planned_us"]
                                           / t["autotuned_us"])
            records.append(rec)
            pt = t["pallas_tiled"]
            rows.append(csv_row(
                rec["name"], t["planned_us"],
                f"naive_us={t['naive_us']:.1f} "
                f"speedup={rec['speedup_vs_naive']:.2f}x "
                f"per_phase_us={t['per_phase_us']:.1f} "
                f"fused_vs_per_phase={rec['fused_vs_per_phase']:.2f}x "
                f"path={t['path']} "
                f"pallas_tiled={pt['path']}"
                + (f"@sp{tuple(pt['sp_tiles'])}" if pt["tiled"] else "")
                + " "
                f"unplanned_us={t['unplanned_us']:.1f} "
                f"plan_gain={rec['plan_gain']:.2f}x "
                f"autotuned={t['autotuned_path']}"
                + ("*" if t["route_flipped"] else "")
                + f"@{rec['autotune_vs_heuristic']:.2f}x"))
    dc = [r["fused_vs_per_phase"] for r in records if r["gan"] == "DCGAN"]
    geomean = geo_mean(dc)
    geomean_at = geo_mean([r["autotune_vs_heuristic"] for r in records])
    flipped = [r["name"] for r in records if r["route_flipped"]]
    payload = {
        "bench": "fig7", "batch": BATCH, "quick": quick,
        "backend": jax.default_backend(),
        "layers": records,
        "dcgan_geomean_fused_vs_per_phase": geomean,
        "geomean_autotuned_vs_heuristic": geomean_at,
        "routes_flipped": flipped,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
    if print_csv:
        for r in rows:
            print(r)
        print(f"# dcgan_geomean_fused_vs_per_phase={geomean:.2f}x "
              f"geomean_autotuned_vs_heuristic={geomean_at:.2f}x "
              f"routes_flipped={flipped}"
              + (f" -> {json_path}" if json_path else ""))
    return payload


if __name__ == "__main__":
    main()
