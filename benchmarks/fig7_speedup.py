"""Paper Fig. 7: inference speedup of HUGE2 (decomposition + untangling)
over the DarkNet-style naive engine (zero-insertion + im2col GEMM), per
DCGAN / cGAN deconvolution layer.  Wall-clock on this host's CPU — the same
comparison the paper ran on the Jetson CPU (batch=1 edge inference).

Both engines get their offline weight prep (the planned engine packs
kernels at model load; DarkNet reshapes to the GEMM layout at load).  The
``unplanned_us`` column times the same planned executor but with the raw
kernel as a call argument, i.e. re-packing traced into every call — the
load-time-vs-call-time gap the plan/executor refactor removes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.util import csv_row, time_fn
from repro.core import huge_conv_transpose2d
from repro.core import reference as ref
from repro.core.plan import ConvSpec, plan_conv
from repro.models.gan import CGAN_LAYERS, DCGAN_LAYERS, deconv_padding

BATCH = 1


def bench_layer(l, backend="xla"):
    pad = deconv_padding(l.kernel, l.stride)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (BATCH, l.in_hw, l.in_hw, l.in_c), jnp.float32)
    k = jax.random.normal(key, (l.kernel, l.kernel, l.in_c, l.out_c),
                          jnp.float32)
    strides = (l.stride, l.stride)
    khw = (l.kernel, l.kernel)

    plan = plan_conv(ConvSpec(                                   # offline
        kind="transposed", in_hw=(l.in_hw, l.in_hw), in_c=l.in_c,
        out_c=l.out_c, kernel_hw=khw, strides=strides, padding=pad,
        backend=backend))
    packed = jax.block_until_ready(plan.pack(k))                 # offline
    w_flat = k.reshape(l.kernel * l.kernel * l.in_c, l.out_c)    # offline

    naive = jax.jit(functools.partial(ref.naive_conv_transpose2d_pre,
                                      kernel_hw=khw, strides=strides,
                                      padding=pad))
    planned = jax.jit(plan.apply)
    unplanned = jax.jit(functools.partial(huge_conv_transpose2d,
                                          strides=strides, padding=pad))
    # correctness guard: every path matches the XLA oracle
    import numpy as np
    want = ref.oracle_conv_transpose2d(x, k, strides=strides, padding=pad)
    np.testing.assert_allclose(np.asarray(planned(x, packed)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(naive(x, w_flat)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(unplanned(x, k)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)
    t_naive = time_fn(naive, x, w_flat)
    t_huge = time_fn(planned, x, packed)
    t_unplanned = time_fn(unplanned, x, k)
    return t_naive, t_huge, t_unplanned


def main(print_csv=True):
    rows = []
    for gan, layers in (("DCGAN", DCGAN_LAYERS), ("cGAN", CGAN_LAYERS)):
        for i, l in enumerate(layers):
            tn, th, tu = bench_layer(l)
            rows.append(csv_row(f"fig7_{gan}_DC{i + 1}", th * 1e6,
                                f"naive_us={tn * 1e6:.1f} "
                                f"speedup={tn / th:.2f}x "
                                f"unplanned_us={tu * 1e6:.1f} "
                                f"plan_gain={tu / th:.2f}x"))
    if print_csv:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    main()
