"""Paper Fig. 8 (right): GAN training speedup.

Times one optimization step of the DCGAN generator+discriminator pair with
(a) the HUGE2 engine — custom VJPs implementing the paper's §3.2.3
dilated/strided-conv backward formulation — vs (b) the naive engine
(autodiff through zero-insertion + im2col).  Covers both cases the paper
measures: dilated derivative-maps convolving inputs (dK) and derivative maps
stridedly convolving inputs (dx)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.util import csv_row, time_fn
from repro.core import reference as ref
from repro.models import gan

BATCH = 4


def naive_generator_apply(p, z, cfg):
    """Naive engine on *undecomposed* (R,S,C,N) kernels — feed it
    ``gan.generator_unpack``-ed params."""
    l0 = cfg.layers[0]
    x = (z @ p["proj"]).reshape(z.shape[0], l0.in_hw, l0.in_hw, l0.in_c)
    x = jax.nn.relu(x)
    for i, l in enumerate(cfg.layers):
        pad = gan.deconv_padding(l.kernel, l.stride)
        x = ref.naive_conv_transpose2d(x, p[f"dc{i}"],
                                       strides=(l.stride, l.stride),
                                       padding=pad)
        x = x + p[f"b{i}"]
        x = jnp.tanh(x) if i == len(cfg.layers) - 1 else jax.nn.relu(x)
    return x


def main(print_csv=True):
    rows = []
    # use the cGAN stack (smaller) plus the first two DCGAN layers: the
    # paper's "several typical layers"
    for name, cfg in (("cGAN", gan.CGAN),
                      ("DCGAN_head", gan.GANConfig(
                          "dcgan_head", gan.DCGAN_LAYERS[2:], z_dim=100))):
        key = jax.random.PRNGKey(0)
        gp, _ = gan.generator_init(key, cfg)          # packed (planned) params
        gp_raw = gan.generator_unpack(gp, cfg)        # full kernels for naive
        z = jax.random.normal(key, (BATCH, cfg.z_dim), jnp.float32)

        def loss_huge(gp, z):
            return jnp.mean(jnp.square(gan.generator_apply(gp, z, cfg)))

        def loss_naive(gp, z):
            return jnp.mean(jnp.square(naive_generator_apply(gp, z, cfg)))

        g_huge = jax.jit(jax.grad(loss_huge))
        g_naive = jax.jit(jax.grad(loss_naive))
        th = time_fn(g_huge, gp, z, iters=5)
        tn = time_fn(g_naive, gp_raw, z, iters=5)
        rows.append(csv_row(f"fig8_train_{name}", th * 1e6,
                            f"naive_us={tn * 1e6:.1f} "
                            f"speedup={tn / th:.2f}x"))
    if print_csv:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    main()
