"""Training through the Pallas backend: VJPs match the XLA oracle."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import huge_conv_transpose2d
from repro.core import reference as ref


def test_conv_transpose_vjp_pallas_backend():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    x = jax.random.normal(k1, (2, 5, 5, 8), jnp.float32)
    k = jax.random.normal(k2, (5, 5, 8, 4), jnp.float32)
    pads = ((2, 3), (2, 3))

    def f_pl(x, k):
        return huge_conv_transpose2d(x, k, (2, 2), pads, "pallas")

    def f_ora(x, k):
        return ref.oracle_conv_transpose2d(x, k, strides=(2, 2), padding=pads)

    y, vjp_p = jax.vjp(f_pl, x, k)
    y2, vjp_o = jax.vjp(f_ora, x, k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    dy = jax.random.normal(k3, y.shape)
    for a, b in zip(vjp_p(dy), vjp_o(dy)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)
