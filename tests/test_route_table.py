"""Golden route-table regression: every model-zoo conv site's per-bucket
execution route is pinned to ``tests/fixtures/route_table.json``.

A route is the engine's whole performance story for a site (Pallas vs XLA,
whole-plane vs spatially tiled, fused vs per-tap backward) — this test
turns any change to it into an **explicit fixture diff** instead of a
silent perf cliff.  After an intentional routing change::

    PYTHONPATH=src python tools/gen_route_table.py

and commit the regenerated fixture; the diff *is* the review artifact.
"""
import json
import pathlib

from tools.gen_route_table import FIXTURE, build_route_table


def _fmt(entry):
    routes = ", ".join(
        f"B{r['batch']}:{r['path']}"
        + (f"@sp{tuple(r['sp_tiles'])}" if r["sp_tiles"] else "")
        for r in entry["routes"])
    return f"{entry['name']}[{entry['backend']}] -> {routes}"


def test_route_table_matches_fixture():
    assert FIXTURE.exists(), \
        "fixture missing — run PYTHONPATH=src python tools/gen_route_table.py"
    want = json.loads(pathlib.Path(FIXTURE).read_text())
    got = build_route_table()
    if got == want:
        return
    want_by_key = {(e["name"], e["backend"]): e for e in want["entries"]}
    got_by_key = {(e["name"], e["backend"]): e for e in got["entries"]}
    lines = []
    for key in sorted(set(want_by_key) | set(got_by_key)):
        w, g = want_by_key.get(key), got_by_key.get(key)
        if w == g:
            continue
        lines.append(f"  was: {_fmt(w) if w else '<absent>'}")
        lines.append(f"  now: {_fmt(g) if g else '<absent>'}")
    raise AssertionError(
        "route table drifted from the golden fixture — if intentional, "
        "regenerate with `PYTHONPATH=src python tools/gen_route_table.py` "
        "and commit the diff:\n" + "\n".join(lines))


def test_fixture_records_the_reclaimed_geometry():
    """The acceptance-criterion geometry is pinned in the fixture: the
    385x385 atrous layer routes 'taps' on the XLA backend (what HEAD's
    pallas verdict also fell back to) and 'pallas' with spatial tiles on
    the Pallas backend, at every bucket including B=64."""
    table = json.loads(pathlib.Path(FIXTURE).read_text())
    by_key = {(e["name"], e["backend"]): e for e in table["entries"]}
    name = "dilated_bench_L9_385x385x32_d2"
    xla = by_key[(name, "xla")]
    pallas = by_key[(name, "pallas")]
    assert all(r["path"] == "taps" for r in xla["routes"])
    assert all(r["path"] == "pallas" and r["sp_tiles"]
               for r in pallas["routes"])
