"""Unified single-correlation executor ('conv' / 'dilated' kinds): one
Pallas launch / one wide GEMM per conv site on the (R·S·C, N) tap superpack,
parity with the XLA oracle, and the custom VJP on the packed layout across
odd dilations, asymmetric padding, and dilation >= kernel extent.
No hypothesis dependency — this file must run everywhere tier-1 runs.
Shared helpers (oracles, assertions, jaxpr counting, plan builders) live in
``tests/conftest.py``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import reference as ref
from repro.core.plan import ConvSpec, conv_spec, plan_conv

from tests.conftest import assert_close, count_eqns, plane_bytes_cap


# ---------------------------------------------------------------------------
# the acceptance property: ONE launch / ONE wide GEMM per conv site
# ---------------------------------------------------------------------------

SEG_SITES = [
    # (h, c, n, k, d) — SegNet context blocks + a strided front-end site
    (16, 16, 24, 3, 2),
    (16, 16, 24, 3, 8),
    (33, 8, 8, 3, 4),
]


@pytest.mark.parametrize("h,c,n,k,d", SEG_SITES)
def test_xla_forward_is_single_wide_gemm(h, c, n, k, d, single_plan):
    """Every planned dilated site on the fused_tap route lowers to exactly
    one dot_general (and no pallas_call)."""
    pad = ((d, d), (d, d))
    plan, _ = single_plan(h, h, c, n, k, k, (1, 1), (d, d), pad)
    assert plan.path == "fused_tap", plan.path
    x = jnp.zeros((1, h, h, c), jnp.float32)
    packed = jnp.zeros((k * k * c, n), jnp.float32)
    jaxpr = jax.make_jaxpr(plan.apply)(x, packed)
    assert count_eqns(jaxpr.jaxpr, "dot_general") == 1
    assert count_eqns(jaxpr.jaxpr, "pallas_call") == 0
    assert count_eqns(jaxpr.jaxpr, "conv_general_dilated") == 0


def test_pallas_forward_is_single_launch(single_plan):
    """backend='pallas' lowers the whole dilated conv to one pallas_call
    (and no XLA GEMM outside it)."""
    plan, _ = single_plan(13, 13, 8, 8, 3, 3, (1, 1), (2, 2),
                          ((2, 2), (2, 2)), backend="pallas")
    assert plan.path == "pallas" and plan.tiles is not None
    x = jnp.zeros((2, 13, 13, 8), jnp.float32)
    packed = jnp.zeros((9 * 8, 8), jnp.float32)
    jaxpr = jax.make_jaxpr(plan.apply)(x, packed)
    assert count_eqns(jaxpr.jaxpr, "pallas_call") == 1
    assert count_eqns(jaxpr.jaxpr, "dot_general") == 0


def test_strided_conv_is_single_wide_gemm(single_plan):
    """The strided 'conv' kind rides the same route: one dot_general."""
    plan, kind = single_plan(12, 12, 6, 8, 3, 3, (2, 2), (1, 1),
                             ((1, 1), (1, 1)))
    assert kind == "conv" and plan.path == "fused_tap"
    jaxpr = jax.make_jaxpr(plan.apply)(
        jnp.zeros((1, 12, 12, 6)), jnp.zeros((9 * 6, 8)))
    assert count_eqns(jaxpr.jaxpr, "dot_general") == 1


# ---------------------------------------------------------------------------
# superpack layout invariants
# ---------------------------------------------------------------------------

def test_superpack_layout_row_offsets_and_roundtrip(single_plan):
    k = jax.random.normal(jax.random.PRNGKey(0), (3, 2, 5, 4), jnp.float32)
    plan, _ = single_plan(9, 9, 5, 4, 3, 2, (1, 1), (2, 3), ((2, 2), (1, 1)))
    packed = plan.pack(k)
    c, n = 5, 4
    assert packed.shape == (3 * 2 * c, n)
    # tap (m, nn) owns rows [(m*S+nn)*C, (m*S+nn+1)*C) — plan-time schedule
    for (m, nn, row) in plan.dx_taps:
        np.testing.assert_array_equal(
            np.asarray(packed[row * c:(row + 1) * c]), np.asarray(k[m, nn]))
    np.testing.assert_array_equal(np.asarray(plan.unpack(packed)),
                                  np.asarray(k))
    # a dilated kernel packs identically to a dense one: layout is geometry-free
    plan_dense, _ = single_plan(9, 9, 5, 4, 3, 2, (1, 1), (1, 1),
                                ((1, 1), (0, 1)))
    np.testing.assert_array_equal(np.asarray(plan_dense.pack(k)),
                                  np.asarray(packed))


def test_full_kernel_adapts_to_superpack(single_plan):
    """Legacy params holding (R,S,C,N) HWIO kernels still apply/unpack."""
    k = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 4, 6), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 9, 9, 4), jnp.float32)
    plan, _ = single_plan(9, 9, 4, 6, 3, 3, (1, 1), (2, 2), ((2, 2), (2, 2)))
    np.testing.assert_array_equal(np.asarray(plan.apply(x, k)),
                                  np.asarray(plan.apply(x, plan.pack(k))))
    np.testing.assert_array_equal(np.asarray(plan.unpack(k)), np.asarray(k))


# ---------------------------------------------------------------------------
# fused-vs-baseline parity: odd dilations, asymmetric padding, dilation >=
# kernel extent, strided+dilated — on both backends
# ---------------------------------------------------------------------------

PARITY_CASES = [
    # (h, w, r, s, strides, dil, pads)
    (9, 9, 3, 3, (1, 1), (2, 2), ((2, 2), (2, 2))),      # SAME atrous
    (13, 11, 3, 2, (1, 1), (3, 5), ((2, 4), (3, 1))),    # odd dil, asym pads
    (17, 17, 3, 3, (1, 1), (4, 4), ((4, 4), (4, 4))),    # dil >= kernel
    (19, 19, 2, 2, (1, 1), (7, 7), ((0, 0), (0, 0))),    # dil >> kernel, VALID
    (12, 12, 3, 3, (2, 2), (1, 1), ((1, 1), (1, 1))),    # strided conv
    (10, 9, 4, 3, (3, 2), (2, 2), ((3, 2), (2, 2))),     # strided + dilated
    (8, 8, 1, 1, (1, 1), (1, 1), ((0, 0), (0, 0))),      # pure 1x1
]


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("case", PARITY_CASES)
def test_planned_matches_oracle(case, backend, single_plan):
    h, w, r, s, strides, dil, pads = case
    key = jax.random.PRNGKey(abs(hash(case)) % (2 ** 31))
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (2, h, w, 3), jnp.float32)
    k = jax.random.normal(k2, (r, s, 3, 4), jnp.float32)
    plan, _ = single_plan(h, w, 3, 4, r, s, strides, dil, pads,
                          backend=backend)
    want = ref.oracle_dilated_conv2d(x, k, dilation=dil, strides=strides,
                                     padding=pads)
    assert_close(plan.apply(x, plan.pack(k)), want)


def test_taps_fallback_matches_fused(single_plan):
    """Force the per-tap fallback (buffer cap) and check parity."""
    case = (9, 9, 3, 3, (1, 1), (2, 2), ((2, 2), (2, 2)))
    h, w, r, s, strides, dil, pads = case
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (1, h, w, 3), jnp.float32)
    k = jax.random.normal(key, (r, s, 3, 4), jnp.float32)
    plan, _ = single_plan(h, w, 3, 4, r, s, strides, dil, pads)
    assert plan.path == "fused_tap"
    with plane_bytes_cap(0):
        plan_t, _ = single_plan(h, w, 3, 4, r, s, strides, dil, pads)
        assert plan_t.path == "taps"
        want = ref.oracle_dilated_conv2d(x, k, dilation=dil, strides=strides,
                                         padding=pads)
        assert_close(plan_t.apply(x, plan_t.pack(k)), want)
        # VJP parity holds on the fallback route too
        y, vjp = jax.vjp(plan_t.apply, x, plan_t.pack(k))
        y_o, vjp_o = jax.vjp(lambda x, k: ref.oracle_dilated_conv2d(
            x, k, dilation=dil, strides=strides, padding=pads), x, k)
        dy = jax.random.normal(key, y.shape)
        (dx, dpk), (dx_o, dk_o) = vjp(dy), vjp_o(dy)
        assert_close(dx, dx_o, tol=1e-3)
        assert_close(plan_t.unpack(dpk), dk_o, tol=1e-3)


# ---------------------------------------------------------------------------
# custom VJP on the superpack vs autodiff of the XLA oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("case", PARITY_CASES[:6])
def test_grad_of_apply_on_superpack(case, backend, single_plan):
    """VJP through the planned executor, on the superpacked layout, matches
    autodiff of the XLA oracle (dx directly; dK after unpack) — odd
    dilations, asymmetric padding, dilation >= kernel extent, strides."""
    h, w, r, s, strides, dil, pads = case
    key = jax.random.PRNGKey(abs(hash(case)) % (2 ** 31) + 1)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (2, h, w, 3), jnp.float32)
    k = jax.random.normal(k2, (r, s, 3, 4), jnp.float32)
    plan, _ = single_plan(h, w, 3, 4, r, s, strides, dil, pads,
                          backend=backend)
    packed = plan.pack(k)
    y, vjp = jax.vjp(plan.apply, x, packed)
    y_o, vjp_o = jax.vjp(
        lambda x, k: ref.oracle_dilated_conv2d(
            x, k, dilation=dil, strides=strides, padding=pads), x, k)
    assert_close(y, y_o)
    dy = jax.random.normal(k3, y.shape)
    (dx, dpacked), (dx_o, dk_o) = vjp(dy), vjp_o(dy)
    assert dpacked.shape == packed.shape       # grads stay superpacked
    assert_close(dx, dx_o, tol=1e-3)
    assert_close(plan.unpack(dpacked), dk_o, tol=1e-3)


def test_grad_with_full_kernel_cotangent_shape():
    """Callers passing the HWIO kernel get an HWIO cotangent back."""
    from repro.core import huge_dilated_conv2d
    key = jax.random.PRNGKey(11)
    x = jax.random.normal(key, (1, 9, 9, 2), jnp.float32)
    k = jax.random.normal(key, (3, 3, 2, 4), jnp.float32)

    def f(x, k):
        return huge_dilated_conv2d(x, k, dilation=(3, 3),
                                   padding=((3, 3), (3, 3)))

    y, vjp = jax.vjp(f, x, k)
    dx, dk = vjp(jnp.ones_like(y))
    assert dk.shape == k.shape
    y_o, vjp_o = jax.vjp(lambda x, k: ref.oracle_dilated_conv2d(
        x, k, dilation=(3, 3), padding=((3, 3), (3, 3))), x, k)
    dx_o, dk_o = vjp_o(jnp.ones_like(y_o))
    assert_close(dx, dx_o, tol=1e-3)
    assert_close(dk, dk_o, tol=1e-3)


def test_negative_padding_vjp(single_plan):
    """pad_or_crop's crop branch transposes correctly in the backward."""
    key = jax.random.PRNGKey(13)
    x = jax.random.normal(key, (1, 12, 12, 3), jnp.float32)
    k = jax.random.normal(key, (3, 3, 3, 2), jnp.float32)
    pads = ((-1, -2), (-2, -1))
    plan, _ = single_plan(12, 12, 3, 2, 3, 3, (1, 1), (2, 2), pads)
    y, vjp = jax.vjp(plan.apply, x, plan.pack(k))
    y_o, vjp_o = jax.vjp(lambda x, k: ref.oracle_dilated_conv2d(
        x, k, dilation=(2, 2), padding=pads), x, k)
    assert_close(y, y_o)
    dy = jax.random.normal(key, y.shape)
    (dx, dpk), (dx_o, dk_o) = vjp(dy), vjp_o(dy)
    assert_close(dx, dx_o, tol=1e-3)
    assert_close(plan.unpack(dpk), dk_o, tol=1e-3)


# ---------------------------------------------------------------------------
# satellite: the dilation-aware VMEM estimate
# ---------------------------------------------------------------------------

def test_vmem_estimate_superpack_is_dilation_aware():
    from repro.kernels.untangled_conv import vmem_bytes_estimate_superpack
    # same tap count, larger plane: dilation grows the plane term only
    small = vmem_bytes_estimate_superpack(18, 18, 8, 9, 8, 16, 16)
    big = vmem_bytes_estimate_superpack(32, 32, 8, 9, 8, 16, 16)
    assert big > small
    assert big - small == 4 * (32 * 32 - 18 * 18) * 8
    # f32 accumulator is itemsize-independent
    for itemsize in (1, 2, 4):
        est = vmem_bytes_estimate_superpack(18, 18, 8, 9, 8, 16, 16,
                                            itemsize)
        streamed = itemsize * (18 * 18 * 8 + 9 * 8 * 8 + 16 * 16 * 8)
        assert est - streamed == 4 * 16 * 16 * 8


def test_pallas_plan_tiles_respect_budget():
    plan = plan_conv(ConvSpec(
        kind="dilated", in_hw=(33, 33), in_c=256, out_c=256,
        kernel_hw=(3, 3), strides=(1, 1), padding=((4, 4), (4, 4)),
        dilation=(4, 4), backend="pallas"))
    if plan.path != "pallas":
        pytest.skip("no VMEM-feasible tiling on this geometry")
    from repro.kernels.untangled_conv import vmem_bytes_estimate_superpack
    c_t, n_t = plan.tiles
    est = vmem_bytes_estimate_superpack(41, 41, c_t, 9, n_t, *plan.out_hw)
    assert est <= 12 * 1024 * 1024
