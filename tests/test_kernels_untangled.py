"""Pallas untangled-conv kernel vs pure-jnp oracle (interpret=True on CPU).

Sweeps shapes, strides, dilations, dtypes per the kernel-test contract.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import untangled_conv2d
from repro.kernels.ref import untangled_conv2d_ref


def tol_for(dtype):
    # f32 tolerance must cover accumulation-order divergence: the kernel sums
    # taps in f32 scratch (tap-major), the reference contracts in a different
    # order, and reordering an n-term f32 dot can shift the result by up to
    # ~n·eps relative in the worst case (typical ~sqrt(n)·eps).  The (160,96)
    # case contracts 5*5*160 = 4000 terms: sqrt(n)·eps ≈ 7.5e-6, n·eps ≈
    # 4.8e-4.  rtol 1e-4 sits between the typical and worst-case bound —
    # deterministic on shared hosts without absorbing order-of-magnitude
    # defects.
    return 2e-2 if dtype == jnp.bfloat16 else 1e-4


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,w,c,n,r,s,strides,dil",
    [
        (1, 4, 4, 16, 8, 3, 3, (1, 1), (1, 1)),
        (2, 8, 8, 32, 16, 2, 3, (1, 1), (1, 1)),
        (1, 9, 7, 7, 5, 3, 2, (1, 1), (1, 1)),       # ragged channels
        (2, 12, 12, 8, 8, 3, 3, (2, 2), (1, 1)),     # strided (discriminator)
        (1, 13, 13, 4, 4, 3, 3, (1, 1), (2, 2)),     # dilated (atrous)
        (1, 16, 16, 160, 96, 5, 5, (1, 1), (1, 1)),  # > one C/N tile
        (1, 7, 7, 300, 40, 1, 1, (1, 1), (1, 1)),    # pure 1x1 conv
        (3, 5, 5, 130, 200, 2, 2, (1, 1), (1, 1)),   # C and N both ragged-tiled
    ])
def test_kernel_matches_ref(b, h, w, c, n, r, s, strides, dil, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(h * 31 + c))
    x = jax.random.normal(k1, (b, h, w, c), dtype)
    k = jax.random.normal(k2, (r, s, c, n), dtype)
    got = untangled_conv2d(x, k, strides=strides, rhs_dilation=dil,
                           interpret=True)
    want = untangled_conv2d_ref(x, k, strides=strides, rhs_dilation=dil)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol_for(dtype), atol=tol_for(dtype) * 4)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 2), st.integers(4, 10), st.integers(4, 10),
       st.integers(1, 40), st.integers(1, 40), st.integers(1, 3),
       st.integers(1, 3), st.integers(0, 2))
def test_kernel_property_sweep(b, h, w, c, n, r, s, pad):
    if h - r + 1 + 2 * pad <= 0 or w - s + 1 + 2 * pad <= 0:
        return
    k1, k2 = jax.random.split(jax.random.PRNGKey(b + h * 13 + c * 7))
    x = jax.random.normal(k1, (b, h, w, c), jnp.float32)
    k = jax.random.normal(k2, (r, s, c, n), jnp.float32)
    pads = ((pad, pad), (pad, pad))
    got = untangled_conv2d(x, k, padding=pads, interpret=True)
    want = untangled_conv2d_ref(x, k, padding=pads)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-4)


def test_engine_pallas_backend_end_to_end():
    """huge_conv_transpose2d(backend='pallas') == oracle on a DCGAN layer."""
    from repro.core import huge_conv_transpose2d
    from repro.core import reference as ref
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (2, 4, 4, 64), jnp.float32)
    k = jax.random.normal(k2, (5, 5, 64, 32), jnp.float32)
    got = huge_conv_transpose2d(x, k, (2, 2), ((2, 3), (2, 3)), "pallas")
    want = ref.oracle_conv_transpose2d(x, k, strides=(2, 2),
                                       padding=((2, 3), (2, 3)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-4)


def test_vmem_fallback_large_plane():
    """Segmentation-sized planes exceed whole-plane VMEM: XLA fallback path."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    x = jax.random.normal(k1, (1, 160, 160, 64), jnp.float32)
    k = jax.random.normal(k2, (3, 3, 64, 8), jnp.float32)
    got = untangled_conv2d(x, k, interpret=True)
    want = untangled_conv2d_ref(x, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-4)
