"""Pallas untangled-conv kernel vs the float64 numpy oracle (interpret=True
on CPU).  Sweeps shapes, strides, dilations, dtypes per the kernel-test
contract.

Tolerance contract: every parity assertion here is an **ULP-scaled bound
against the float64 oracle** (``tests/conftest.py``'s ``conv_oracle_f64`` /
``assert_close_ulp``), not an rtol guess.  The bound is the standard
recursive-summation forward error (Higham, *Accuracy and Stability of
Numerical Algorithms*, §4.2): any ordering of an ``n``-term f32 accumulation
satisfies ``|fl(Σ) − Σ| ≤ γ_{n+1}·Σ|x_i·k_i|`` with ``γ_n = n·u/(1−n·u)``
and ``u = 2⁻²⁴``, plus half an output-ULP for the final cast.  ``n`` here is
the contraction length ``R·S·C``.  This replaces the widened fixed rtol the
(160, 96) case used to need: the bound scales with each output element's
*condition* (``Σ|x·k|``), so accumulation-order divergence between the
tap-major kernel and any reference ordering is covered by construction,
while a genuine defect (wrong tap offset, wrong superpack row) lands orders
of magnitude outside it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                      # only the property sweep needs hypothesis
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # pragma: no cover - exercised on minimal hosts
    HAVE_HYPOTHESIS = False

from repro.kernels.ops import untangled_conv2d
from repro.kernels.ref import untangled_conv2d_ref

from tests.conftest import assert_close_ulp, conv_oracle_f64


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,w,c,n,r,s,strides,dil",
    [
        (1, 4, 4, 16, 8, 3, 3, (1, 1), (1, 1)),
        (2, 8, 8, 32, 16, 2, 3, (1, 1), (1, 1)),
        (1, 9, 7, 7, 5, 3, 2, (1, 1), (1, 1)),       # ragged channels
        (2, 12, 12, 8, 8, 3, 3, (2, 2), (1, 1)),     # strided (discriminator)
        (1, 13, 13, 4, 4, 3, 3, (1, 1), (2, 2)),     # dilated (atrous)
        (1, 16, 16, 160, 96, 5, 5, (1, 1), (1, 1)),  # > one C/N tile
        (1, 7, 7, 300, 40, 1, 1, (1, 1), (1, 1)),    # pure 1x1 conv
        (3, 5, 5, 130, 200, 2, 2, (1, 1), (1, 1)),   # C and N both ragged-tiled
    ])
def test_kernel_matches_f64_oracle(b, h, w, c, n, r, s, strides, dil, dtype):
    """Kernel output within the ULP-scaled f64-oracle bound (see module
    docstring for the derivation).  bf16 products are exact in the f32
    accumulator (8-bit mantissas), so the same γ_{n+1} bound applies with
    the output cast charged at ε_bf16 = 2⁻⁸."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(h * 31 + c))
    x = jax.random.normal(k1, (b, h, w, c), dtype)
    k = jax.random.normal(k2, (r, s, c, n), dtype)
    got = untangled_conv2d(x, k, strides=strides, rhs_dilation=dil,
                           interpret=True)
    y64, amax64 = conv_oracle_f64(x, k, strides=strides, dilation=dil)
    assert got.shape == y64.shape
    assert_close_ulp(got, y64, amax64, n_terms=r * s * c, out_dtype=dtype)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 2), st.integers(4, 10), st.integers(4, 10),
           st.integers(1, 40), st.integers(1, 40), st.integers(1, 3),
           st.integers(1, 3), st.integers(0, 2))
    def test_kernel_property_sweep(b, h, w, c, n, r, s, pad):
        if h - r + 1 + 2 * pad <= 0 or w - s + 1 + 2 * pad <= 0:
            return
        k1, k2 = jax.random.split(jax.random.PRNGKey(b + h * 13 + c * 7))
        x = jax.random.normal(k1, (b, h, w, c), jnp.float32)
        k = jax.random.normal(k2, (r, s, c, n), jnp.float32)
        pads = ((pad, pad), (pad, pad))
        got = untangled_conv2d(x, k, padding=pads, interpret=True)
        y64, amax64 = conv_oracle_f64(x, k, padding=pads)
        assert_close_ulp(got, y64, amax64, n_terms=r * s * c)
        # and the pure-jnp reference stays within the same bound of the oracle
        want = untangled_conv2d_ref(x, k, padding=pads)
        assert_close_ulp(want, y64, amax64, n_terms=r * s * c)


def test_engine_pallas_backend_end_to_end():
    """huge_conv_transpose2d(backend='pallas') == oracle on a DCGAN layer."""
    from repro.core import huge_conv_transpose2d
    from repro.core import reference as ref
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (2, 4, 4, 64), jnp.float32)
    k = jax.random.normal(k2, (5, 5, 64, 32), jnp.float32)
    got = huge_conv_transpose2d(x, k, (2, 2), ((2, 3), (2, 3)), "pallas")
    want = ref.oracle_conv_transpose2d(x, k, strides=(2, 2),
                                       padding=((2, 3), (2, 3)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-4)


def test_vmem_fallback_large_plane():
    """Segmentation-sized planes exceed whole-plane VMEM: XLA fallback path."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    x = jax.random.normal(k1, (1, 160, 160, 64), jnp.float32)
    k = jax.random.normal(k2, (3, 3, 64, 8), jnp.float32)
    got = untangled_conv2d(x, k, interpret=True)
    want = untangled_conv2d_ref(x, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-4)
