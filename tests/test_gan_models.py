"""DCGAN/cGAN built on the engine: shapes, finiteness, training step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gan
from repro.models.gan import DeconvLayer, GANConfig

SMALL = GANConfig("small", (
    DeconvLayer(4, 32, 16, 5, 2),
    DeconvLayer(8, 16, 3, 5, 2),
), z_dim=16)


def test_generator_shapes_table1():
    """Full Table-1 DCGAN generator: 4x4x1024 z-proj -> 64x64x3 image."""
    key = jax.random.PRNGKey(0)
    p, _ = gan.generator_init(key, gan.DCGAN)
    z = jax.random.normal(key, (2, 100), jnp.float32)
    img = gan.generator_apply(p, z, gan.DCGAN)
    assert img.shape == (2, 64, 64, 3)
    assert np.isfinite(np.asarray(img)).all()
    assert np.abs(np.asarray(img)).max() <= 1.0          # tanh out


def test_cgan_generator_shapes():
    key = jax.random.PRNGKey(1)
    p, _ = gan.generator_init(key, gan.CGAN)
    z = jax.random.normal(key, (2, gan.CGAN.z_dim), jnp.float32)
    img = gan.generator_apply(p, z, gan.CGAN)
    assert img.shape == (2, 32, 32, 3)


def test_discriminator_shapes():
    key = jax.random.PRNGKey(2)
    p, _ = gan.discriminator_init(key, SMALL)
    x = jax.random.normal(key, (3, 16, 16, 3), jnp.float32)
    out = gan.discriminator_apply(p, x, SMALL)
    assert out.shape == (3, 1)


def test_gan_train_step_reduces_d_loss():
    key = jax.random.PRNGKey(3)
    kg, kd, kz, kr = jax.random.split(key, 4)
    gp, _ = gan.generator_init(kg, SMALL)
    dp, _ = gan.discriminator_init(kd, SMALL)
    z = jax.random.normal(kz, (8, SMALL.z_dim), jnp.float32)
    real = jax.random.uniform(kr, (8, 16, 16, 3), jnp.float32, -1, 1)

    @jax.jit
    def d_step(dp):
        def loss(dp):
            return gan.gan_losses(gp, dp, z, real, SMALL)[1]
        l, g = jax.value_and_grad(loss)(dp)
        return jax.tree.map(lambda p, gg: p - 0.05 * gg, dp, g), l

    losses = []
    for _ in range(12):
        dp, l = d_step(dp)
        losses.append(float(l))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_pallas_backend_generator():
    cfg = GANConfig("small-pallas", SMALL.layers, z_dim=16, backend="pallas")
    key = jax.random.PRNGKey(4)
    p, _ = gan.generator_init(key, cfg)
    z = jax.random.normal(key, (1, 16), jnp.float32)
    img_pl = gan.generator_apply(p, z, cfg)
    img_xla = gan.generator_apply(p, z, SMALL)
    np.testing.assert_allclose(np.asarray(img_pl), np.asarray(img_xla),
                               rtol=2e-4, atol=2e-4)


def test_deconv_padding_doubles_size():
    for k, s in ((5, 2), (4, 2), (3, 2)):
        (pl, ph), _ = gan.deconv_padding(k, s)
        for h in (4, 8, 16):
            out = (h - 1) * s + pl + ph - k + 2
            assert out == s * h, (k, s, h, out)
