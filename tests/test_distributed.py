"""Multi-device integration tests (subprocess with forced host devices):
MoE expert-parallel == dense oracle; sharded train with failure/restart;
elastic restore onto a different mesh."""
import os
import subprocess
import sys
import textwrap

import pytest

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=4",
           PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))


def _mesh_capability() -> str | None:
    """Probe (in the same forced-device subprocess the tests use) whether the
    host can build the 2x2 mesh these tests need.  Returns a skip reason, or
    None when the prerequisites are met."""
    probe = (
        "import jax\n"
        "from jax.sharding import PartitionSpec as P\n"
        "from repro.launch.mesh import make_host_mesh\n"
        "from repro.sharding import shard_map_compat\n"
        "m = make_host_mesh(data=2, model=2)\n"
        "f = shard_map_compat(lambda x: x * 2, m, in_specs=P('data'),\n"
        "                     out_specs=P('data'))\n"
        "f(jax.numpy.ones((4,)))\n"
        "print(len(list(m.devices.flat)))\n")
    try:
        r = subprocess.run([sys.executable, "-c", probe], env=ENV,
                           capture_output=True, text=True, timeout=120)
    except Exception as e:  # noqa: BLE001 - any probe failure means skip
        return f"mesh probe failed to run: {e}"
    if r.returncode != 0:
        tail = (r.stderr.strip().splitlines() or ["unknown error"])[-1]
        return f"host mesh unavailable: {tail}"
    n = int(r.stdout.strip() or 0)
    if n < 4:
        return f"need a 2x2 host mesh, got {n} device(s)"
    return None


_SKIP_REASON = _mesh_capability()
pytestmark = pytest.mark.skipif(
    _SKIP_REASON is not None,
    reason=f"distributed prerequisites not met: {_SKIP_REASON}")


def run_py(code: str, timeout=600):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=ENV, capture_output=True, text=True,
                       timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_moe_ep_matches_dense_oracle():
    run_py("""
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.configs import registry
    from repro.layers import moe as moe_lib
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import DistContext, DEFAULT_RULES

    cfg = registry.get_reduced('dbrx-132b')
    # capacity_factor = E/k guarantees no dropped token -> exact equality
    cfg = dataclasses.replace(cfg, moe_impl='ep',
                              capacity_factor=cfg.n_experts / cfg.top_k)
    key = jax.random.PRNGKey(0)
    p, _ = moe_lib.moe_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model),
                          jnp.bfloat16)
    mesh = make_host_mesh(data=2, model=2)
    rules = dict(DEFAULT_RULES); rules['batch'] = 'data'
    dist = DistContext(mesh=mesh, rules=rules)
    with mesh:
        y_ep = jax.jit(lambda p, x: moe_lib.moe_apply_ep(p, x, cfg, dist))(p, x)
    y_dense = moe_lib.moe_apply_dense(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_ep, np.float32),
                               np.asarray(y_dense, np.float32),
                               rtol=5e-2, atol=5e-2)
    print('EP == dense oracle OK')
    """)


def test_moe_a2a_ep_matches_dense_oracle():
    """All-to-all EP (1 expert/chip over data*model) == dense oracle,
    including the padded-token decode path."""
    run_py("""
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs import registry
    from repro.layers import moe as moe_lib
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import DistContext, DEFAULT_RULES

    cfg = registry.get_reduced('dbrx-132b')
    cfg = dataclasses.replace(cfg, moe_impl='ep',
                              capacity_factor=cfg.n_experts / cfg.top_k * 4)
    key = jax.random.PRNGKey(0)
    p, _ = moe_lib.moe_init(key, cfg)
    mesh = make_host_mesh(data=2, model=2)
    rules = dict(DEFAULT_RULES)
    rules['batch'] = 'data'
    rules['expert'] = ('data', 'model')       # 4 experts over 4 chips
    dist = DistContext(mesh=mesh, rules=rules)
    for (b, s) in ((4, 8), (2, 3)):           # divisible and PADDED cases
        x = jax.random.normal(jax.random.PRNGKey(b), (b, s, cfg.d_model),
                              jnp.bfloat16)
        with mesh:
            y = jax.jit(lambda p, x: moe_lib.moe_apply_ep_a2a(
                p, x, cfg, dist))(p, x)
        y_ref = moe_lib.moe_apply_dense(p, x, cfg)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(y_ref, np.float32),
                                   rtol=5e-2, atol=5e-2)
    print('a2a EP == dense oracle OK (incl. padding)')
    """)


def test_sharded_train_with_failure_restart(tmp_path):
    out = run_py(f"""
    import numpy as np
    from repro.launch.train import train
    losses, final = train('llama3.2-1b', reduced=True, steps=12, batch=8,
                          seq=32, ckpt_dir={str(tmp_path)!r}, ckpt_every=4,
                          fail_at=[6], data=2, model=2)
    # the claim under test is fault tolerance: the injected failure at step 6
    # must be survived via checkpoint restore and training must complete.
    assert final == 12, final
    assert np.isfinite(losses).all()
    # random-token loss barely moves in 12 steps; just bound the drift
    assert losses[-1] < losses[0] + 0.1, (losses[0], losses[-1])
    print('sharded train with restart OK', losses[0], losses[-1])
    """)
    assert "restart" in out or "OK" in out


def test_elastic_restore_on_smaller_mesh(tmp_path):
    run_py(f"""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    from repro.runtime.elastic import restore_on_mesh, shrink_mesh
    from repro.sharding import DistContext, DEFAULT_RULES
    from repro.train.checkpoint import CheckpointManager

    state = {{'w': jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
    specs = {{'w': P(None, 'model')}}
    big = make_host_mesh(data=2, model=2)
    ck = CheckpointManager({str(tmp_path)!r}, async_save=False)
    ck.save(3, state)

    small = shrink_mesh(2, model=2)      # lost half the chips
    assert dict(zip(small.axis_names, small.devices.shape)) == \\
        {{'data': 1, 'model': 2}}
    dist = DistContext(mesh=small, rules=dict(DEFAULT_RULES))
    restored = restore_on_mesh(ck, state, specs, dist)
    np.testing.assert_array_equal(np.asarray(restored['w']),
                                  np.arange(64).reshape(8, 8))
    shd = restored['w'].sharding
    assert shd.spec == P(None, 'model'), shd
    print('elastic restore OK')
    """)


def test_crosspod_compressed_allreduce():
    run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    from repro.runtime import compress
    from repro.sharding import shard_map_compat

    mesh = make_host_mesh(data=2, model=1, pod=2)
    grads = {'w': jnp.stack([jnp.full((4,), float(i)) for i in range(2)])}
    errs = {'w': jnp.zeros((2, 4))}

    def f(g, e):
        return compress.crosspod_allreduce_compressed(g, e, 'pod')

    fm = shard_map_compat(f, mesh,
                          in_specs=({'w': P('pod', None)},) * 2,
                          out_specs=({'w': P('pod', None)},) * 2)
    with mesh:
        mean, new_e = fm(grads, errs)
    # mean over pods of [0, 1] = 0.5 everywhere
    np.testing.assert_allclose(np.asarray(mean['w']), 0.5, atol=0.01)
    print('compressed cross-pod allreduce OK')
    """)
