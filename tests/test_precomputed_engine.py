"""Offline-decomposed serving path (P0) matches the oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import reference as ref
from repro.core.engine import (huge_conv_transpose2d_pre,
                               precompute_transposed_weights)


@pytest.mark.parametrize("h,r,stride,pad", [
    (4, 5, 2, (2, 3)), (8, 4, 2, (1, 2)), (5, 3, 3, (0, 0)), (6, 3, 1, (1, 1)),
])
def test_precomputed_matches_oracle(h, r, stride, pad):
    key = jax.random.PRNGKey(h * 10 + r)
    x = jax.random.normal(key, (2, h, h + 1, 6), jnp.float32)
    k = jax.random.normal(key, (r, r, 6, 8), jnp.float32)
    pads = (pad, pad)
    subs = precompute_transposed_weights(k, (stride, stride), pads)
    got = huge_conv_transpose2d_pre(x, subs, (r, r), (stride, stride), pads)
    want = ref.oracle_conv_transpose2d(x, k, strides=(stride, stride),
                                       padding=pads)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_naive_pre_matches_oracle():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (1, 6, 6, 4), jnp.float32)
    k = jax.random.normal(key, (5, 5, 4, 8), jnp.float32)
    w_flat = k.reshape(5 * 5 * 4, 8)
    got = ref.naive_conv_transpose2d_pre(x, w_flat, (5, 5), strides=(2, 2),
                                         padding=((2, 3), (2, 3)))
    want = ref.oracle_conv_transpose2d(x, k, strides=(2, 2),
                                       padding=((2, 3), (2, 3)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
