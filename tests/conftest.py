"""Shared oracle / property-test harness for the HUGE² engine suite.

One home for everything the per-file suites used to duplicate:

- ``assert_close`` / ``count_eqns`` — tolerance assertion and the jaxpr
  equation counter (descends into sub-jaxprs but never into a
  ``pallas_call`` body: its interior matmuls live inside the one launch
  being counted).
- NHWC oracle wrappers over ``lax.conv_general_dilated``
  (``oracle_transposed`` / ``oracle_single``) and the **float64 numpy
  oracle** ``conv_oracle_f64`` with its ULP-scaled error bound
  ``ulp_bound`` — the principled replacement for widened rtols (see the
  bound derivation on ``ulp_bound``).
- superpack round-trip builders (``random_case`` / ``packed_roundtrip``)
  and plan-builder fixtures (``dcgan_plan`` / ``single_plan``).
- plan-constant patch helpers (``plane_bytes_cap`` / ``vmem_budget``)
  that swap a route-builder cap and clear the plan cache on both sides —
  the one sanctioned way tests force a route.
- the **seeded-shuffle** collection hook: set ``PYTEST_SHUFFLE_SEED=<int>``
  to run the suite in a deterministic random order (flushes test-order
  dependence without a pytest-randomly dependency; CI runs one shuffled
  pass per build).
"""
from __future__ import annotations

import contextlib
import os
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.plan as planmod
from repro.core import reference as ref
from repro.core.plan import ConvSpec, conv_spec, plan_cache_clear, plan_conv

# shared tolerance constants (f32 forward / VJP-vs-autodiff / bf16)
TOL_FWD = 2e-4
TOL_GRAD = 1e-3
TOL_BF16 = 2e-2


def pytest_collection_modifyitems(config, items):
    seed = os.environ.get("PYTEST_SHUFFLE_SEED")
    if seed:
        random.Random(int(seed)).shuffle(items)
        print(f"\n[conftest] shuffled {len(items)} tests "
              f"(PYTEST_SHUFFLE_SEED={seed})")


# ---------------------------------------------------------------------------
# assertion + jaxpr helpers
# ---------------------------------------------------------------------------

def assert_close(a, b, tol=TOL_FWD):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=tol, atol=tol)


def count_eqns(jaxpr, prim_name):
    """Recursively count equations named ``prim_name``, descending into
    sub-jaxprs (custom_vjp calls, pjit bodies, ...) — but not into a
    pallas_call's kernel body: its interior matmuls live inside the one
    launch being counted."""
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == prim_name:
            total += 1
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else [v]):
                if hasattr(sub, "eqns"):
                    total += count_eqns(sub, prim_name)
                elif hasattr(sub, "jaxpr"):
                    total += count_eqns(sub.jaxpr, prim_name)
    return total


# ---------------------------------------------------------------------------
# NHWC oracles: the lax wrappers and the float64 reference
# ---------------------------------------------------------------------------

def oracle_transposed(x, k, *, strides, padding):
    """XLA's lhs-dilated conv — the transposed-kind correctness oracle."""
    return ref.oracle_conv_transpose2d(x, k, strides=strides, padding=padding)


def oracle_single(x, k, *, strides=(1, 1), dilation=(1, 1),
                  padding=((0, 0), (0, 0))):
    """XLA's rhs-dilated conv — the 'conv'/'dilated'-kind oracle."""
    return ref.oracle_dilated_conv2d(x, k, dilation=dilation, strides=strides,
                                     padding=padding)


def conv_oracle_f64(x, k, *, strides=(1, 1), dilation=(1, 1),
                    padding=((0, 0), (0, 0))):
    """Float64 numpy correlation oracle: returns ``(y64, amax64)`` where
    ``y64`` is the exact-to-f64 output and ``amax64`` the same contraction
    over ``|x|·|k|`` — the condition-number companion every ULP-scaled
    error bound needs.  Tap loop over (R, S) with strided/dilated slices,
    accumulated in float64; no jax x64 flag required."""
    x64 = np.asarray(x, np.float64)
    k64 = np.asarray(k, np.float64)
    (sh, sw), (dh, dw) = strides, dilation
    (ph, pw) = padding
    r, s, c, n = k64.shape
    x64 = np.pad(x64, ((0, 0), (ph[0], ph[1]), (pw[0], pw[1]), (0, 0)))
    b, hp, wp, _ = x64.shape
    oh = (hp - (r - 1) * dh - 1) // sh + 1
    ow = (wp - (s - 1) * dw - 1) // sw + 1
    y = np.zeros((b, oh, ow, n))
    amax = np.zeros((b, oh, ow, n))
    for m in range(r):
        for nn in range(s):
            xs = x64[:, m * dh:m * dh + (oh - 1) * sh + 1:sh,
                     nn * dw:nn * dw + (ow - 1) * sw + 1:sw, :]
            y += xs @ k64[m, nn]
            amax += np.abs(xs) @ np.abs(k64[m, nn])
    return y, amax


def ulp_bound(y64, amax64, n_terms, out_dtype=jnp.float32):
    """Elementwise absolute error bound for an f32-accumulated contraction
    of ``n_terms`` products, checked against the float64 oracle.

    Derivation (standard recursive-summation forward error, Higham §4.2):
    for any summation order of ``n`` f32 terms, ``|fl(Σ) - Σ| ≤ γ_n·Σ|t_i|``
    with ``γ_n = n·u/(1 - n·u)`` and ``u = 2^-24`` (the products themselves
    are exact in f32 for bf16 inputs and one-rounding for f32 inputs, which
    the ``n+1`` below absorbs).  The kernel and any reference ordering both
    satisfy the bound, so vs the exact f64 value we allow ``γ_{n+1}·amax``.
    A final cast to ``out_dtype`` adds half an output ULP: ``ε_out·|y|``.
    Unlike an rtol on ``|y|``, this scales with the *condition* of each
    output element — catastrophic cancellation widens it honestly, and a
    genuine defect (wrong tap, wrong offset) lands orders of magnitude
    outside it."""
    u = np.float64(2) ** -24
    eps_out = np.finfo(np.dtype(jnp.dtype(out_dtype)).name).eps \
        if jnp.dtype(out_dtype) != jnp.bfloat16 else np.float64(2) ** -8
    gamma = (n_terms + 1) * u / (1 - (n_terms + 1) * u)
    return gamma * amax64 + eps_out * np.abs(y64) + np.finfo(np.float32).tiny


def assert_close_ulp(got, y64, amax64, n_terms, out_dtype=jnp.float32):
    """Assert ``got`` is within the ULP-scaled bound of the f64 oracle."""
    err = np.abs(np.asarray(got, np.float64) - y64)
    bound = ulp_bound(y64, amax64, n_terms, out_dtype)
    worst = np.max(err - bound)
    assert np.all(err <= bound), (
        f"max excess over ULP-scaled bound: {worst:.3e} "
        f"(n_terms={n_terms}, max_err={err.max():.3e}, "
        f"max_bound={bound.max():.3e})")


# ---------------------------------------------------------------------------
# superpack round-trip builders
# ---------------------------------------------------------------------------

def random_case(seed, b, h, w, c, n, r, s, dtype=jnp.float32):
    """(x, kernel) drawn from a seeded normal — the standard test inputs."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (b, h, w, c), dtype)
    k = jax.random.normal(k2, (r, s, c, n), dtype)
    return x, k


def packed_roundtrip(plan, kernel):
    """Pack onto the superpack, assert the exact unpack round-trip, return
    the packed buffer — the invariant every packed-weight test leans on."""
    packed = plan.pack(kernel)
    np.testing.assert_array_equal(np.asarray(plan.unpack(packed)),
                                  np.asarray(kernel))
    return packed


# ---------------------------------------------------------------------------
# plan-constant patches (route forcing) — save/restore + cache clear
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def plane_bytes_cap(cap):
    """Temporarily swap ``plan._PLANE_BYTES_MAX`` (the fused-buffer cap the
    route builders evaluate per bucket) and clear the plan cache."""
    old = planmod._PLANE_BYTES_MAX
    planmod._PLANE_BYTES_MAX = cap
    plan_cache_clear()
    try:
        yield
    finally:
        planmod._PLANE_BYTES_MAX = old
        plan_cache_clear()


@contextlib.contextmanager
def vmem_budget(budget):
    """Temporarily swap ``plan._VMEM_BUDGET`` (what the Pallas tile searches
    fit against) and clear the plan cache — small geometries then exercise
    the spatially tiled routes real segmentation planes would take."""
    old = planmod._VMEM_BUDGET
    planmod._VMEM_BUDGET = budget
    plan_cache_clear()
    try:
        yield
    finally:
        planmod._VMEM_BUDGET = old
        plan_cache_clear()


# ---------------------------------------------------------------------------
# plan-builder fixtures
# ---------------------------------------------------------------------------

@pytest.fixture
def dcgan_plan():
    """Factory: Table-1 DCGAN layer record -> transposed ConvPlan."""
    from repro.models.gan import deconv_padding

    def build(l, backend="xla"):
        return plan_conv(ConvSpec(
            kind="transposed", in_hw=(l.in_hw, l.in_hw), in_c=l.in_c,
            out_c=l.out_c, kernel_hw=(l.kernel, l.kernel),
            strides=(l.stride, l.stride),
            padding=deconv_padding(l.kernel, l.stride), backend=backend))

    return build


@pytest.fixture
def single_plan():
    """Factory: (h, w, c, n, r, s, strides, dil, pads[, backend]) ->
    (single-correlation ConvPlan, kind)."""

    def build(h, w, c, n, r, s, strides, dil, pads, backend="xla"):
        kind = "dilated" if tuple(dil) != (1, 1) else "conv"
        return plan_conv(conv_spec(kind, (1, h, w, c), (r, s, c, n),
                                   strides=strides, padding=pads,
                                   dilation=dil, backend=backend)), kind

    return build
