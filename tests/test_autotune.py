"""Measured route autotuning: timing loop, candidate set, cache robustness,
fallback ladder, and oracle parity of tuned plans.

The cache-corruption suite is the load-bearing part: a route cache is an
*accelerator*, so every failure mode (corrupt JSON, truncated file, stale
schema, foreign device fingerprint, malformed entries) must degrade to
heuristic routes with a ``RuntimeWarning`` — never a crash, never a wrong
route.  The warm-cache tests assert the acceptance criterion directly:
a second model load against a populated cache performs ZERO microbenchmark
runs (``autotune.measure_calls()`` unchanged).
"""
from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.autotune as at
from repro.core.autotune import (SCHEMA, AutotunePolicy, RouteCache, Timing,
                                 candidate_routes, device_fingerprint,
                                 measure_bucket, measure_fn, route_from_json,
                                 route_label, route_to_json)
from repro.core.plan import (BATCH_BUCKETS, ConvSpec, Route,
                             plan_cache_clear, plan_conv)
from tests.conftest import (TOL_GRAD, assert_close, oracle_transposed,
                            random_case)

# a tiny transposed site: cheap to jit, has the full transposed candidate
# set (fused_plane / fused_tap / taps / per_phase)
TINY = ConvSpec(kind="transposed", in_hw=(4, 4), in_c=4, out_c=4,
                kernel_hw=(3, 3), strides=(2, 2),
                padding=((1, 0), (1, 0)))
# fast measure policy for tests: one bucket, one timed iteration
FAST = dict(buckets=(1,), iters=1, warmup=0)


@pytest.fixture(autouse=True)
def _fresh_caches():
    plan_cache_clear()
    yield
    plan_cache_clear()


def tiny_spec(**kw):
    """A distinct tiny spec per test (vary in_c/out_c to dodge the
    in-process tuned-plan singleton across tests)."""
    return dataclasses.replace(TINY, **kw)


# ---------------------------------------------------------------------------
# timing loop
# ---------------------------------------------------------------------------

def test_measure_fn_min_le_median_and_iters():
    f = jax.jit(lambda x: x * 2.0)
    t = measure_fn(f, jnp.ones((8, 8)), iters=5, warmup=1)
    assert isinstance(t, Timing)
    assert 0.0 < t.min_s <= t.median_s
    assert t.iters == 5
    assert t.min_us == pytest.approx(t.min_s * 1e6)


def test_bench_util_time_fn_is_the_shared_loop():
    import benchmarks.util as bu
    assert bu.measure_fn is measure_fn          # ONE implementation
    f = jax.jit(lambda x: x + 1.0)
    assert bu.time_fn(f, jnp.ones(4), iters=3, warmup=1) > 0.0
    assert isinstance(bu.time_stats(f, jnp.ones(4), iters=3, warmup=1),
                      Timing)


# ---------------------------------------------------------------------------
# candidate enumeration
# ---------------------------------------------------------------------------

def test_candidates_include_heuristic_and_per_phase():
    plan = plan_conv(tiny_spec())
    for b in BATCH_BUCKETS:
        cands = candidate_routes(plan, b)
        labels = [route_label(r) for r in cands]
        assert len(labels) == len(set(labels))          # deduped
        assert plan.route_for_batch(b) in cands         # heuristic is in set
        assert any(r.path == "per_phase" for r in cands)
        assert all(r.batch == b for r in cands)


def test_candidates_single_kind_feasible_set():
    spec = ConvSpec(kind="conv", in_hw=(8, 8), in_c=4, out_c=4,
                    kernel_hw=(3, 3), padding=((1, 1), (1, 1)))
    plan = plan_conv(spec)
    cands = candidate_routes(plan, 1)
    paths = {r.path for r in cands}
    assert "taps" in paths and "fused_tap" in paths
    assert "per_phase" not in paths          # transposed-only executor
    assert plan.route_for_batch(1) in cands


# ---------------------------------------------------------------------------
# cache round-trip + corruption ladder
# ---------------------------------------------------------------------------

def test_cache_roundtrip_identical_routes(tmp_path):
    path = str(tmp_path / "c.json")
    spec = tiny_spec()
    routes = (Route(1, "per_phase", None),
              Route(4, "fused_plane", None),
              Route(16, "pallas", (8, 8), sp_tiles=(4, 4)),
              Route(64, "taps", None, fused_bwd=False))
    cache = RouteCache(path)
    for r in routes:
        cache.put(spec, r, {"taps": 1e-4})
    cache.save()
    fresh = RouteCache(path)
    assert fresh.loaded_from_disk
    for r in routes:
        assert fresh.get(spec, r.batch) == r            # exact Route tuples
    assert fresh.get(spec, 2) is None
    assert fresh.get(tiny_spec(in_c=8), 1) is None


def test_route_json_schema_matches_fixture():
    r = Route(4, "pallas", (8, 8), sp_tiles=(4, 4), fused_bwd=False)
    rj = route_to_json(r)
    assert set(rj) == {"batch", "path", "tiles", "sp_tiles", "fused_bwd",
                       "dev_tiles"}
    assert route_from_json(rj) == r
    dev = Route(16, "pallas", (8, 8), sp_tiles=(4, 4), dev_tiles=(2, 2))
    assert route_from_json(route_to_json(dev)) == dev


@pytest.mark.parametrize("poison", ["corrupt", "truncated", "stale_schema",
                                    "bad_fingerprint", "malformed_entries"])
def test_cache_poison_warns_and_falls_back(tmp_path, poison):
    path = tmp_path / "c.json"
    good = {"schema": SCHEMA, "fingerprint": device_fingerprint(),
            "entries": {"k": {"spec": {}, "routes": {
                "1": route_to_json(Route(1, "taps", None))}}},
            "bucket_costs": {}}
    if poison == "corrupt":
        path.write_text("{this is not json")
    elif poison == "truncated":
        full = json.dumps(good)
        path.write_text(full[:len(full) // 2])
    elif poison == "stale_schema":
        path.write_text(json.dumps({**good, "schema": "huge2-route-cache/v0"}))
    elif poison == "bad_fingerprint":
        path.write_text(json.dumps(
            {**good, "fingerprint": {"platform": "mars"}}))
    elif poison == "malformed_entries":
        path.write_text(json.dumps(
            {**good, "entries": {"k": {"routes": {"1": {"batch": "NaN?"}}}}}))
    with pytest.warns(RuntimeWarning, match="falling back to heuristic"):
        cache = RouteCache(str(path))
    assert cache.entries == {} and not cache.loaded_from_disk
    assert cache.get(tiny_spec(), 1) is None
    cache.save()                                     # rewrites cleanly
    assert RouteCache(str(path)).fingerprint == device_fingerprint()


def test_poisoned_cache_never_crashes_plan_build(tmp_path):
    path = tmp_path / "c.json"
    path.write_text("garbage")
    spec = tiny_spec(out_c=8)
    with pytest.warns(RuntimeWarning):
        plan = plan_conv(spec, autotune=AutotunePolicy(
            mode="cache", cache_path=str(path), **FAST))
    assert plan.routes == plan_conv(spec).routes     # heuristic fallback


# ---------------------------------------------------------------------------
# fallback ladder + warm-cache zero-measurement acceptance
# ---------------------------------------------------------------------------

def test_cache_mode_cold_is_heuristic_and_measures_nothing(tmp_path):
    spec = tiny_spec(in_c=8)
    before = at.measure_calls()
    plan = plan_conv(spec, autotune=AutotunePolicy(
        mode="cache", cache_path=str(tmp_path / "c.json"), **FAST))
    assert at.measure_calls() == before              # cold + cache-only
    assert plan.tuned
    assert plan.routes == plan_conv(spec).routes


def test_measure_mode_persists_then_warm_load_measures_zero(tmp_path):
    path = str(tmp_path / "c.json")
    spec = tiny_spec(in_c=8, out_c=8)
    policy = AutotunePolicy(mode="measure", cache_path=path, **FAST)

    before = at.measure_calls()
    plan1 = plan_conv(spec, autotune=policy)
    assert at.measure_calls() > before               # cold: measured
    raw = json.loads((tmp_path / "c.json").read_text())
    assert raw["schema"] == SCHEMA                   # file produced + valid
    assert raw["fingerprint"] == device_fingerprint()
    (ent,) = raw["entries"].values()
    assert "1" in ent["routes"]
    assert "measured_us" in ent["routes"]["1"]

    plan_cache_clear()                               # simulate a restart
    before = at.measure_calls()
    plan2 = plan_conv(spec, autotune=policy)
    assert at.measure_calls() == before              # warm: ZERO runs
    assert plan2.routes == plan1.routes
    assert plan2.tuned


def test_untuned_buckets_keep_heuristic_routes(tmp_path):
    spec = tiny_spec(kernel_hw=(5, 5), padding=((2, 1), (2, 1)))
    heur = plan_conv(spec)
    plan = plan_conv(spec, autotune=AutotunePolicy(
        mode="measure", cache_path=str(tmp_path / "c.json"), buckets=(1,),
        iters=1, warmup=0))
    for b in BATCH_BUCKETS[1:]:
        assert plan.route_for_batch(b) == heur.route_for_batch(b)


def test_min_gain_hysteresis(monkeypatch):
    spec = tiny_spec(in_c=16)
    plan = plan_conv(spec)
    heur = plan.route_for_batch(1)

    def fake_measure(plan_, route, x, packed, *, iters, warmup):
        # challenger 2% faster than the heuristic: inside min_gain=1.03
        t = 1.00e-3 if route == heur else 0.98e-3
        return Timing(t, t, iters)

    monkeypatch.setattr(at, "measure_route", fake_measure)
    winner, timings = measure_bucket(plan, 1, AutotunePolicy(**FAST))
    assert winner == heur                            # tie stays heuristic
    assert timings[route_label(heur)] == pytest.approx(1.00e-3)

    def fake_measure_big(plan_, route, x, packed, *, iters, warmup):
        t = 1.00e-3 if route == heur else 0.50e-3    # 2x: a real flip
        return Timing(t, t, iters)

    monkeypatch.setattr(at, "measure_route", fake_measure_big)
    winner, _ = measure_bucket(plan, 1, AutotunePolicy(**FAST))
    assert winner != heur


# ---------------------------------------------------------------------------
# tuned plans stay correct: fwd + VJP oracle parity
# ---------------------------------------------------------------------------

def test_autotuned_plan_oracle_parity():
    spec = tiny_spec(in_hw=(6, 6))
    plan = plan_conv(spec, autotune=AutotunePolicy(
        mode="measure", cache_path="", **FAST))      # memory-only
    x, k = random_case(0, 1, 6, 6, spec.in_c, spec.out_c, 3, 3)
    packed = plan.pack(k)
    want = oracle_transposed(x, k, strides=spec.strides,
                             padding=spec.padding)
    assert_close(plan.apply(x, packed), want)
    gx, gk = jax.grad(lambda a, w: plan.apply(a, w).sum(),
                      argnums=(0, 1))(x, packed)
    ox, ok = jax.grad(
        lambda a, w: oracle_transposed(a, w, strides=spec.strides,
                                       padding=spec.padding).sum(),
        argnums=(0, 1))(x, k)
    assert_close(gx, ox, TOL_GRAD)
    assert_close(gk, plan.pack(ok), TOL_GRAD)


@pytest.mark.parametrize("batch", [1, 3])
def test_forced_per_phase_route_parity(batch):
    spec = tiny_spec(in_hw=(8, 8))
    base = plan_conv(spec)
    plan = base.with_routes(tuple(
        Route(r.batch, "per_phase", None, fused_bwd=r.fused_bwd)
        for r in base.routes))
    x, k = random_case(1, batch, 8, 8, spec.in_c, spec.out_c, 3, 3)
    packed = plan.pack(k)
    want = oracle_transposed(x, k, strides=spec.strides,
                             padding=spec.padding)
    assert_close(plan.apply(x, packed), want)
    gx = jax.grad(lambda a: plan.apply(a, packed).sum())(x)
    ox = jax.grad(lambda a: oracle_transposed(
        a, k, strides=spec.strides, padding=spec.padding).sum())(x)
    assert_close(gx, ox, TOL_GRAD)


# ---------------------------------------------------------------------------
# serving: bucket-cost persistence through the same cache file
# ---------------------------------------------------------------------------

def test_batcher_bucket_costs_persist_and_skip_remeasure(tmp_path):
    from repro.serving.image_batcher import DynamicImageBatcher

    path = str(tmp_path / "c.json")
    serve = lambda x: x * 2.0                        # noqa: E731
    proto = np.zeros((3,), np.float32)

    cache = RouteCache(path)
    b1 = DynamicImageBatcher(serve, buckets=(1, 4), cache=cache,
                             cache_key="m")
    assert b1.warmup(proto) == (1, 4)                # cold: both timed
    assert set(b1.bucket_cost_s) == {1, 4}

    cache2 = RouteCache(path)                        # restarted server
    assert cache2.loaded_from_disk
    b2 = DynamicImageBatcher(serve, buckets=(1, 4), cache=cache2,
                             cache_key="m")
    assert set(b2.bucket_cost_s) == {1, 4}           # preloaded
    assert b2.warmup(proto) == ()                    # compiles, times none
    assert b2.bucket_cost_s == pytest.approx(b1.bucket_cost_s)
    assert b2.warmup(proto, force=True) == (1, 4)    # explicit re-measure


def test_batcher_foreign_cache_key_measures(tmp_path):
    from repro.serving.image_batcher import DynamicImageBatcher

    cache = RouteCache(str(tmp_path / "c.json"))
    cache.put_bucket_costs("other-model", {1: 1.0})
    b = DynamicImageBatcher(lambda x: x, buckets=(1,), cache=cache,
                            cache_key="mine")
    assert b.bucket_cost_s == {}                     # keys don't bleed
    assert b.warmup(np.zeros((2,), np.float32)) == (1,)


# ---------------------------------------------------------------------------
# model zoo threads the policy
# ---------------------------------------------------------------------------

def test_models_thread_policy_to_plans():
    from repro.models import gan, segnet, vae

    policy = AutotunePolicy(mode="cache", cache_path="", **FAST)
    g = gan.GANConfig("t", (gan.DeconvLayer(4, 8, 4, 3, 2),),
                      autotune=policy)
    s = dataclasses.replace(segnet.SEGNET_TINY, autotune=policy)
    v = dataclasses.replace(vae.VAE_TINY, autotune=policy)
    before = at.measure_calls()
    for plans in (gan.generator_plans(g), gan.discriminator_plans(g),
                  segnet.segnet_plans(s), vae.vae_plans(v)):
        assert plans and all(p.tuned for p in plans)
    assert at.measure_calls() == before              # cache-mode: zero runs
    assert not any(p.tuned for p in gan.generator_plans(
        gan.GANConfig("t2", g.layers)))              # None policy: untouched
