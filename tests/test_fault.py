"""Unit coverage for the fault-tolerance primitives in runtime/fault.py:
EWMA straggler detection, deterministic failure injection, the heartbeat
watchdog, and the restart driver's explicit restore contract.  The
serving-side replay integration test lives in tests/test_control_plane.py.
"""
import pytest

from repro.runtime.fault import (FailureInjector, Heartbeat, NodeFailure,
                                 StragglerMonitor, run_with_restarts)

# ---------------------------------------------------------------------------
# StragglerMonitor
# ---------------------------------------------------------------------------


def test_straggler_warmup_window_never_flags():
    m = StragglerMonitor(warmup=5, k=3.0)
    # even a wild spike inside the warmup window must not flag: the
    # monitor has no variance estimate yet
    assert not m.record(0, 0.1)
    assert not m.record(1, 5.0)
    assert not m.record(2, 0.1)
    assert not m.events


def test_straggler_sub_noise_jitter_never_flags():
    m = StragglerMonitor(warmup=3, k=3.0)
    # jitter within the 5%-of-mean stddev floor: 0.1 s +- 0.4% never
    # exceeds mean + 3 * max(std, 0.005)
    for s in range(200):
        assert not m.record(s, 0.1 + 0.0004 * (s % 2))
    assert not m.events


def test_straggler_monitor_flags_slow_step():
    m = StragglerMonitor(warmup=3, k=3.0)
    for s in range(10):
        m.record(s, 0.1 + 0.001 * (s % 2))
    assert not m.events
    assert m.record(10, 1.5)          # 15x slower
    assert m.events
    step, dt, _mean = m.events[0]
    assert (step, dt) == (10, 1.5)


def test_straggler_recovers_after_flagged_spike():
    m = StragglerMonitor(warmup=3, k=3.0)
    for s in range(10):
        m.record(s, 0.1)
    assert m.record(10, 1.5)
    # the spike moved the EWMA mean up; steady steps settle back down
    # and stop flagging
    flags = [m.record(11 + s, 0.1) for s in range(20)]
    assert not any(flags[5:])


# ---------------------------------------------------------------------------
# FailureInjector / Heartbeat
# ---------------------------------------------------------------------------


def test_failure_injector_fires_once_per_step():
    inj = FailureInjector((3, 5))
    inj.check(0)
    with pytest.raises(NodeFailure):
        inj.check(3)
    inj.check(3)                      # already fired: replay passes
    with pytest.raises(NodeFailure):
        inj.check(5)
    inj.check(5)
    assert inj.fired == {3, 5}


def test_heartbeat_beat_and_expiry():
    hb = Heartbeat(timeout=1e4)
    assert hb.beat() >= 0.0
    assert not hb.expired()
    hb.last -= 2e4                    # pretend the last beat was long ago
    assert hb.expired()
    hb.beat()                         # beating un-expires the watchdog
    assert not hb.expired()


# ---------------------------------------------------------------------------
# run_with_restarts: explicit restore contract
# ---------------------------------------------------------------------------


def test_restart_reenters_at_restored_step():
    inj = FailureInjector((3,))
    calls = []

    def loop(start):
        calls.append(start)
        for s in range(start, 6):
            inj.check(s)
        return 6

    # restore() says "checkpoint at 2": second attempt enters there
    assert run_with_restarts(loop, restore=lambda: 2) == 6
    assert calls == [0, 2]


def test_restart_without_restore_reenters_at_initial_step():
    inj = FailureInjector((3,))
    calls = []

    def loop(start):
        calls.append(start)
        for s in range(start, 6):
            inj.check(s)
        return 6

    assert run_with_restarts(loop, initial_step=1) == 6
    assert calls == [1, 1]


def test_restart_budget_exhausted():
    inj = FailureInjector((0,))
    seen = []

    def loop(start):
        inj.fired.clear()             # fail every time
        inj.check(0)
        return 1

    with pytest.raises(NodeFailure):
        run_with_restarts(loop, max_restarts=2,
                          on_restart=lambda n, e: seen.append(n))
    assert seen == [1, 2]             # on_restart ran for each retry only
