"""VAE on the engine: planned sites both halves, superpacked weights,
decoder parity vs the transposed-conv oracle, ELBO training through the
packed VJPs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reference as ref
from repro.models import vae


CFG = vae.VAE_TINY


def assert_close(a, b, tol=2e-4):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=tol, atol=tol)


def test_config_mirrors_encoder_and_decoder():
    assert CFG.feat_hw == CFG.image_hw // 4
    enc, dec = CFG.encoder_layers, CFG.decoder_layers
    assert [l.in_hw for l in enc] == [16, 8]
    assert [l.in_hw for l in dec] == [4, 8]
    # decoder mirrors encoder channels exactly
    assert [(l.in_c, l.out_c) for l in dec] \
        == [(l.out_c, l.in_c) for l in reversed(enc)]


def test_plans_cover_both_halves():
    plans = vae.vae_plans(CFG)
    kinds = [p.spec.kind for p in plans]
    assert kinds == ["conv", "conv", "transposed", "transposed"]
    # every plan carries the batch-bucket route table
    assert all(len(p.routes) > 0 for p in plans)


def test_params_are_superpacked_2d():
    p, s = vae.vae_init(jax.random.PRNGKey(0), CFG)
    for i, plan in enumerate(vae.encoder_plans(CFG)):
        r, ss = plan.spec.kernel_hw
        assert p[f"enc{i}"].shape == (r * ss * plan.spec.in_c,
                                      plan.spec.out_c)
    for i, plan in enumerate(vae.decoder_plans(CFG)):
        assert p[f"dec{i}"].shape == (plan.total_taps * plan.spec.in_c,
                                      plan.spec.out_c)
    assert set(s) == set(p)


def test_apply_shapes_and_finiteness():
    key = jax.random.PRNGKey(0)
    p, _ = vae.vae_init(key, CFG)
    x = jax.random.normal(key, (3, CFG.image_hw, CFG.image_hw, CFG.in_c))
    mu, lv = vae.encode(p, x, CFG)
    assert mu.shape == lv.shape == (3, CFG.latent_dim)
    recon, mu, lv = vae.vae_apply(p, x, key, CFG)
    assert recon.shape == x.shape
    assert np.isfinite(np.asarray(recon)).all()
    imgs = vae.sample(p, key, CFG, n=5)
    assert imgs.shape == (5, CFG.image_hw, CFG.image_hw, CFG.in_c)
    assert (np.abs(np.asarray(imgs)) <= 1.0).all()      # tanh output


def test_decoder_matches_transposed_oracle():
    """The full decoder == a chain of lax transposed-conv oracles run on
    the unpacked HWIO kernels (same nonlinearity schedule)."""
    key = jax.random.PRNGKey(1)
    p, _ = vae.vae_init(key, CFG)
    z = jax.random.normal(key, (2, CFG.latent_dim))
    plans = vae.decoder_plans(CFG)
    h = jax.nn.relu(z @ p["proj"] + p["projb"])
    x = h.reshape(2, CFG.feat_hw, CFG.feat_hw, CFG.feat_c)
    for i, plan in enumerate(plans):
        k = plan.unpack(p[f"dec{i}"])
        x = ref.oracle_conv_transpose2d(
            x, k, strides=plan.spec.strides,
            padding=plan.spec.padding) + p[f"decb{i}"]
        x = jnp.tanh(x) if i == len(plans) - 1 else jax.nn.relu(x)
    assert_close(vae.decode(p, z, CFG), x, tol=1e-3)


def test_elbo_one_step_improves_through_packed_vjps():
    key = jax.random.PRNGKey(0)
    p, _ = vae.vae_init(key, CFG)
    x = jax.random.normal(key, (4, CFG.image_hw, CFG.image_hw, CFG.in_c))
    loss_fn = jax.jit(jax.value_and_grad(
        lambda p: vae.elbo_loss(p, x, key, CFG)))
    l0, g = loss_fn(p)
    # gradients reach every param, including both superpack halves
    assert all(np.isfinite(np.asarray(v)).all() for v in jax.tree.leaves(g))
    assert float(jnp.abs(g["enc0"]).max()) > 0
    assert float(jnp.abs(g["dec0"]).max()) > 0
    p2 = jax.tree.map(lambda a, b: a - 1e-3 * b, p, g)
    l1 = loss_fn(p2)[0]
    assert float(l1) < float(l0)


def test_elbo_kl_term_behaves():
    """beta=0 removes the KL pull: loss reduces to reconstruction only."""
    key = jax.random.PRNGKey(3)
    p, _ = vae.vae_init(key, CFG)
    x = jnp.zeros((2, CFG.image_hw, CFG.image_hw, CFG.in_c))
    full = float(vae.elbo_loss(p, x, key, CFG, beta=1.0))
    recon_only = float(vae.elbo_loss(p, x, key, CFG, beta=0.0))
    assert full >= recon_only                 # KL >= 0
