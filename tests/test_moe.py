"""MoE unit tests: routing, dense combine, balance-bias controller."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.layers import moe as moe_lib


def small_cfg(router="softmax"):
    cfg = registry.get_reduced("dbrx-132b")
    return dataclasses.replace(cfg, router_type=router)


def test_softmax_router_topk_normalized():
    cfg = small_cfg()
    key = jax.random.PRNGKey(0)
    p, _ = moe_lib.moe_init(key, cfg)
    x = jax.random.normal(key, (16, cfg.d_model), jnp.float32)
    w, idx = moe_lib._route(x, p, cfg)
    assert w.shape == (16, cfg.top_k) and idx.shape == (16, cfg.top_k)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert (np.asarray(idx) < cfg.n_experts).all()


def test_sigmoid_bias_router_affects_selection_not_weights():
    cfg = small_cfg("sigmoid_bias")
    key = jax.random.PRNGKey(1)
    p, _ = moe_lib.moe_init(key, cfg)
    x = jax.random.normal(key, (32, cfg.d_model), jnp.float32)
    w0, idx0 = moe_lib._route(x, p, cfg)
    # push bias of expert 0 way up: it must enter everyone's top-k ...
    p2 = dict(p, bias=p["bias"].at[0].add(100.0))
    w1, idx1 = moe_lib._route(x, p2, cfg)
    assert (np.asarray(idx1) == 0).any(axis=-1).all()
    # ... but gate weights still come from the *unbiased* scores
    np.testing.assert_allclose(np.asarray(w1.sum(-1)),
                               cfg.routed_scaling, rtol=1e-4)


def test_dense_moe_is_topk_combination():
    """Dense path == manual per-token expert mixture."""
    cfg = small_cfg()
    key = jax.random.PRNGKey(2)
    p, _ = moe_lib.moe_init(key, cfg)
    x = jax.random.normal(key, (2, 4, cfg.d_model), jnp.bfloat16)
    y = moe_lib.moe_apply_dense(p, x, cfg)
    x2 = x.reshape(-1, cfg.d_model)
    w, idx = moe_lib._route(x2, p, cfg)
    manual = np.zeros((x2.shape[0], cfg.d_model), np.float32)
    for t in range(x2.shape[0]):
        for j in range(cfg.top_k):
            e = int(idx[t, j])
            h = moe_lib._expert_ffn(p["wi"][e], p["wg"][e], p["wo"][e],
                                    x2[t:t + 1], cfg.act)
            manual[t] += float(w[t, j]) * np.asarray(h, np.float32)[0]
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model),
                                          np.float32),
                               manual, rtol=5e-2, atol=5e-2)


def test_balance_bias_controller():
    bias = jnp.zeros((4,))
    load = jnp.asarray([0.7, 0.1, 0.1, 0.1])
    nb = moe_lib.update_balance_bias(bias, load, gamma=0.01)
    assert float(nb[0]) < 0          # overloaded expert pushed down
    assert (np.asarray(nb[1:]) > 0).all()
    idx = jnp.asarray([[0, 1], [0, 2], [0, 3], [0, 1]])
    load2 = moe_lib.expert_load_from_idx(idx, 4)
    np.testing.assert_allclose(np.asarray(load2), [0.5, 0.25, 0.125, 0.125])
