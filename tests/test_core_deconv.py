"""Correctness of the HUGE2 core vs XLA oracles, incl. hypothesis sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (huge_conv2d, huge_conv_transpose2d,
                        huge_dilated_conv2d, untangled_conv2d)
from repro.core import reference as ref

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype)


def assert_close(a, b, tol=2e-5):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# untangled standard / strided / dilated conv vs lax
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.integers(1, 4),
       st.integers(1, 3), st.integers(1, 3), st.integers(0, 2), st.integers(0, 2),
       st.integers(1, 2), st.integers(1, 2))
def test_untangled_conv_matches_oracle(b, r, s, sh, sw, pl, ph, dh, dw):
    h = r * dh - dh + sh * 2 + 2   # big enough for >=1 output
    w = s * dw - dw + sw * 2 + 2
    c, n = 3, 5
    k1, k2 = jax.random.split(jax.random.PRNGKey(b * 1000 + r * 100 + s))
    x = rand(k1, (b, h, w, c))
    k = rand(k2, (r, s, c, n))
    got = untangled_conv2d(x, k, strides=(sh, sw),
                           padding=((pl, ph), (pl, ph)), rhs_dilation=(dh, dw))
    want = ref.oracle_dilated_conv2d(x, k, dilation=(dh, dw), strides=(sh, sw),
                                     padding=((pl, ph), (pl, ph)))
    assert_close(got, want)


# ---------------------------------------------------------------------------
# transposed conv: decomposition + untangling vs oracle
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(1, 2), st.integers(2, 6), st.integers(1, 6), st.integers(1, 4),
       st.integers(0, 4), st.integers(0, 4), st.integers(1, 4), st.integers(1, 4))
def test_conv_transpose_matches_oracle(b, h, r, stride, pl, ph, c, n):
    # keep output size positive
    out = (h - 1) * stride + pl + ph - r + 2
    if out <= 0 or pl >= r or ph >= r:
        return
    k1, k2 = jax.random.split(jax.random.PRNGKey(h * 77 + r * 7 + stride))
    x = rand(k1, (b, h, h + 1, c))
    k = rand(k2, (r, r, c, n))
    got = huge_conv_transpose2d(x, k, (stride, stride), ((pl, ph), (pl, ph)))
    want = ref.oracle_conv_transpose2d(x, k, strides=(stride, stride),
                                       padding=((pl, ph), (pl, ph)))
    assert_close(got, want)


def test_conv_transpose_dcgan_shapes():
    """The exact Table-1 DCGAN layers (stride 2, 5x5, SAME-style 2x out)."""
    for (h, c, n) in [(4, 64, 32), (8, 32, 16), (16, 16, 8)]:
        k1, k2 = jax.random.split(jax.random.PRNGKey(h))
        x = rand(k1, (2, h, h, c))
        k = rand(k2, (5, 5, c, n))
        got = huge_conv_transpose2d(x, k, (2, 2), ((2, 3), (2, 3)))
        want = ref.oracle_conv_transpose2d(x, k, strides=(2, 2),
                                           padding=((2, 3), (2, 3)))
        assert got.shape == (2, 2 * h, 2 * h, n)
        assert_close(got, want)


def test_conv_transpose_stride_gt_kernel():
    """Phases with zero taps (stride > kernel) must emit zeros."""
    x = rand(jax.random.PRNGKey(0), (1, 5, 5, 2))
    k = rand(jax.random.PRNGKey(1), (2, 2, 2, 3))
    got = huge_conv_transpose2d(x, k, (3, 3), ((0, 0), (0, 0)))
    want = ref.oracle_conv_transpose2d(x, k, strides=(3, 3),
                                       padding=((0, 0), (0, 0)))
    assert_close(got, want)


# ---------------------------------------------------------------------------
# naive (DarkNet) baselines also match the oracle — the comparison is fair
# ---------------------------------------------------------------------------

def test_naive_baselines_match_oracle():
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = rand(k1, (2, 6, 7, 3))
    k = rand(k2, (5, 4, 3, 8))
    got = ref.naive_conv_transpose2d(x, k, strides=(2, 2), padding=((2, 1), (3, 2)))
    want = ref.oracle_conv_transpose2d(x, k, strides=(2, 2), padding=((2, 1), (3, 2)))
    assert_close(got, want)
    got = ref.naive_dilated_conv2d(x, k, dilation=(2, 2), padding=((4, 4), (3, 3)))
    want = ref.oracle_dilated_conv2d(x, k, dilation=(2, 2), padding=((4, 4), (3, 3)))
    assert_close(got, want)


# ---------------------------------------------------------------------------
# §3.2.3 training: custom VJPs match autodiff of the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride,r,pad", [(2, 5, 2), (2, 4, 1), (3, 3, 0), (1, 3, 1)])
def test_conv_transpose_vjp_matches_oracle(stride, r, pad):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(stride * 10 + r), 3)
    x = rand(k1, (2, 5, 6, 3))
    k = rand(k2, (r, r, 3, 4))
    pads = ((pad, pad), (pad, pad))

    def f_huge(x, k):
        return huge_conv_transpose2d(x, k, (stride, stride), pads)

    def f_ora(x, k):
        return ref.oracle_conv_transpose2d(x, k, strides=(stride, stride), padding=pads)

    y, vjp_h = jax.vjp(f_huge, x, k)
    y2, vjp_o = jax.vjp(f_ora, x, k)
    assert_close(y, y2)
    dy = rand(k3, y.shape)
    (dx_h, dk_h), (dx_o, dk_o) = vjp_h(dy), vjp_o(dy)
    assert_close(dx_h, dx_o, tol=1e-4)
    assert_close(dk_h, dk_o, tol=1e-4)


@pytest.mark.parametrize("stride,r,pad", [(2, 5, 2), (2, 4, 1), (1, 3, 1), (3, 4, 0)])
def test_conv2d_vjp_matches_oracle(stride, r, pad):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(stride * 100 + r), 3)
    x = rand(k1, (2, 9, 10, 3))
    k = rand(k2, (r, r, 3, 4))
    pads = ((pad, pad), (pad, pad))

    def f_huge(x, k):
        return huge_conv2d(x, k, (stride, stride), pads)

    def f_ora(x, k):
        return jax.lax.conv_general_dilated(
            x, k, window_strides=(stride, stride), padding=pads,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))

    y, vjp_h = jax.vjp(f_huge, x, k)
    y2, vjp_o = jax.vjp(f_ora, x, k)
    assert_close(y, y2)
    dy = rand(k3, y.shape)
    (dx_h, dk_h), (dx_o, dk_o) = vjp_h(dy), vjp_o(dy)
    assert_close(dx_h, dx_o, tol=1e-4)
    assert_close(dk_h, dk_o, tol=1e-4)


def test_dilated_conv_autodiff():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(9), 3)
    x = rand(k1, (1, 9, 9, 2))
    k = rand(k2, (3, 3, 2, 4))

    def f_huge(x, k):
        return huge_dilated_conv2d(x, k, dilation=(2, 2), padding=((2, 2), (2, 2)))

    def f_ora(x, k):
        return ref.oracle_dilated_conv2d(x, k, dilation=(2, 2), padding=((2, 2), (2, 2)))

    y, vjp_h = jax.vjp(f_huge, x, k)
    y2, vjp_o = jax.vjp(f_ora, x, k)
    assert_close(y, y2)
    dy = rand(k3, y.shape)
    for a, b in zip(vjp_h(dy), vjp_o(dy)):
        assert_close(a, b, tol=1e-4)


def test_flop_advantage_bookkeeping():
    """Decomposition does s^2 fewer MACs than the zero-inserted naive conv."""
    h = w = 8; r = s = 5; c, n, stride = 16, 8, 2
    naive_macs = ((h - 1) * stride + 1 + 4) ** 2 * r * s * c * n  # dense on x_hat
    huge_macs = 0
    from repro.core.decompose import plan_phases_1d
    for p_h in plan_phases_1d(h, r, stride, (2, 2)):
        for p_w in plan_phases_1d(w, s, stride, (2, 2)):
            huge_macs += p_h.out_size * p_w.out_size * p_h.taps * p_w.taps * c * n
    assert naive_macs / huge_macs > (stride * stride) * 0.8
