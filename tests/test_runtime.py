"""Checkpoint manager, data pipeline, grad compression.  (The fault
runtime's unit coverage moved to tests/test_fault.py.)"""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import compress
from repro.train.checkpoint import CheckpointManager
from repro.train.data import GANPipeline, Prefetcher, TokenPipeline


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def make_state(v=0.0):
    return {"params": {"w": jnp.full((4, 4), v), "b": jnp.zeros((3,))},
            "step": jnp.asarray(int(v), jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    ckpt.save(5, make_state(5.0))
    assert ckpt.latest_step() == 5
    restored = ckpt.restore(make_state(0.0))
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 5.0)
    assert int(restored["step"]) == 5


def test_checkpoint_gc_and_latest(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ckpt.save(s, make_state(float(s)))
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert sorted(dirs) == ["step_00000003", "step_00000004"]
    assert ckpt.latest_step() == 4


def test_checkpoint_async(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    ckpt.save(7, make_state(7.0))
    ckpt.wait()
    assert ckpt.latest_step() == 7


def test_checkpoint_restore_with_dtype_cast(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), async_save=False)
    state = {"w": jnp.ones((4,), jnp.bfloat16)}
    ckpt.save(1, state)
    restored = ckpt.restore({"w": jnp.zeros((4,), jnp.bfloat16)})
    assert restored["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_by_step():
    from repro.configs import registry
    cfg = registry.get_reduced("llama3.2-1b")
    p1 = TokenPipeline(cfg, 4, 16, seed=7)
    p2 = TokenPipeline(cfg, 4, 16, seed=7)
    b1, b2 = p1.batch_at(123), p2.batch_at(123)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    b3 = p1.batch_at(124)
    assert not np.array_equal(b1["inputs"], b3["inputs"])


def test_prefetcher_yields_in_order():
    from repro.configs import registry
    cfg = registry.get_reduced("llama3.2-1b")
    pipe = TokenPipeline(cfg, 2, 8, seed=1)
    pf = Prefetcher(pipe, start_step=0, depth=2)
    try:
        a = pf.next()
        np.testing.assert_array_equal(a["inputs"], pipe.batch_at(0)["inputs"])
        b = pf.next()
        np.testing.assert_array_equal(b["inputs"], pipe.batch_at(1)["inputs"])
    finally:
        pf.close()


def test_gan_pipeline_shapes():
    from repro.models.gan import DCGAN
    p = GANPipeline(DCGAN, 4, 64)
    b = p.batch_at(0)
    assert b["z"].shape == (4, 100) and b["real"].shape == (4, 64, 64, 3)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bounded():
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    q, scale, err = compress.quantize_int8(g, jnp.zeros_like(g))
    deq = compress.dequantize_int8(q, scale)
    max_err = float(jnp.max(jnp.abs(deq - g)))
    assert max_err <= float(scale) / 2 + 1e-6
    np.testing.assert_allclose(np.asarray(err), np.asarray(g - deq),
                               atol=1e-6)


def test_error_feedback_reduces_bias():
    """With error feedback the *averaged* quantization error shrinks vs
    without it (unbiased over steps)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.standard_normal(512) * 1e-3, jnp.float32)
    err = jnp.zeros_like(g_true)
    acc_fb, acc_nofb = [], []
    for _ in range(50):
        q, s, err = compress.quantize_int8(g_true, err)
        acc_fb.append(compress.dequantize_int8(q, s))
        q2, s2, _ = compress.quantize_int8(g_true, jnp.zeros_like(g_true))
        acc_nofb.append(compress.dequantize_int8(q2, s2))
    mean_fb = np.mean(np.stack(acc_fb), axis=0)
    mean_nofb = np.mean(np.stack(acc_nofb), axis=0)
    assert (np.abs(mean_fb - np.asarray(g_true)).mean()
            <= np.abs(mean_nofb - np.asarray(g_true)).mean() + 1e-9)
