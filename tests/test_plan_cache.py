"""Plan/executor engine: cache keying, packed execution + VJP vs the XLA
oracle, and parity with the legacy pre-decomposed serving path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import reference as ref
from repro.core.engine import (huge_conv_transpose2d,
                               huge_conv_transpose2d_pre,
                               precompute_transposed_weights)
from repro.core.plan import (ConvSpec, conv_spec, plan_cache_clear,
                             plan_cache_info, plan_conv)

BASE = ConvSpec(kind="transposed", in_hw=(5, 6), in_c=4, out_c=3,
                kernel_hw=(4, 4), strides=(2, 2), padding=((1, 2), (1, 2)))


def assert_close(a, b, tol=1e-4):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# cache keying
# ---------------------------------------------------------------------------

def test_plan_cache_hit_on_identical_spec():
    plan_cache_clear()
    p1 = plan_conv(BASE)
    p2 = plan_conv(dataclasses.replace(BASE))     # equal, distinct instance
    assert p1 is p2
    info = plan_cache_info()
    assert info.misses == 1 and info.hits == 1


@pytest.mark.parametrize("field,value", [
    ("in_hw", (6, 6)),
    ("kernel_hw", (3, 3)),
    ("strides", (3, 2)),
    ("padding", ((2, 1), (1, 2))),
    ("dtype", "bfloat16"),
    ("backend", "pallas"),
])
def test_plan_cache_misses_on_changed_field(field, value):
    plan_cache_clear()
    p1 = plan_conv(BASE)
    p2 = plan_conv(dataclasses.replace(BASE, **{field: value}))
    assert p1 is not p2
    assert plan_cache_info().misses == 2


def test_plan_cache_miss_on_dilation():
    plan_cache_clear()
    base = ConvSpec(kind="dilated", in_hw=(9, 9), in_c=2, out_c=3,
                    kernel_hw=(3, 3), padding=((2, 2), (2, 2)))
    p1 = plan_conv(base)
    p2 = plan_conv(dataclasses.replace(base, dilation=(2, 2)))
    assert p1 is not p2 and plan_cache_info().misses == 2


def test_engine_wrapper_reuses_cached_plan():
    plan_cache_clear()
    x = jnp.zeros((1, 5, 5, 2))
    k = jnp.zeros((3, 3, 2, 3))
    huge_conv_transpose2d(x, k, (2, 2), ((1, 1), (1, 1)))
    misses = plan_cache_info().misses
    huge_conv_transpose2d(x, k, (2, 2), ((1, 1), (1, 1)))
    info = plan_cache_info()
    assert info.misses == misses and info.hits >= 1


def test_spec_normalization_is_cache_canonical():
    """int-pair and nested paddings of the same geometry key identically."""
    s1 = conv_spec("transposed", (1, 4, 4, 2), (3, 3, 2, 3),
                   strides=(2, 2), padding=(1, 1))
    s2 = conv_spec("transposed", (1, 4, 4, 2), (3, 3, 2, 3),
                   strides=(2, 2), padding=((1, 1), (1, 1)))
    assert s1 == s2 and plan_conv(s1) is plan_conv(s2)


# ---------------------------------------------------------------------------
# planned execution + VJP vs the XLA oracle (odd strides, asymmetric padding)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,w,r,s,sh,sw,pads", [
    (4, 5, 5, 4, 3, 2, ((2, 3), (1, 0))),     # odd stride, asymmetric
    (5, 4, 3, 3, 3, 3, ((0, 2), (1, 1))),
    (6, 6, 2, 2, 3, 3, ((0, 0), (0, 0))),     # stride > kernel: empty phases
    (5, 5, 5, 5, 1, 1, ((2, 2), (2, 2))),     # stride 1 degenerate
])
def test_planned_forward_and_vjp_match_oracle(h, w, r, s, sh, sw, pads):
    key = jax.random.PRNGKey(h * 100 + r * 10 + sh)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (2, h, w, 3), jnp.float32)
    k = jax.random.normal(k2, (r, s, 3, 4), jnp.float32)
    plan = plan_conv(conv_spec("transposed", x.shape, k.shape,
                               strides=(sh, sw), padding=pads))
    packed = plan.pack(k)

    y, vjp = jax.vjp(plan.apply, x, packed)
    y_o, vjp_o = jax.vjp(
        lambda x, k: ref.oracle_conv_transpose2d(
            x, k, strides=(sh, sw), padding=pads), x, k)
    assert_close(y, y_o)
    dy = jax.random.normal(k3, y.shape)
    (dx, dpacked), (dx_o, dk_o) = vjp(dy), vjp_o(dy)
    assert_close(dx, dx_o)
    # packed dK regroups the oracle dK phase-by-phase; unpack to compare
    assert_close(plan.unpack(dpacked), dk_o)


def test_table1_layer_geometry_forward_and_vjp():
    """Table-1 layer geometry (channel-reduced for CPU runtime): planned
    forward + VJP within 1e-4 of the oracle."""
    for (h, k_sz, stride) in [(4, 5, 2), (8, 5, 2), (16, 5, 2), (32, 5, 2),
                              (8, 4, 2), (16, 4, 2)]:
        pl = max(0, (k_sz - stride + 1) // 2)
        ph = k_sz + stride - 2 - pl
        pads = ((pl, ph), (pl, ph))
        key = jax.random.PRNGKey(h + k_sz)
        k1, k2, k3 = jax.random.split(key, 3)
        x = jax.random.normal(k1, (1, h, h, 16), jnp.float32)
        k = jax.random.normal(k2, (k_sz, k_sz, 16, 8), jnp.float32)
        plan = plan_conv(conv_spec("transposed", x.shape, k.shape,
                                   strides=(stride, stride), padding=pads))
        packed = plan.pack(k)
        y, vjp = jax.vjp(plan.apply, x, packed)
        y_o, vjp_o = jax.vjp(
            lambda x, k: ref.oracle_conv_transpose2d(
                x, k, strides=(stride, stride), padding=pads), x, k)
        assert y.shape == (1, stride * h, stride * h, 8)
        assert_close(y, y_o)
        dy = jax.random.normal(k3, y.shape)
        (dx, dpacked), (dx_o, dk_o) = vjp(dy), vjp_o(dy)
        assert_close(dx, dx_o)
        assert_close(plan.unpack(dpacked), dk_o)


def test_pack_unpack_roundtrip():
    k = jax.random.normal(jax.random.PRNGKey(0), (5, 4, 3, 2), jnp.float32)
    plan = plan_conv(conv_spec("transposed", (1, 4, 4, 3), k.shape,
                               strides=(2, 3), padding=((2, 2), (1, 1))))
    np.testing.assert_array_equal(np.asarray(plan.unpack(plan.pack(k))),
                                  np.asarray(k))


# ---------------------------------------------------------------------------
# parity with the legacy pre-decomposed path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,r,stride,pad", [
    (4, 5, 2, (2, 3)), (8, 4, 2, (1, 2)), (5, 3, 3, (0, 0)), (6, 3, 1, (1, 1)),
])
def test_planned_matches_legacy_pre(h, r, stride, pad):
    key = jax.random.PRNGKey(h * 10 + r)
    x = jax.random.normal(key, (2, h, h + 1, 6), jnp.float32)
    k = jax.random.normal(key, (r, r, 6, 8), jnp.float32)
    pads = (pad, pad)
    subs = precompute_transposed_weights(k, (stride, stride), pads)
    legacy = huge_conv_transpose2d_pre(x, subs, (r, r), (stride, stride), pads)
    plan = plan_conv(conv_spec("transposed", x.shape, k.shape,
                               strides=(stride, stride), padding=pads))
    planned = plan.apply(x, plan.pack(k))
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(planned))
    # and both match the full-kernel wrapper
    assert_close(huge_conv_transpose2d(x, k, (stride, stride), pads), planned,
                 tol=2e-4)
