"""Continuous batcher, LR schedule, and file-backed data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer as tfm
from repro.serving.batcher import ContinuousBatcher, Request
from repro.train import optim as opt
from repro.train.data import FileTokenPipeline
from repro.train.schedule import ScheduleConfig, lr_at


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

def test_continuous_batcher_completes_and_matches_sequential():
    cfg = registry.get_reduced("llama3.2-1b")
    params, _ = tfm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, p).astype(np.int32)
               for p in (3, 5, 2, 4, 3)]
    cb = ContinuousBatcher(cfg, params, slots=2, max_len=16)
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=i, prompt=p, max_new=4))
    steps = cb.run()
    assert len(cb.done) == 5
    st = cb.stats()
    assert st["completed"] == 5 and st["p50_latency_s"] > 0
    # 2 slots, 5 requests: continuous batching must beat one-at-a-time steps
    sequential_steps = sum(len(p) + 4 - 1 for p in prompts)
    assert steps < sequential_steps

    # correctness: batcher greedy output == manual greedy decode
    r0 = next(r for r in cb.done if r.rid == 0)
    cache, _ = tfm.init_cache(cfg, 1, 16)
    toks = list(prompts[0])
    out = []
    for t in range(len(prompts[0]) + 3):
        cur = np.array([[toks[t] if t < len(toks) else out[-1]]], np.int32)
        logits, cache = tfm.decode_step(params, cache, jnp.asarray(cur), t,
                                        cfg)
        if t >= len(prompts[0]) - 1:
            out.append(int(np.argmax(np.asarray(logits[0, -1]))))
    assert r0.out == out, (r0.out, out)


# ---------------------------------------------------------------------------
# LR schedule
# ---------------------------------------------------------------------------

def test_schedule_warmup_and_decay():
    sc = ScheduleConfig(peak_lr=1.0, warmup_steps=10, total_steps=110,
                        kind="cosine", final_frac=0.1)
    assert float(lr_at(0, sc)) == 0.0
    assert float(lr_at(5, sc)) == pytest.approx(0.5)
    assert float(lr_at(10, sc)) == pytest.approx(1.0)
    assert float(lr_at(60, sc)) == pytest.approx(0.55, abs=0.02)  # mid-cosine
    assert float(lr_at(110, sc)) == pytest.approx(0.1)
    lin = ScheduleConfig(peak_lr=2.0, warmup_steps=0, total_steps=100,
                         kind="linear", final_frac=0.0)
    assert float(lr_at(50, lin)) == pytest.approx(1.0)


def test_adamw_uses_schedule():
    sc = ScheduleConfig(peak_lr=0.1, warmup_steps=100, total_steps=1000)
    cfg = opt.OptConfig(lr=999.0, schedule=sc, weight_decay=0.0)
    params = {"w": jnp.ones((4,))}
    state, _ = opt.adamw_init(params)
    g = {"w": jnp.ones((4,))}
    newp, state, _ = opt.adamw_update(g, state, params, cfg)
    # at step 1 of warmup, lr ~ 0.001 -> tiny update, NOT the bogus lr=999
    delta = float(jnp.abs(newp["w"] - params["w"]).max())
    assert delta < 0.01


# ---------------------------------------------------------------------------
# file-backed token pipeline
# ---------------------------------------------------------------------------

def test_file_pipeline_roundtrip(tmp_path):
    cfg = registry.get_reduced("llama3.2-1b")
    path = os.path.join(tmp_path, "tokens.bin")
    toks = np.arange(10_000, dtype=np.uint32)
    FileTokenPipeline.write_token_file(path, toks)
    pipe = FileTokenPipeline(path, cfg, batch=4, seq=16, seed=3)
    b0 = pipe.batch_at(0)
    assert b0["inputs"].shape == (4, 16)
    # targets are inputs shifted by one position in the source stream
    np.testing.assert_array_equal(b0["inputs"][:, 1:], b0["targets"][:, :-1])
    # deterministic by step
    pipe2 = FileTokenPipeline(path, cfg, batch=4, seq=16, seed=3)
    np.testing.assert_array_equal(b0["inputs"], pipe2.batch_at(0)["inputs"])
    assert not np.array_equal(b0["inputs"], pipe.batch_at(1)["inputs"])
    # tokens bounded by vocab
    assert (b0["inputs"] < cfg.vocab_size).all()
