"""Property tests on the decomposition invariants (paper §3.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.decompose import (decompose_kernel, plan_phases_1d,
                                  transposed_out_size)


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 12), st.integers(1, 7), st.integers(1, 5),
       st.integers(0, 6), st.integers(0, 6))
def test_phase_plans_partition_output(h, k, s, pl, ph):
    out = transposed_out_size(h, k, s, (pl, ph))
    if out <= 0:
        return
    plans = plan_phases_1d(h, k, s, (pl, ph))
    assert len(plans) == s
    # phase sizes partition the output exactly
    assert sum(p.out_size for p in plans) == out
    for q, p in enumerate(plans):
        assert p.phase == q
        # U_q = |{o in [0, out) : o % s == q}|
        assert p.out_size == len([o for o in range(out) if o % s == q])
        # taps of phase q are exactly the kernel rows == rho (mod s)
        assert p.taps == len(range(p.rho, k, s))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 6), st.integers(1, 6), st.integers(1, 4),
       st.integers(1, 4), st.integers(0, 3), st.integers(0, 3))
def test_decomposed_kernels_partition_taps(r, s_k, sh, sw, plh, plw):
    """Every kernel tap appears in exactly one phase sub-kernel."""
    k = jnp.arange(r * s_k * 2 * 3, dtype=jnp.float32).reshape(r, s_k, 2, 3)
    subs = decompose_kernel(k, (sh, sw), ((plh, plh), (plw, plw)))
    total = sum(int(np.prod(sub.shape[:2])) for sub in subs.values())
    assert total == r * s_k
    # values cover the original kernel exactly once
    seen = []
    for sub in subs.values():
        seen.extend(np.asarray(sub).reshape(-1, 2, 3)[:, 0, 0].tolist())
    orig = np.asarray(k)[:, :, 0, 0].reshape(-1).tolist()
    assert sorted(seen) == sorted(orig)
