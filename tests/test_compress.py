"""``runtime.compress``: the int8 quantization primitives behind both
gradient compression and the quantized-superpack checkpoint path.

What this file proves:

- **error feedback converges**: repeatedly quantizing the same gradient
  with the residual fed back recovers the true sum (the 1-bit-SGD
  property) — the accumulated dequantized mean tracks the exact mean far
  tighter than quantizing without feedback, and a constant gradient's
  *accumulated* error stays bounded while the no-feedback variant's bias
  grows linearly with step count.
- **scale edge cases**: all-zero rows (scale floors, q == 0, exact
  round-trip), subnormal rows (finite scale, no inf/nan anywhere), and
  ±float32-max rows (no overflow; the extreme element lands on ±127 and
  round-trips within one step).
- **one home for the rounding rules**: ``ConvPlan.pack(wdtype='int8')``
  produces bit-identical codes and scales to calling
  ``quantize_int8_rows`` on the f32 superpack directly — the checkpoint /
  superpack path *reuses* these primitives rather than duplicating them.
(The cross-pod allreduce itself is exercised on a forced multi-device
mesh in ``test_distributed.py``.)

No hypothesis dependency — this file must run everywhere tier-1 runs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.compress import (_SCALE_FLOOR, dequantize_int8,
                                    init_error_state, quantize_int8,
                                    quantize_int8_rows)


# ---------------------------------------------------------------------------
# error-feedback convergence
# ---------------------------------------------------------------------------

def test_error_feedback_recovers_constant_gradient():
    """Quantizing the SAME gradient T times with feedback: the summed
    dequantized signal approaches T·g with bounded (not growing) error,
    while no-feedback quantization repeats one biased step T times."""
    g = jax.random.normal(jax.random.PRNGKey(0), (64,), jnp.float32) * 0.1
    T = 50
    err = jnp.zeros_like(g)
    acc_fb = jnp.zeros_like(g)
    for _ in range(T):
        q, scale, err = quantize_int8(g, err)
        acc_fb = acc_fb + dequantize_int8(q, scale)
    # no feedback: the same biased step T times
    q0, s0, _ = quantize_int8(g, jnp.zeros_like(g))
    acc_nofb = T * dequantize_int8(q0, s0)

    exact = T * g
    err_fb = float(jnp.max(jnp.abs(acc_fb - exact)))
    err_nofb = float(jnp.max(jnp.abs(acc_nofb - exact)))
    # feedback error stays within ~one quantization step of the LAST
    # residual; no-feedback bias is T·(per-step error) — linear in T
    step = float(s0) / 2
    assert err_fb <= 4 * step, (err_fb, step)
    assert err_nofb >= 0.5 * T * step or err_nofb > 4 * err_fb
    assert err_fb < err_nofb / 5


def test_error_feedback_mean_converges_over_random_grads():
    """Over a random gradient stream, the feedback path's cumulative
    dequantized sum tracks the exact cumulative sum to within one step
    (the residual), independent of stream length."""
    key = jax.random.PRNGKey(1)
    err = jnp.zeros((32,), jnp.float32)
    acc_q = np.zeros((32,), np.float64)
    acc = np.zeros((32,), np.float64)
    worst_step = 0.0
    for t in range(30):
        key, k = jax.random.split(key)
        g = jax.random.normal(k, (32,), jnp.float32)
        q, scale, err = quantize_int8(g, err)
        acc_q += np.asarray(dequantize_int8(q, scale), np.float64)
        acc += np.asarray(g, np.float64)
        worst_step = max(worst_step, float(scale))
        # invariant: sum(deq) + err == sum(g) up to f32 round-off
        drift = np.max(np.abs(acc_q + np.asarray(err, np.float64) - acc))
        assert drift <= 1e-3 * (t + 1), drift
    # the residual itself is bounded by one quantization step
    assert float(jnp.max(jnp.abs(err))) <= worst_step


# ---------------------------------------------------------------------------
# scale edge cases: all-zero, subnormal, ±max
# ---------------------------------------------------------------------------

def test_all_zero_rows_floor_scale_and_roundtrip_exact():
    w = jnp.zeros((4, 8), jnp.float32)
    q, scale = quantize_int8_rows(w)
    assert np.all(np.asarray(q) == 0)
    # the floor is the smallest NORMAL f32 (applied after the /127), so it
    # survives XLA's subnormal flush and the quantizing divide is never 0/0
    assert np.all(np.asarray(scale) == np.float32(_SCALE_FLOOR))
    np.testing.assert_array_equal(np.asarray(dequantize_int8(q, scale)),
                                  np.zeros((4, 8), np.float32))


def test_subnormal_rows_stay_finite():
    tiny = np.float32(_SCALE_FLOOR)          # smallest normal f32
    w = jnp.array([[tiny, -tiny / 2, 0.0, tiny / 4]], jnp.float32)
    q, scale = quantize_int8_rows(w)
    deq = dequantize_int8(q, scale)
    assert np.all(np.isfinite(np.asarray(scale)))
    assert np.all(np.isfinite(np.asarray(deq)))
    assert float(scale[0, 0]) >= _SCALE_FLOOR
    # error within one step even in the subnormal regime
    assert np.max(np.abs(np.asarray(deq) - np.asarray(w))) \
        <= 0.5 * float(scale[0, 0]) * (1 + 1e-5) + _SCALE_FLOOR


def test_float32_max_rows_do_not_overflow():
    fmax = np.float32(np.finfo(np.float32).max)
    w = jnp.array([[fmax, -fmax, fmax / 3, 0.0]], jnp.float32)
    q, scale = quantize_int8_rows(w)
    deq = np.asarray(dequantize_int8(q, scale))
    assert np.all(np.isfinite(np.asarray(scale)))
    assert np.all(np.isfinite(deq))
    assert int(q[0, 0]) == 127 and int(q[0, 1]) == -127
    # extreme elements round-trip to within one step of the grid
    step = float(scale[0, 0])
    assert np.max(np.abs(deq - np.asarray(w, np.float64))) <= step
    # per-tensor flavor too (gradient spikes must not inf the wire)
    qg, sg, err = quantize_int8(w[0], jnp.zeros((4,), jnp.float32))
    assert np.isfinite(float(sg)) and np.all(np.isfinite(np.asarray(err)))


def test_clipping_is_symmetric_127():
    """Codes never reach -128: the symmetric grid keeps dequant unbiased."""
    w = jax.random.normal(jax.random.PRNGKey(2), (16, 16), jnp.float32)
    q, _ = quantize_int8_rows(w)
    assert int(jnp.min(q)) >= -127 and int(jnp.max(q)) <= 127


# ---------------------------------------------------------------------------
# the checkpoint / superpack path REUSES these primitives
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,strides,pads", [
    ("conv", (1, 1), ((1, 1), (1, 1))),
    ("transposed", (2, 2), ((2, 3), (2, 3))),
])
def test_plan_pack_reuses_quantize_int8_rows(kind, strides, pads):
    """``ConvPlan.pack`` under ``wdtype='int8'`` == ``quantize_int8_rows``
    on the f32 superpack, bit for bit (codes AND scales) — one module owns
    the rounding/clipping/floor rules for both entry points."""
    from repro.core.plan import conv_spec, plan_conv
    r = 5 if kind == "transposed" else 3
    kern = jax.random.normal(jax.random.PRNGKey(3), (r, r, 6, 4),
                             jnp.float32)
    spec = conv_spec(kind, (1, 6, 6, 6), kern.shape, strides=strides,
                     padding=pads)
    pf = plan_conv(spec)
    pq = plan_conv(dataclasses.replace(spec, wdtype="int8"))
    wq = pq.pack(kern)
    q_want, s_want = quantize_int8_rows(pf.pack(kern))
    np.testing.assert_array_equal(np.asarray(wq.q), np.asarray(q_want))
    np.testing.assert_array_equal(np.asarray(wq.scale), np.asarray(s_want))
    # and unpack dequantizes through the same shared primitive
    np.testing.assert_array_equal(
        np.asarray(pq.unpack(wq)),
        np.asarray(pf.unpack(dequantize_int8(wq.q, wq.scale))))


def test_init_error_state_matches_tree():
    params = {"a": jnp.ones((3, 2)), "b": jnp.zeros((5,))}
    errs = init_error_state(params)
    assert errs["a"].shape == (3, 2) and errs["b"].shape == (5,)
    assert all(float(jnp.max(jnp.abs(e))) == 0.0 for e in errs.values())
