"""Dynamic image batcher: bucket coalescing, deadline flush, tail padding,
cost-aware launch planning, and the shared latency metrics."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.plan import BATCH_BUCKETS
from repro.serving.image_batcher import DynamicImageBatcher, ImageRequest
from repro.serving.metrics import format_stats, latency_stats


def echo_batcher(**kw):
    """Serve fn that tags each row with its own sum — output rows map 1:1
    onto input rows, so request/response pairing is checkable."""
    return DynamicImageBatcher(lambda x: x * 2.0, **kw)


def reqs(n, dim=3):
    return [ImageRequest(rid=i, payload=np.full((dim,), float(i), np.float32))
            for i in range(n)]


def test_requests_map_to_their_own_outputs():
    b = echo_batcher()
    done = b.run(reqs(11))
    assert len(done) == 11
    for r in done:
        np.testing.assert_array_equal(r.out, np.full((3,), 2.0 * r.rid))
        assert r.t_done is not None and r.latency_s >= 0


def test_burst_coalesces_into_buckets_with_tail_padding():
    b = echo_batcher()
    b.run(reqs(11))
    # 11 -> one bucket-16 launch (no measured costs: round-up policy)
    assert b.launches == [(16, 11)]
    st = b.stats()
    assert st["completed"] == 11 and st["launches"] == 1
    assert st["pad_fraction"] == pytest.approx(5 / 16)
    assert st["throughput_rps"] > 0
    assert set(st["bucket_histogram"]) == set(BATCH_BUCKETS)


def test_pump_waits_for_deadline_then_flushes():
    b = echo_batcher(max_wait_ms=10_000)
    for r in reqs(2):
        b.submit(r)
    assert b.pump() == []                    # still coalescing
    assert len(b.queue) == 2
    done = b.pump(drain=True)                # deadline override
    assert len(done) == 2 and not b.queue


def test_zero_wait_launches_immediately():
    b = echo_batcher(max_wait_ms=0.0)
    b.submit(reqs(1)[0])
    assert len(b.pump()) == 1


def test_full_bucket_launches_before_deadline():
    b = echo_batcher(max_wait_ms=10_000)
    for r in reqs(BATCH_BUCKETS[-1]):
        b.submit(r)
    assert len(b.pump()) > 0                 # full largest bucket: go now


def test_cost_aware_cover_minimizes_measured_cost():
    b = echo_batcher()
    b.bucket_cost_s = {1: 1.0, 4: 2.0, 16: 7.0, 64: 100.0}
    b._sched_memo = {0: (0.0, 0)}
    assert sorted(b._plan_cover(5)) == [1, 4]          # 3.0 beats pad-to-16
    assert b._plan_cover(16) == (16,)                  # 7.0 beats 4x4 = 8.0
    assert sorted(b._plan_cover(20)) == [4, 16]
    assert b._first_launch_size(5) == 4                # biggest chunk first
    # without costs: round-up-to-bucket
    b2 = echo_batcher()
    assert b2._first_launch_size(5) == 16


def test_cost_aware_schedule_drives_launches():
    b = echo_batcher()
    b.bucket_cost_s = {1: 1.0, 4: 2.0, 16: 7.0, 64: 100.0}
    b._sched_memo = {0: (0.0, 0)}
    b.run(reqs(5))
    assert b.launches == [(4, 4), (1, 1)]              # split, not pad-to-16


def test_warmup_measures_every_bucket():
    b = echo_batcher(buckets=(1, 4))
    b.warmup(np.zeros((3,), np.float32))
    assert set(b.bucket_cost_s) == {1, 4}
    assert all(v > 0 for v in b.bucket_cost_s.values())


def test_warmup_without_shape_raises():
    with pytest.raises(ValueError):
        echo_batcher().warmup()


def test_latency_stats_shared_math():
    lat = [0.010, 0.020, 0.030, 0.040]
    st = latency_stats(lat, window_s=0.1)
    assert st["completed"] == 4
    assert st["p50_ms"] == pytest.approx(25.0)
    assert st["p95_ms"] == pytest.approx(np.percentile(lat, 95) * 1e3)
    assert st["throughput_rps"] == pytest.approx(40.0)
    assert "p99" in format_stats(st)
    empty = latency_stats([])
    assert empty["completed"] == 0 and empty["p99_ms"] == 0.0


def test_image_payloads_roundtrip():
    """Segmentation-shaped (H, W, C) payloads batch just as well, and the
    jitted fn may change the output rank (class-map outputs)."""
    b = DynamicImageBatcher(lambda x: jnp.argmax(x, axis=-1),
                            buckets=(1, 4))
    rng = np.random.default_rng(0)
    rs = [ImageRequest(rid=i, payload=rng.uniform(
        -1, 1, (5, 5, 3)).astype(np.float32)) for i in range(3)]
    done = b.run(rs)
    for r in done:
        np.testing.assert_array_equal(r.out, np.argmax(r.payload, axis=-1))
