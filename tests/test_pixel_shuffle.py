"""The sub-pixel ('pixel_shuffle') transposed route.

What this file proves:

- **eligibility algebra**: the plan-time rewrite fires exactly when every
  phase shares its tap footprint, pad, and output extent — ``k % s == 0``
  'SAME' ``deconv_padding`` geometry (k=4/s=2) qualifies; k=5/s=2 (DCGAN,
  unequal per-phase tap counts) and k=3/s=2 do not.
- **byte gate**: the rewrite's stacked-tap buffer obeys the same
  ``_PLANE_BYTES_MAX`` cap as every other route verdict, degrading to the
  transposed fallbacks at buckets where ``4·B·T·H·W·C`` busts it.
- **forward parity** against the float64 lhs-dilation oracle AND against
  the same plan forced onto the route it rewrites — the rewrite is
  algebra, not a different convolution.
- **VJP parity**: ``jax.vjp`` through a pixel_shuffle plan matches the
  lax oracle for ``dx`` and the unpacked ``dK`` (the transposed backward
  is path-independent, so the sub-pixel forward must not perturb it).
- **jaxpr proof**: the route lowers to exactly ONE ``dot_general``, ONE
  ``transpose`` (the depth-to-space permute), and ZERO
  ``conv_general_dilated`` — the claimed 'dense conv + depth-to-space'
  shape, with no hidden convolutions or extra data movement.
- **int8**: the quantized twin routes identically and its executor output
  matches the twin's fallback route bit-for-bit (same dequantized GEMM
  operand, different loop order).
- **fixture pin**: the committed golden route table records
  pixel_shuffle verdicts for real zoo geometry (fig7 k=4/s=2 sites and
  the U-Net ups), so a heuristic regression is a visible fixture diff.
"""
import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.plan as planmod
from repro.core import reference as ref
from repro.core.plan import ConvSpec, Route, conv_spec, plan_conv
from repro.models.gan import deconv_padding

from tests.conftest import assert_close, count_eqns, plane_bytes_cap
from tests.test_quantized import transposed_oracle_f64

FIXTURE = pathlib.Path(__file__).parent / "fixtures" / "route_table.json"


def sp_spec(k=4, s=2, hw=8, c=16, n=8, backend="xla", **kw):
    return conv_spec("transposed", (1, hw, hw, c), (k, k, c, n),
                     strides=(s, s), padding=deconv_padding(k, s),
                     backend=backend, **kw)


def rand_xk(spec, seed=0):
    kx, kk = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (2,) + spec.in_hw + (spec.in_c,), jnp.float32)
    k = jax.random.normal(kk, spec.kernel_hw + (spec.in_c, spec.out_c),
                          jnp.float32)
    return x, k


# ---------------------------------------------------------------------------
# eligibility + byte gate
# ---------------------------------------------------------------------------

def test_k4s2_routes_pixel_shuffle_at_every_bucket():
    """'SAME' k%s==0 geometry: all phases share taps/pad/extent, so the
    sub-pixel rewrite wins every bucket under the default cap."""
    plan = plan_conv(sp_spec())
    assert [r.path for r in plan.routes] == ["pixel_shuffle"] * 4
    # the rewrite is a plan-time verdict, not a tiling: no tile metadata
    assert all(r.tiles is None and r.dev_tiles is None for r in plan.routes)


@pytest.mark.parametrize("k,s", [(5, 2), (3, 2)])
def test_unequal_phase_footprints_are_ineligible(k, s):
    """k=5/s=2 (DCGAN) and k=3/s=2 split their taps unevenly across
    phases — no shared dense kernel exists, so the route must not fire."""
    plan = plan_conv(sp_spec(k=k, s=s))
    assert "pixel_shuffle" not in {r.path for r in plan.routes}
    assert planmod._pixel_shuffle_geom(plan.spec, plan.phases) is None


def test_byte_cap_gates_the_stacked_tap_buffer():
    """The (T,B,H,W,C) stack obeys _PLANE_BYTES_MAX like every verdict:
    cap it to fit B=4 but not B=16 and the large buckets fall back."""
    spec = sp_spec()
    th = spec.kernel_hw[0] // spec.strides[0]
    per_b = 4 * th * th * spec.in_hw[0] * spec.in_hw[1] * spec.in_c
    with plane_bytes_cap(4 * per_b):
        plan = plan_conv(spec)
    paths = {r.batch: r.path for r in plan.routes}
    assert paths[1] == paths[4] == "pixel_shuffle"
    assert paths[16] != "pixel_shuffle" and paths[64] != "pixel_shuffle"


# ---------------------------------------------------------------------------
# parity: f64 oracle, the rewritten route, and the VJP
# ---------------------------------------------------------------------------

def test_fwd_matches_f64_oracle_and_rewritten_route():
    spec = sp_spec()
    plan = plan_conv(spec)
    x, k = rand_xk(spec)
    packed = plan.pack(k)
    y = plan.apply(x, packed)
    y64, _ = transposed_oracle_f64(x, k, strides=spec.strides,
                                   padding=spec.padding)
    assert_close(y, y64)
    # force the route the rewrite replaced: identical math, other path
    for fallback in ("fused_plane", "fused_tap"):
        forced = plan.with_routes(tuple(
            dataclasses.replace(r, path=fallback) for r in plan.routes))
        assert_close(forced.apply(x, packed), y)


def test_vjp_matches_lax_oracle():
    spec = sp_spec()
    plan = plan_conv(spec)
    x, k = rand_xk(spec, seed=1)
    packed = plan.pack(k)
    y, vjp = jax.vjp(plan.apply, x, packed)
    y_o, vjp_o = jax.vjp(lambda x, k: ref.oracle_conv_transpose2d(
        x, k, strides=spec.strides, padding=spec.padding), x, k)
    assert_close(y, y_o)
    dy = jax.random.normal(jax.random.PRNGKey(2), y.shape)
    (dx, dpk), (dx_o, dk_o) = vjp(dy), vjp_o(dy)
    assert_close(dx, dx_o, tol=1e-3)
    assert_close(plan.unpack(dpk), dk_o, tol=1e-3)


def test_pallas_backend_executes_the_forced_route():
    """The executor is backend-independent: a pallas-policy plan forced
    onto pixel_shuffle (as the autotuner may install it) stays exact."""
    spec = sp_spec(backend="pallas")
    plan = plan_conv(spec)
    forced = plan.with_routes(tuple(
        Route(r.batch, "pixel_shuffle", None) for r in plan.routes))
    x, k = rand_xk(spec, seed=3)
    y64, _ = transposed_oracle_f64(x, k, strides=spec.strides,
                                   padding=spec.padding)
    assert_close(forced.apply(x, forced.pack(k)), y64)


# ---------------------------------------------------------------------------
# jaxpr proof: one GEMM + one depth-to-space permute, zero convs
# ---------------------------------------------------------------------------

def test_lowers_to_one_gemm_one_transpose_zero_convs():
    spec = sp_spec()
    plan = plan_conv(spec)
    x, k = rand_xk(spec)
    jaxpr = jax.make_jaxpr(plan.apply)(x, plan.pack(k))
    assert count_eqns(jaxpr, "dot_general") == 1
    assert count_eqns(jaxpr, "transpose") == 1      # the depth-to-space
    assert count_eqns(jaxpr, "conv_general_dilated") == 0


# ---------------------------------------------------------------------------
# int8 twin + the committed fixture pin
# ---------------------------------------------------------------------------

def test_int8_twin_routes_and_matches_its_fallback():
    spec = sp_spec()
    p8 = plan_conv(dataclasses.replace(spec, wdtype="int8"))
    assert [r.path for r in p8.routes] == ["pixel_shuffle"] * 4
    x, k = rand_xk(spec, seed=4)
    packed = p8.pack(k)
    forced = p8.with_routes(tuple(
        dataclasses.replace(r, path="fused_tap") for r in p8.routes))
    # same dequantized GEMM operand either way: bit-level agreement is not
    # guaranteed (different contraction order), plain f32 closeness is
    assert_close(p8.apply(x, packed), forced.apply(x, packed))


def test_fixture_pins_pixel_shuffle_for_zoo_geometry():
    """The golden table must record the sub-pixel verdict on real model
    sites — losing them silently would be a perf regression with no diff."""
    table = json.loads(FIXTURE.read_text())
    winners = {e["name"] for e in table["entries"]
               if any(r["path"] == "pixel_shuffle" for r in e["routes"])}
    assert any(n.startswith("unet_up") for n in winners), winners
    assert any(n.startswith("fig7_") for n in winners), winners
