"""Optimizer unit tests: convergence, ZeRO-1 state specs, clipping."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.train import optim as opt


def quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)


def run_opt(name, steps=200, lr=0.05):
    cfg = opt.OptConfig(name=name, lr=lr, weight_decay=0.0)
    params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}
    init, update = opt.OPTIMIZERS[name]
    state, _ = init(params, None, None, cfg)
    for _ in range(steps):
        g = jax.grad(quad_loss)(params)
        params, state, _ = update(g, state, params, cfg)
    return params


def test_adamw_converges():
    p = run_opt("adamw")
    np.testing.assert_allclose(np.asarray(p["w"]), 3.0, atol=0.15)
    np.testing.assert_allclose(np.asarray(p["b"]), -1.0, atol=0.15)


def test_adafactor_converges():
    p = run_opt("adafactor", steps=400, lr=0.3)
    np.testing.assert_allclose(np.asarray(p["w"]), 3.0, atol=0.3)
    np.testing.assert_allclose(np.asarray(p["b"]), -1.0, atol=0.3)


def test_grad_clip():
    g = {"w": jnp.full((4,), 100.0)}
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["w"])), 1.0, rtol=1e-5)


def test_zero1_specs_shard_over_data():
    params = {"w": jnp.zeros((64, 32)), "tiny": jnp.zeros((3,))}
    specs = {"w": P(None, "model"), "tiny": P(None)}
    _, sspecs = opt.adamw_init(params, specs, None, opt.OptConfig())
    # first unsharded, divisible dim picks up the data axis
    assert sspecs["m"]["w"] == P("data", "model")
    assert sspecs["m"]["tiny"] == P(None)


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((7,))}
    state, _ = opt.adafactor_init(params)
    assert set(state["f"]["w"]) == {"vr", "vc"}
    assert state["f"]["w"]["vr"].shape == (64,)
    assert state["f"]["w"]["vc"].shape == (32,)
    assert set(state["f"]["b"]) == {"v"}
    # memory: factored state is ~ (64+32)/(64*32) of Adam's
    adam_state, _ = opt.adamw_init(params)
    fac = sum(x.size for x in jax.tree.leaves(state["f"]))
    full = sum(x.size for x in jax.tree.leaves(adam_state["m"]))
    assert fac < full / 10
