"""Fused single-launch transposed conv: one Pallas launch / one wide GEMM
per conv site, superpacked weight layout, and fused-vs-per-phase parity.
No hypothesis dependency — this file must run everywhere tier-1 runs.
Shared helpers (oracles, assertions, jaxpr counting) live in
``tests/conftest.py``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import reference as ref
from repro.core.plan import ConvSpec, conv_spec, plan_conv
from repro.models.gan import DCGAN_LAYERS

from tests.conftest import assert_close, count_eqns


# ---------------------------------------------------------------------------
# the acceptance property: ONE launch / ONE wide GEMM per conv site
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("i", range(len(DCGAN_LAYERS)))
def test_xla_forward_is_single_wide_gemm(i, dcgan_plan):
    """Every Table-1 DCGAN deconv site lowers to exactly one dot_general."""
    l = DCGAN_LAYERS[i]
    plan = dcgan_plan(l)
    assert plan.path in ("fused_tap", "fused_plane"), plan.path
    x = jnp.zeros((1, l.in_hw, l.in_hw, l.in_c), jnp.float32)
    packed = jnp.zeros((plan.total_taps * l.in_c, l.out_c), jnp.float32)
    jaxpr = jax.make_jaxpr(plan.apply)(x, packed)
    assert count_eqns(jaxpr.jaxpr, "dot_general") == 1
    assert count_eqns(jaxpr.jaxpr, "pallas_call") == 0


def test_pallas_forward_is_single_launch():
    """backend='pallas' lowers the whole transposed conv to one pallas_call
    (and no XLA GEMM outside it)."""
    plan = plan_conv(ConvSpec(
        kind="transposed", in_hw=(4, 4), in_c=64, out_c=32, kernel_hw=(5, 5),
        strides=(2, 2), padding=((2, 3), (2, 3)), backend="pallas"))
    assert plan.path == "pallas" and plan.tiles is not None
    x = jnp.zeros((2, 4, 4, 64), jnp.float32)
    packed = jnp.zeros((plan.total_taps * 64, 32), jnp.float32)
    jaxpr = jax.make_jaxpr(plan.apply)(x, packed)
    assert count_eqns(jaxpr.jaxpr, "pallas_call") == 1
    assert count_eqns(jaxpr.jaxpr, "dot_general") == 0


# ---------------------------------------------------------------------------
# superpack layout invariants
# ---------------------------------------------------------------------------

def test_superpack_layout_and_offsets():
    from tests.conftest import packed_roundtrip
    k = jax.random.normal(jax.random.PRNGKey(0), (5, 4, 3, 2), jnp.float32)
    plan = plan_conv(conv_spec("transposed", (1, 4, 4, 3), k.shape,
                               strides=(2, 3), padding=((2, 2), (1, 1))))
    packed = packed_roundtrip(plan, k)
    c, n = plan.spec.in_c, plan.spec.out_c
    assert packed.shape == (plan.total_taps * c, n)
    # each phase's rows sit at tap_off*C and match the per-phase slicing
    from repro.core.decompose import decompose_kernel
    subs = decompose_kernel(k, (2, 3), ((2, 2), (1, 1)))
    for ex in plan.phases:
        th, tw = ex.taps
        if th * tw == 0:
            continue
        seg = packed[ex.tap_off * c:(ex.tap_off + th * tw) * c]
        np.testing.assert_array_equal(
            np.asarray(seg), np.asarray(subs[ex.q].reshape(th * tw * c, n)))
    # offsets partition the buffer exactly (round-trip asserted above)
    assert sum(ex.taps[0] * ex.taps[1] for ex in plan.phases) \
        == plan.total_taps


def test_legacy_phase_dict_adapts_to_superpack():
    """Pre-superpack checkpoints ({key: per-phase buf}) still apply/unpack."""
    k = jax.random.normal(jax.random.PRNGKey(1), (4, 4, 6, 8), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 5, 5, 6), jnp.float32)
    pads = ((1, 2), (1, 2))
    plan = plan_conv(conv_spec("transposed", x.shape, k.shape,
                               strides=(2, 2), padding=pads))
    from repro.core.decompose import decompose_kernel
    subs = decompose_kernel(k, (2, 2), pads)
    legacy = {ex.key: subs[ex.q].reshape(-1, 8) for ex in plan.phases}
    np.testing.assert_array_equal(np.asarray(plan.apply(x, legacy)),
                                  np.asarray(plan.apply(x, plan.pack(k))))
    np.testing.assert_array_equal(np.asarray(plan.unpack(legacy)),
                                  np.asarray(k))


# ---------------------------------------------------------------------------
# fused-vs-per-phase parity: odd strides, asymmetric padding, non-uniform
# phase sizes (the general interleave path), every whole-conv route
# ---------------------------------------------------------------------------

PARITY_CASES = [
    (4, 5, 5, 4, 3, 2, ((2, 3), (1, 0))),    # odd stride, asymmetric pads
    (5, 4, 3, 3, 3, 3, ((0, 2), (1, 1))),    # non-uniform phase extents
    (6, 6, 2, 2, 3, 3, ((0, 0), (0, 0))),    # stride > kernel: empty phases
    (5, 5, 5, 5, 1, 1, ((2, 2), (2, 2))),    # stride 1 degenerate
    (4, 4, 5, 5, 2, 2, ((2, 3), (2, 3))),    # DCGAN geometry (fused_tap)
    (8, 8, 4, 4, 2, 2, ((1, 3), (1, 3))),    # cGAN geometry (fused_plane)
    (7, 3, 6, 2, 4, 2, ((3, 1), (0, 1))),    # wildly asymmetric
]


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("case", PARITY_CASES)
def test_fused_matches_per_phase_and_oracle(case, backend):
    h, w, r, s, sh, sw, pads = case
    key = jax.random.PRNGKey(abs(hash(case)) % (2 ** 31))
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (2, h, w, 3), jnp.float32)
    k = jax.random.normal(k2, (r, s, 3, 4), jnp.float32)
    plan = plan_conv(conv_spec("transposed", x.shape, k.shape,
                               strides=(sh, sw), padding=pads,
                               backend=backend))
    packed = plan.pack(k)
    want = ref.oracle_conv_transpose2d(x, k, strides=(sh, sw), padding=pads)
    assert_close(plan.apply(x, packed), want)
    assert_close(plan.apply_per_phase(x, packed), want)


@pytest.mark.parametrize("case", PARITY_CASES[:4])
def test_grad_of_apply_on_superpack(case):
    """VJP through the fused executor, on the superpacked layout, matches
    autodiff of the XLA oracle (dx directly; dK after unpack)."""
    h, w, r, s, sh, sw, pads = case
    key = jax.random.PRNGKey(abs(hash(case)) % (2 ** 31) + 1)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (2, h, w, 3), jnp.float32)
    k = jax.random.normal(k2, (r, s, 3, 4), jnp.float32)
    plan = plan_conv(conv_spec("transposed", x.shape, k.shape,
                               strides=(sh, sw), padding=pads))
    packed = plan.pack(k)
    y, vjp = jax.vjp(plan.apply, x, packed)
    y_o, vjp_o = jax.vjp(
        lambda x, k: ref.oracle_conv_transpose2d(
            x, k, strides=(sh, sw), padding=pads), x, k)
    assert_close(y, y_o)
    dy = jax.random.normal(k3, y.shape)
    (dx, dpacked), (dx_o, dk_o) = vjp(dy), vjp_o(dy)
    assert dpacked.shape == packed.shape       # grads stay superpacked
    assert_close(dx, dx_o)
    assert_close(plan.unpack(dpacked), dk_o)


def test_fused_pallas_kernel_direct():
    """Kernel-level fused deconv entry (interpret mode) vs the oracle."""
    from repro.kernels.ops import untangled_deconv2d
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (2, 4, 4, 64), jnp.float32)
    k = jax.random.normal(k2, (5, 5, 64, 32), jnp.float32)
    got = untangled_deconv2d(x, k, strides=(2, 2), padding=((2, 3), (2, 3)),
                             interpret=True)
    want = ref.oracle_conv_transpose2d(x, k, strides=(2, 2),
                                       padding=((2, 3), (2, 3)))
    assert_close(got, want, tol=2e-5)


def test_fused_pallas_bf16_and_ragged_tiles():
    """bf16 input + channel counts that don't divide the tile size."""
    from repro.kernels.ops import untangled_deconv2d
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(k1, (1, 5, 5, 130), jnp.bfloat16)
    k = jax.random.normal(k2, (3, 3, 130, 40), jnp.bfloat16)
    got = untangled_deconv2d(x, k, strides=(2, 2), padding=((1, 1), (1, 1)),
                             interpret=True)
    want = ref.oracle_conv_transpose2d(x.astype(jnp.float32),
                                       k.astype(jnp.float32),
                                       strides=(2, 2), padding=((1, 1), (1, 1)))
    assert_close(got, want, tol=2e-2)


# ---------------------------------------------------------------------------
# satellite: VMEM estimates count the f32 accumulator at 4 bytes
# ---------------------------------------------------------------------------

def test_vmem_estimate_accumulator_is_f32():
    from repro.kernels.untangled_conv import (vmem_bytes_estimate,
                                              vmem_bytes_estimate_fused)
    hp = wp = 16; c_t = n_t = 8; r = s = 3; oh = ow = 14
    for itemsize in (1, 2, 4):
        est = vmem_bytes_estimate(hp, wp, c_t, r, s, n_t, oh, ow, itemsize)
        streamed = itemsize * (hp * wp * c_t + r * s * c_t * n_t
                               + oh * ow * n_t)
        # the accumulator contribution is itemsize-independent: always f32
        assert est - streamed == 4 * oh * ow * n_t
    est2 = vmem_bytes_estimate_fused(hp, wp, c_t, r * s, n_t, oh * ow,
                                     oh, ow, itemsize=2)
    streamed2 = 2 * (hp * wp * c_t + r * s * c_t * n_t + oh * ow * n_t)
    assert est2 - streamed2 == 4 * oh * ow * n_t


def test_bf16_plan_picks_tiles_accounting_f32_scratch():
    """A bf16 spec must not get bigger tiles than the f32 scratch allows:
    the estimate at itemsize=2 still carries the 4-byte accumulator."""
    from repro.kernels.untangled_conv import vmem_bytes_estimate_fused
    plan = plan_conv(ConvSpec(
        kind="transposed", in_hw=(16, 16), in_c=256, out_c=256,
        kernel_hw=(5, 5), strides=(2, 2), padding=((2, 3), (2, 3)),
        dtype="bfloat16", backend="pallas"))
    if plan.path != "pallas":
        pytest.skip("no VMEM-feasible tiling on this geometry")
    c_t, n_t = plan.tiles
    (glh, ghh), (glw, ghw) = plan.gpad
    hg, wg = 16 + glh + ghh, 16 + glw + ghw
    est = vmem_bytes_estimate_fused(hg, wg, c_t, plan.total_taps, n_t,
                                    plan.sum_uv, *plan.out_hw, itemsize=2)
    assert est <= 12 * 1024 * 1024
