"""Plane-parallel execution (``core.spatial``): geometry/verdict unit tests
in-process, oracle parity + jaxpr collective proofs in a forced-8-device
subprocess (the ``test_distributed.py`` pattern — the XLA host-device flag
must be set before jax initializes)."""
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

from repro.core import spatial
from repro.core.autotune import (candidate_routes, route_from_json,
                                 route_to_json, spec_key, _measurable)
from repro.core.plan import ConvSpec, plan_conv

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))


def dilated385(spatial_tiles=(4, 1), c=4, n=4):
    """The ISSUE's 385x385 dilated-context geometry (channel count scaled
    down for test wall-clock; the tiling algebra only sees H/W/k/s/d)."""
    return ConvSpec(kind="dilated", in_hw=(385, 385), in_c=c, out_c=n,
                    kernel_hw=(3, 3), strides=(1, 1),
                    padding=((2, 2), (2, 2)), dilation=(2, 2),
                    backend="xla", spatial=spatial_tiles)


def decoder96(spatial_tiles=(2, 2), c=16, n=16):
    """The large transposed-decoder geometry (k4 s2, zoo 'SAME' padding)."""
    return ConvSpec(kind="transposed", in_hw=(96, 96), in_c=c, out_c=n,
                    kernel_hw=(4, 4), strides=(2, 2),
                    padding=((1, 3), (1, 3)), backend="xla",
                    spatial=spatial_tiles)


# ---------------------------------------------------------------------------
# geometry (pure arithmetic, no devices)
# ---------------------------------------------------------------------------

def test_single_dim_geometry():
    sp = spatial.spatial_plan(dilated385((4, 1)))
    th, tw = sp.dims
    assert (th.dev, tw.dev) == (4, 1)
    assert th.pad_to == th.block * 4 and th.pad_to >= th.size
    # slab = strided span of the block's outputs + dilated kernel reach
    t = th.out_pad // 4
    assert th.tin == (t - 1) * 1 + 2 * 2 + 1
    assert th.halo_lo == 2                       # == the spec's low pad
    assert th.halo_lo + th.block + th.halo_hi >= th.tin
    assert th.halo_lo <= th.block and th.halo_hi <= th.block
    # local spec: zero padding on the sharded dim (halo replaces it)
    assert sp.local_spec.padding[0] == (0, 0)
    assert sp.local_spec.spatial == (1, 1)
    assert sp.out_hw == (385, 385)


def test_transposed_dim_geometry():
    sp = spatial.spatial_plan(decoder96((2, 2)))
    for d in sp.dims:
        assert d.dev == 2 and d.pad_to == 96 and d.block == 48
        assert d.out_pad == 192
        assert d.halo_lo <= d.block and d.halo_hi <= d.block
    assert sp.out_hw == (192, 192)
    # the local plan must share the parent's superpack layout bit-for-bit
    parent = plan_conv(decoder96((1, 1)))
    local = plan_conv(sp.local_spec)
    assert local.total_taps == parent.total_taps


def test_infeasible_geometries_return_none():
    assert spatial.spatial_plan(dilated385((1, 1))) is None
    # block of 1 row cannot hold a k5 halo: one-hop exchange infeasible
    tiny = ConvSpec(kind="conv", in_hw=(16, 16), in_c=2, out_c=2,
                    kernel_hw=(5, 5), strides=(1, 1),
                    padding=((2, 2), (2, 2)), backend="xla",
                    spatial=(16, 1))
    assert spatial.spatial_plan(tiny) is None


# ---------------------------------------------------------------------------
# plan-layer verdict + serialization
# ---------------------------------------------------------------------------

def test_dev_verdict_emitted_above_bytes_floor():
    plan = plan_conv(dilated385((4, 1), c=32, n=32))
    assert plan.route_for_batch(4).dev_tiles == (4, 1)
    # path/tiles stay the single-device verdict — the fallback route
    ref = plan_conv(dilated385((1, 1), c=32, n=32))
    assert plan.route_for_batch(4).path == ref.route_for_batch(4).path


def test_dev_verdict_suppressed_below_bytes_floor():
    small = ConvSpec(kind="conv", in_hw=(32, 32), in_c=4, out_c=4,
                     kernel_hw=(3, 3), strides=(1, 1),
                     padding=((1, 1), (1, 1)), backend="xla",
                     spatial=(2, 1))
    plan = plan_conv(small)
    assert all(r.dev_tiles is None for r in plan.routes)


def test_route_json_roundtrip_and_spec_key():
    plan = plan_conv(dilated385((4, 1), c=32, n=32))
    r = plan.route_for_batch(4)
    assert r.dev_tiles == (4, 1)
    assert route_from_json(route_to_json(r)) == r
    assert spec_key(dilated385((4, 1))).endswith(":sp4x1")
    # unchanged spec -> unchanged key: old cache entries stay valid
    assert ":sp" not in spec_key(dilated385((1, 1)))


def test_autotune_candidates_pair_dev_and_single():
    plan = plan_conv(dilated385((4, 1), c=32, n=32))
    cands = candidate_routes(plan, 4)
    dev = [r for r in cands if r.dev_tiles == (4, 1)]
    single = [r for r in cands if r.dev_tiles is None]
    assert dev and single
    # a dev-tiled candidate is unmeasurable without a bound matching mesh
    assert not _measurable(dev[0])


def test_apply_falls_back_without_mesh():
    """A dev_tiles route on a mesh-less host must silently execute the
    single-device route and agree bit-for-bit."""
    import jax
    import jax.numpy as jnp
    spec = dilated385((4, 1), c=8, n=8)
    plan, ref = plan_conv(spec), plan_conv(dilated385((1, 1), c=8, n=8))
    assert plan.route_for_batch(1).dev_tiles == (4, 1)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (1, 385, 385, 8), jnp.float32)
    kern = jax.random.normal(k2, (3, 3, 8, 8), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(plan.apply(x, plan.pack(kern))),
        np.asarray(ref.apply(x, ref.pack(kern))))


# ---------------------------------------------------------------------------
# multi-device subprocess suite
# ---------------------------------------------------------------------------

def _capability() -> str | None:
    probe = (
        "import jax\n"
        "from jax.sharding import PartitionSpec as P\n"
        "from repro.launch.mesh import make_spatial_mesh\n"
        "from repro.sharding import shard_map_compat\n"
        "m = make_spatial_mesh(2, 2)\n"
        "f = shard_map_compat(lambda x: x * 2, m, in_specs=P('sp_h'),\n"
        "                     out_specs=P('sp_h'))\n"
        "f(jax.numpy.ones((4,)))\n"
        "print(jax.device_count())\n")
    try:
        r = subprocess.run([sys.executable, "-c", probe], env=ENV,
                           capture_output=True, text=True, timeout=120)
    except Exception as e:  # noqa: BLE001 - any probe failure means skip
        return f"spatial mesh probe failed to run: {e}"
    if r.returncode != 0:
        tail = (r.stderr.strip().splitlines() or ["unknown error"])[-1]
        return f"spatial mesh unavailable: {tail}"
    if int(r.stdout.strip() or 0) < 8:
        return "need 8 forced host devices"
    return None


_SKIP = _capability()
multidev = pytest.mark.skipif(_SKIP is not None, reason=f"{_SKIP}")


def run_py(code: str, timeout=600):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=ENV, capture_output=True, text=True,
                       timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


_PARITY_PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import spatial
from repro.core.plan import ConvSpec, plan_conv
from repro.launch.mesh import make_spatial_mesh

def parity(spec_kw, dev_tiles, batch=2, tol=2e-6):
    sharded = plan_conv(ConvSpec(backend='xla', spatial=dev_tiles, **spec_kw))
    single = plan_conv(ConvSpec(backend='xla', **spec_kw))
    assert sharded.route_for_batch(batch).dev_tiles == dev_tiles, \\
        sharded.route_for_batch(batch)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    h, w = spec_kw['in_hw']
    x = jax.random.normal(k1, (batch, h, w, spec_kw['in_c']), jnp.float32)
    kern = jax.random.normal(
        k2, spec_kw['kernel_hw'] + (spec_kw['in_c'], spec_kw['out_c']),
        jnp.float32)
    pk = single.pack(kern)

    def loss(plan):
        return lambda x, pk: jnp.sum(plan.apply(x, pk) ** 2)

    y1 = single.apply(x, pk)
    g1x, g1k = jax.grad(loss(single), argnums=(0, 1))(x, pk)
    mesh = make_spatial_mesh(*dev_tiles)
    with spatial.use_spatial_mesh(mesh):
        yd = jax.jit(lambda x, pk: sharded.apply(x, pk))(x, pk)
        gdx, gdk = jax.jit(jax.grad(loss(sharded), argnums=(0, 1)))(x, pk)
    for a, b in ((y1, yd), (g1x, gdx), (g1k, gdk)):
        err = float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-30))
        assert err < tol, err
    return yd
"""


@multidev
def test_parity_dilated_context_385():
    run_py(_PARITY_PRELUDE + """
    parity(dict(kind='dilated', in_hw=(385, 385), in_c=4, out_c=4,
                kernel_hw=(3, 3), strides=(1, 1), padding=((2, 2), (2, 2)),
                dilation=(2, 2)), (4, 1))
    print('dilated385 fwd+vjp parity OK')
    """)


@multidev
def test_parity_transposed_decoder_2x2():
    run_py(_PARITY_PRELUDE + """
    parity(dict(kind='transposed', in_hw=(96, 96), in_c=16, out_c=16,
                kernel_hw=(4, 4), strides=(2, 2), padding=((1, 3), (1, 3))),
           (2, 2))
    print('decoder96 2x2 fwd+vjp parity OK')
    """)


@multidev
def test_parity_strided_conv():
    run_py(_PARITY_PRELUDE + """
    parity(dict(kind='conv', in_hw=(385, 385), in_c=4, out_c=4,
                kernel_hw=(3, 3), strides=(2, 2), padding=((1, 1), (1, 1))),
           (2, 1))
    print('strided conv parity OK')
    """)


@multidev
def test_halo_exchange_is_collective_permute():
    """The ISSUE's lowering proof: the sharded program moves halos with
    ppermute (collective-permute) and NEVER all-gathers the plane —
    forward and backward both."""
    run_py("""
    import jax, jax.numpy as jnp
    from repro.core import spatial
    from repro.core.plan import ConvSpec, plan_conv
    from repro.launch.mesh import make_spatial_mesh

    spec = ConvSpec(kind='dilated', in_hw=(385, 385), in_c=4, out_c=4,
                    kernel_hw=(3, 3), strides=(1, 1),
                    padding=((2, 2), (2, 2)), dilation=(2, 2),
                    backend='xla', spatial=(4, 1))
    plan = plan_conv(spec)
    x = jnp.zeros((2, 385, 385, 4))
    pk = jnp.zeros((plan.total_taps * 4, 4))
    mesh = make_spatial_mesh(4, 1)
    with spatial.use_spatial_mesh(mesh):
        fwd = str(jax.make_jaxpr(lambda a, k: plan.apply(a, k))(x, pk))
        bwd = str(jax.make_jaxpr(jax.grad(
            lambda a, k: jnp.sum(plan.apply(a, k) ** 2),
            argnums=(0, 1)))(x, pk))
    assert fwd.count('ppermute') >= 1, fwd.count('ppermute')
    assert 'all_gather' not in fwd
    assert bwd.count('ppermute') >= 1
    assert 'all_gather' not in bwd
    print('collective-permute lowering proof OK')
    """)


@multidev
def test_shard_params_nondivisible_warns_once():
    run_py("""
    import warnings
    import jax.numpy as jnp
    from repro.layers import common as cm
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import DistContext

    dist = DistContext(mesh=make_host_mesh(data=2, model=2))
    p = {'head': jnp.ones((3, 8))}          # 3 does not divide model=2
    s = {'head': cm.spec('model', None)}
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter('always')
        out = dist.shard_params(p, s)
        hits = [x for x in w if 'shard_params' in str(x.message)]
    assert len(hits) == 1, [str(x.message) for x in w]
    msg = str(hits[0].message)
    assert 'head' in msg and 'dim 0' in msg and 'model' in msg, msg
    # replicated on the offending dim, no crash
    assert out['head'].shape == (3, 8)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter('always')
        dist.shard_params(p, s)             # same param: warned already
        assert not [x for x in w if 'shard_params' in str(x.message)]
    print('shard_params replication warning OK')
    """)


@multidev
def test_degrade_replans_spatial_tiles():
    """Serving integration: a spatially-sharded model serves behind the
    same admission layer, and ``degrade(spatial_tiles=...)`` re-plans
    ``dev_tiles`` on the shrunk mesh — outputs stay equal to the
    single-device closure."""
    run_py("""
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import spatial
    from repro.core.plan import ConvSpec, plan_conv
    from repro.serving.control_plane import ControlPlane, ServeRequest

    kw = dict(kind='dilated', in_hw=(385, 385), in_c=4, out_c=4,
              kernel_hw=(3, 3), strides=(1, 1), padding=((2, 2), (2, 2)),
              dilation=(2, 2), backend='xla')
    kern = jax.random.normal(jax.random.PRNGKey(0), (3, 3, 4, 4))

    def serve_for(tiles):
        plan = plan_conv(ConvSpec(spatial=tiles, **kw))
        pk = plan.pack(kern)
        return lambda x: plan.apply(x, pk)

    cp = ControlPlane()
    cp.register_image_model('seg', serve_for((1, 1)),
                            np.zeros((385, 385, 4), np.float32),
                            buckets=(1, 2))
    zs = [np.random.RandomState(i).randn(385, 385, 4).astype(np.float32)
          for i in range(2)]
    cp.run([ServeRequest(rid=i, model='seg', payload=z)
            for i, z in enumerate(zs)])
    before = {r.rid: r.out for r in cp.done}

    mesh = cp.degrade(8, spatial_tiles=(2, 2),
                      serve_fns={'seg': serve_for((2, 2))})
    assert dict(mesh.shape) == {'data': 2, 'sp_h': 2, 'sp_w': 2}
    assert cp.degraded['spatial_tiles'] == (2, 2)
    assert spatial.active_spatial_mesh()[0] is mesh
    cp.run([ServeRequest(rid=10 + i, model='seg', payload=z)
            for i, z in enumerate(zs)])
    after = {r.rid: r.out for r in cp.done}
    for i in range(2):
        np.testing.assert_allclose(after[10 + i], before[i],
                                   rtol=1e-4, atol=1e-5)
    print('spatial degrade re-plan OK')
    """)


# ---------------------------------------------------------------------------
# infeasible-tiling warning: named, once per spec, fallback untouched
# ---------------------------------------------------------------------------

def test_infeasible_tiling_warns_once_and_falls_back_bit_equal():
    """A transposed spec with non-uniform phases that *requests* device
    tiling must not silently plan single-device: a RuntimeWarning names
    the spec and the reason, exactly once per process — surviving
    ``plan_cache_clear()`` — and the fallback plan's output is bit-equal
    to the ``spatial=(1, 1)`` twin (the verdict vanishes, the math
    doesn't)."""
    import jax
    import jax.numpy as jnp
    from repro.core.plan import plan_cache_clear

    def spec(tiles):
        # k=3 s=2: phases carry 2 and 1 taps -> no uniform block tiling
        return ConvSpec(kind="transposed", in_hw=(24, 24), in_c=6, out_c=10,
                        kernel_hw=(3, 3), strides=(2, 2),
                        padding=((1, 0), (1, 0)), backend="xla",
                        spatial=tiles)

    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        plan = plan_conv(spec((2, 2)))
        plan_cache_clear()              # re-derives the geometry...
        plan2 = plan_conv(spec((2, 2)))
    hits = [w for w in rec if issubclass(w.category, RuntimeWarning)
            and "spatial_plan" in str(w.message)]
    assert len(hits) == 1, [str(w.message) for w in rec]   # ...but warns once
    msg = str(hits[0].message)
    assert "spatial=(2, 2)" in msg and "transposed" in msg
    assert "non-uniform" in msg and "planning single-device" in msg

    assert all(r.dev_tiles is None for r in plan.routes)
    twin = plan_conv(spec((1, 1)))
    k = jax.random.normal(jax.random.PRNGKey(0), (3, 3, 6, 10), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 24, 6), jnp.float32)
    got = np.asarray(plan2.apply(x, plan2.pack(k)))
    want = np.asarray(twin.apply(x, twin.pack(k)))
    np.testing.assert_array_equal(got, want)    # bit-equal, not just close
