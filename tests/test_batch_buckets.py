"""Batch-bucketed plan routes: every ConvPlan sizes one Route per batch
bucket at build time, ``route_for_batch`` is a table lookup, the executors
never re-derive a path from a traced batch, and every bucket still lowers
to one launch / one wide GEMM."""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.plan as planmod
from repro.core import reference as ref
from repro.core.plan import BATCH_BUCKETS, ConvSpec, plan_conv

from tests.conftest import assert_close, count_eqns, plane_bytes_cap


def transposed_spec(**kw):
    base = dict(kind="transposed", in_hw=(8, 8), in_c=16, out_c=8,
                kernel_hw=(5, 5), strides=(2, 2), padding=((2, 2), (2, 2)))
    base.update(kw)
    return ConvSpec(**base)


def dilated_spec(**kw):
    base = dict(kind="dilated", in_hw=(16, 16), in_c=8, out_c=8,
                kernel_hw=(3, 3), dilation=(2, 2), padding=((2, 2), (2, 2)))
    base.update(kw)
    return ConvSpec(**base)


# ---------------------------------------------------------------------------
# route table shape + lookup semantics
# ---------------------------------------------------------------------------

def test_every_plan_carries_one_route_per_bucket():
    for spec in (transposed_spec(), dilated_spec()):
        plan = plan_conv(spec)
        assert tuple(r.batch for r in plan.routes) == BATCH_BUCKETS
        # plan.path stays the B=1 bucket's decision (introspection compat)
        assert plan.path == plan.routes[0].path
        assert plan.tiles == plan.routes[0].tiles


def test_route_for_batch_rounds_up_to_bucket():
    plan = plan_conv(dilated_spec())
    for b, want in ((1, 1), (2, 4), (4, 4), (5, 16), (16, 16), (17, 64),
                    (64, 64)):
        assert plan.route_for_batch(b).batch == want


def test_route_beyond_largest_bucket_is_exact_and_memoized():
    plan = plan_conv(dilated_spec())
    r1 = plan.route_for_batch(1000)
    assert r1.batch == 1000
    assert plan.route_for_batch(1000) is r1        # memo hit
    # an absurd batch must overflow the plane-bytes cap -> per-tap route
    big = plan.route_for_batch(10 ** 7)
    assert big.path == "taps" and not big.fused_bwd


# ---------------------------------------------------------------------------
# route-switch boundaries at the plane-bytes cap
# ---------------------------------------------------------------------------

@pytest.fixture
def tight_cap():
    """Cap sized so the dilated test spec fits fused at B<=4 but not B>=16."""
    spec = dilated_spec()
    r, s = spec.kernel_hw
    oh = ow = 16
    per_image = 4 * oh * ow * r * s * spec.in_c
    with plane_bytes_cap(per_image * 4):              # B=4 fits exactly
        yield spec


def test_single_route_switches_at_cap(tight_cap):
    plan = plan_conv(tight_cap)
    paths = {r.batch: r.path for r in plan.routes}
    assert paths[1] == "fused_tap" and paths[4] == "fused_tap"
    assert paths[16] == "taps" and paths[64] == "taps"
    # the backward verdict flips at the same boundary
    assert plan.route_for_batch(4).fused_bwd
    assert not plan.route_for_batch(16).fused_bwd


def test_parity_and_vjp_across_the_switch(tight_cap):
    """Both sides of the route switch match the lax oracle, fwd and bwd."""
    spec = tight_cap
    plan = plan_conv(spec)
    key = jax.random.PRNGKey(0)
    k = jax.random.normal(key, (3, 3, spec.in_c, spec.out_c), jnp.float32)
    packed = plan.pack(k)
    for b in (4, 16):                       # fused_tap side, taps side
        x = jax.random.normal(jax.random.PRNGKey(b),
                              (b, 16, 16, spec.in_c), jnp.float32)
        want = ref.oracle_dilated_conv2d(x, k, dilation=spec.dilation,
                                         padding=spec.padding)
        assert_close(plan.apply(x, packed), want)
        y, vjp = jax.vjp(plan.apply, x, packed)
        y_o, vjp_o = jax.vjp(lambda x, k: ref.oracle_dilated_conv2d(
            x, k, dilation=spec.dilation, padding=spec.padding), x, k)
        dy = jax.random.normal(jax.random.PRNGKey(b + 1), y.shape)
        (dx, dpk), (dx_o, dk_o) = vjp(dy), vjp_o(dy)
        assert_close(dx, dx_o, tol=1e-3)
        assert_close(plan.unpack(dpk), dk_o, tol=1e-3)


def test_transposed_route_switches_at_cap():
    """fused_plane at small buckets degrades to the exact fused_tap (uniform
    phases) once the bucket-scaled plane-GEMM intermediate busts the cap."""
    spec = transposed_spec(strides=(2, 2), kernel_hw=(4, 4),
                           padding=((1, 1), (1, 1)))
    plan = plan_conv(spec)
    if plan.routes[0].path != "fused_plane":
        pytest.skip(f"geometry routed {plan.routes[0].path}, not fused_plane")
    (glh, ghh), (glw, ghw) = plan.gpad
    hg = spec.in_hw[0] + glh + ghh
    wg = spec.in_hw[1] + glw + ghw
    plane1 = 4 * hg * wg * plan.total_taps * spec.out_c
    with plane_bytes_cap(plane1 * 4):                 # B=4 fits, B=16 not
        plan_t = plan_conv(spec)
        paths = {r.batch: r.path for r in plan_t.routes}
        assert paths[1] == "fused_plane" and paths[4] == "fused_plane"
        assert paths[16] == "fused_tap" and paths[64] == "fused_tap"
        # parity on both sides of the boundary
        key = jax.random.PRNGKey(1)
        k = jax.random.normal(key, (4, 4, spec.in_c, spec.out_c), jnp.float32)
        packed = plan_t.pack(k)
        for b in (4, 16):
            x = jax.random.normal(jax.random.PRNGKey(b),
                                  (b, *spec.in_hw, spec.in_c), jnp.float32)
            want = ref.oracle_conv_transpose2d(
                x, k, strides=spec.strides, padding=spec.padding)
            assert_close(plan_t.apply(x, packed), want)


# ---------------------------------------------------------------------------
# every bucket still lowers to one launch / one wide GEMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b", BATCH_BUCKETS)
def test_xla_bucket_lowers_to_one_dot_general(b):
    plan = plan_conv(dilated_spec())
    assert plan.route_for_batch(b).path == "fused_tap"
    x = jnp.zeros((b, 16, 16, 8), jnp.float32)
    packed = jnp.zeros((9 * 8, 8), jnp.float32)
    jaxpr = jax.make_jaxpr(plan.apply)(x, packed)
    assert count_eqns(jaxpr.jaxpr, "dot_general") == 1
    assert count_eqns(jaxpr.jaxpr, "conv_general_dilated") == 0


@pytest.mark.parametrize("b", BATCH_BUCKETS)
def test_pallas_bucket_lowers_to_one_launch(b):
    plan = plan_conv(dilated_spec(backend="pallas"))
    route = plan.route_for_batch(b)
    if route.path != "pallas":
        pytest.skip("no VMEM-feasible tiling on this geometry")
    x = jnp.zeros((b, 16, 16, 8), jnp.float32)
    packed = jnp.zeros((9 * 8, 8), jnp.float32)
    jaxpr = jax.make_jaxpr(plan.apply)(x, packed)
    assert count_eqns(jaxpr.jaxpr, "pallas_call") == 1
    assert count_eqns(jaxpr.jaxpr, "dot_general") == 0


@pytest.mark.parametrize("b", (1, 4, 16))
def test_transposed_bucket_parity_vs_oracle(b):
    spec = transposed_spec()
    plan = plan_conv(spec)
    key = jax.random.PRNGKey(2)
    k = jax.random.normal(key, (5, 5, spec.in_c, spec.out_c), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(b),
                          (b, *spec.in_hw, spec.in_c), jnp.float32)
    want = ref.oracle_conv_transpose2d(x, k, strides=spec.strides,
                                       padding=spec.padding)
    assert_close(plan.apply(x, plan.pack(k)), want)


# ---------------------------------------------------------------------------
# the executors carry no trace-time batch re-checks
# ---------------------------------------------------------------------------

def test_executors_never_touch_the_byte_cap():
    """The cap lives in the route builders only: no executor or backward
    re-derives a path from the traced batch (the PR-3 re-check branches
    at _transposed_fwd/_single_fwd/_ps_bwd are gone)."""
    for fn in (planmod._transposed_fwd, planmod._single_fwd,
               planmod._ps_bwd, planmod._pt_bwd):
        src = inspect.getsource(fn)
        assert "_PLANE_BYTES_MAX" not in src, fn.__name__
    for fn in (planmod._transposed_fwd, planmod._single_fwd,
               planmod._ps_bwd):
        assert "route_for_batch" in inspect.getsource(fn), fn.__name__


# ---------------------------------------------------------------------------
# zoo-wide route_for_batch property: round-up identity + per-plan memo
# ---------------------------------------------------------------------------

ODD_BATCHES = (2, 3, 5, 17, 65, 100)


def test_route_for_batch_property_over_the_whole_zoo():
    """For EVERY model-zoo site (fig7 GANs, VAE, SegNet, the dilated bench
    suite, the U-Net — int8 twins and convplane tilings included) and a
    spread of non-bucket batches: an in-range batch returns the round-up
    bucket's route *object* (identity, not equality — callers key compiled
    executables on it), an oversize batch returns an exactly-sized memoized
    route, and the oversize memo is per-plan state that never aliases
    across specs or survives a ``with_routes`` copy."""
    from tools.gen_route_table import route_specs

    plans = [(name, plan_conv(spec)) for name, spec in route_specs()]
    largest = BATCH_BUCKETS[-1]
    for name, plan in plans:
        for b in ODD_BATCHES:
            r = plan.route_for_batch(b)
            if b <= largest:
                bucket = next(rt for rt in plan.routes if b <= rt.batch)
                assert r is bucket, (name, b)
            else:
                assert r.batch == b, (name, b)
                assert plan.route_for_batch(b) is r, (name, b)  # memo hit
    # the oversize memo belongs to the plan instance, not the class.
    # Dedupe by plan identity first: sites with identical normalized specs
    # legitimately share one cached ConvPlan (and therefore one memo).
    distinct = {id(plan): plan for _, plan in plans}.values()
    memos = [id(p._xl_routes) for p in distinct]
    assert len(set(memos)) == len(memos), "aliased _xl_routes dicts"
    # a with_routes sibling starts with a fresh, empty memo
    name0, plan0 = plans[0]
    sib = plan0.with_routes(plan0.routes)
    assert sib._xl_routes == {} and sib._xl_routes is not plan0._xl_routes
    r65 = sib.route_for_batch(65)
    assert r65.batch == 65 and 65 in sib._xl_routes
    assert sib._xl_routes is not plan0._xl_routes
