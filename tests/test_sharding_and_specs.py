"""Sharding rule resolution, input specs, and roofline accounting units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.configs.base import SHAPES, ShapeConfig
from repro.launch import roofline as rl
from repro.launch import specs as specs_lib
from repro.models import transformer as tfm
from repro.sharding import DEFAULT_RULES, DistContext


class FakeMesh:
    """Duck-typed mesh: enough for make_dist rule logic."""
    def __init__(self, shape):
        self._shape = dict(shape)
        self.axis_names = tuple(self._shape)
        self.size = int(np.prod(list(self._shape.values())))

    @property
    def shape(self):
        return self._shape


def make_dist_for(arch, shape_name, mesh_shape=(("data", 16), ("model", 16)),
                  **kw):
    from repro.launch.steps import make_dist
    cfg = registry.get_config(arch)
    return make_dist(FakeMesh(mesh_shape), cfg, SHAPES[shape_name], **kw), cfg


def test_ssm_arch_disables_tp():
    dist, _ = make_dist_for("mamba2-130m", "train_4k")
    assert dist.rules["heads"] is None and dist.rules["ffn"] is None
    assert dist.rules["vocab"] == "model"


def test_decode_small_kv_heads_shards_cache_seq():
    dist, _ = make_dist_for("qwen2-7b", "decode_32k")
    assert dist.rules["kv_heads"] is None
    assert dist.rules["kv_seq"] == "model"


def test_mla_decode_shards_cache_seq():
    dist, _ = make_dist_for("deepseek-v3-671b", "decode_32k")
    assert dist.rules["kv_seq"] == "model"


def test_long_context_decode_replicates_batch():
    dist, _ = make_dist_for("mamba2-130m", "long_500k")
    assert dist.rules["batch"] is None
    assert dist.rules["kv_seq"] == "data"


def test_huge_moe_experts_fully_sharded():
    dist, _ = make_dist_for("deepseek-v3-671b", "train_4k")
    assert dist.rules["expert"] == ("data", "model")
    dist, _ = make_dist_for("dbrx-132b", "train_4k")
    assert dist.rules["expert"] == "model"
    assert dist.rules["expert_ffn"] == "data"


def test_dp_only_rules():
    dist, _ = make_dist_for("llama3.2-1b", "train_4k", parallelism="dp_only")
    assert dist.rules["heads"] is None and dist.rules["vocab"] is None
    assert dist.rules["batch"] == ("data", "model")


def test_resolve_logical_spec():
    dist = DistContext(mesh=None, rules=dict(DEFAULT_RULES))
    assert dist.resolve(P(None, "heads")) == P(None, "model")
    assert dist.resolve(P("vocab", None)) == P("model", None)


def test_resolve_superpack_axes():
    """Superpacked conv weights: (conv_taps, conv_out) shards out-channels
    by default; flipping conv_taps makes the superpack row-parallel."""
    from repro.sharding import SUPERPACK_SPEC
    dist = DistContext(mesh=None, rules=dict(DEFAULT_RULES))
    assert dist.resolve(SUPERPACK_SPEC) == P(None, "model")
    assert dist.image_spec() == P(("data",))
    rp = dict(DEFAULT_RULES, conv_taps="model", conv_out=None)
    assert DistContext(mesh=None, rules=rp).resolve(SUPERPACK_SPEC) \
        == P("model", None)


def test_planned_model_specs_use_superpack_axes():
    """Every superpacked weight in the planned model zoos carries the
    logical (conv_taps, conv_out) spec."""
    from repro.models import gan, segnet, vae
    _, s = gan.generator_init(jax.random.PRNGKey(0), gan.CGAN)
    assert s["dc0"] == P("conv_taps", "conv_out")
    _, s = segnet.segnet_init(jax.random.PRNGKey(0), segnet.SEGNET_TINY)
    assert s["w0"] == P("conv_taps", "conv_out")
    _, s = vae.vae_init(jax.random.PRNGKey(0), vae.VAE_TINY)
    assert s["enc0"] == s["dec0"] == P("conv_taps", "conv_out")


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen2-7b", "seamless-m4t-large-v2",
                                  "qwen2-vl-2b"])
def test_batch_specs_shapes(arch):
    cfg = registry.get_config(arch)
    sds, shard = specs_lib.batch_specs(cfg, SHAPES["train_4k"])
    assert sds["inputs"].shape == (256, 4096)
    assert "targets" in sds
    if cfg.is_encoder_decoder:
        assert sds["src_embeds"].shape == (256, specs_lib.SRC_FRAMES,
                                           cfg.d_model)
    if cfg.frontend == "vlm_stub":
        assert sds["embeds"].shape == (256, 4096, cfg.d_model)
    assert set(shard) == set(sds)


def test_param_specs_no_allocation():
    cfg = registry.get_config("llama3.2-1b")
    sds, logical = specs_lib.param_specs(cfg)
    leaves = jax.tree.leaves(sds)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    n = sum(int(np.prod(l.shape)) for l in leaves)
    assert 1.0e9 < n < 2.0e9          # ~1.24B params + padded vocab
    # specs tree mirrors params tree
    jax.tree.map(lambda a, b: None, sds,
                 jax.tree.map(lambda x: x, logical,
                              is_leaf=lambda x: isinstance(x, P)))


def test_cache_specs_match_shapes():
    cfg = registry.get_config("gemma3-1b")
    cache_sds, logical = specs_lib.cache_specs(cfg, SHAPES["decode_32k"])
    k0 = cache_sds[0]["l0"]["k"]
    assert k0.shape[1:] == (128, 32768, cfg.num_kv_heads, cfg.head_dim)


# ---------------------------------------------------------------------------
# roofline accounting
# ---------------------------------------------------------------------------

def test_active_params_close_to_actual_dense():
    cfg = registry.get_reduced("llama3.2-1b")
    params, _ = tfm.init(jax.random.PRNGKey(0), cfg)
    actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    est = rl.active_param_count(cfg)
    # estimate excludes norms and (for tied) counts the head once
    assert 0.7 * actual < est < 1.3 * actual


def test_moe_active_far_below_total():
    cfg = registry.get_config("deepseek-v3-671b")
    active = rl.active_param_count(cfg)
    # ~37B active of 671B total
    assert 2.5e10 < active < 6e10


def test_model_flops_shapes():
    cfg = registry.get_config("qwen2-7b")
    tr = rl.model_flops_for(cfg, SHAPES["train_4k"])
    pf = rl.model_flops_for(cfg, SHAPES["prefill_32k"])
    dc = rl.model_flops_for(cfg, SHAPES["decode_32k"])
    assert tr > pf > dc
    assert tr / pf == pytest.approx(3.0, rel=0.01)   # 6ND vs 2ND same tokens


def test_dominant_and_mfu():
    r = rl.Roofline(compute_s=1.0, memory_s=2.0, collective_s=0.5,
                    flops=197e12, bytes_hbm=1.0, bytes_coll=1.0,
                    model_flops=256 * 197e12, chips=256)
    assert r.dominant == "memory"
    assert r.mfu == pytest.approx(0.5)
    assert r.flops_ratio == pytest.approx(1.0)
