"""Loop-aware HLO analyzer: synthetic-text units + a live lowering check."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_analysis as ha

SYNTH = """\
HloModule test, num_partitions=4

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] parameter(1)
  %y = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16] all-reduce(%y), replica_groups=[2,2]<=[4], to_apply=%add
  ROOT %t = (s32[], f32[8,16]) tuple(%i2, %ar)
}

%cond (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %j = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%j, %n), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %z = s32[] constant(0)
  %tup = (s32[], f32[8,16]) tuple(%z, %a)
  %wh = (s32[], f32[8,16]) while(%tup), condition=%cond, body=%body
  ROOT %out = f32[8,16] get-tuple-element(%wh), index=1
}
"""


def test_synthetic_while_trip_and_flops():
    res = ha.analyze(SYNTH, default_group=4)
    # dot: 2*8*16*16 = 4096 flops x 10 trips
    assert res["flops"] == 4096 * 10
    # all-reduce f32[8,16] = 512B, group 2 -> 2*512*(1/2) = 512 x 10 trips
    assert res["coll_total"] == 512 * 10
    assert res["num_collectives"] == 10


def test_live_scan_lowering_counts_trips():
    def f(ws, x):
        def body(c, w):
            return jnp.dot(c, w, preferred_element_type=jnp.float32), None
        out, _ = jax.lax.scan(body, x, ws)
        return out.sum()

    ws = jax.ShapeDtypeStruct((7, 32, 32), jnp.float32)
    xs = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    comp = jax.jit(f).lower(ws, xs).compile()
    res = ha.analyze(comp.as_text(), default_group=1)
    # 7 iterations x 2*8*32*32
    expect = 7 * 2 * 8 * 32 * 32
    assert res["flops"] == expect, (res["flops"], expect)


def test_remat_doubles_counted_flops():
    """Compiled FLOPs of grad(f) with remat exceed those without — the
    analyzer sees recomputation (the §Roofline flops_ratio signal)."""
    def mk(remat):
        def f(ws, x):
            def body(c, w):
                return jnp.tanh(jnp.dot(c, w)), None
            b = jax.checkpoint(body) if remat else body
            out, _ = jax.lax.scan(b, x, ws)
            return (out ** 2).sum()
        return jax.jit(jax.grad(f))

    ws = jnp.zeros((5, 16, 16))
    xs = jnp.zeros((4, 16))
    base = ha.analyze(mk(False).lower(ws, xs).compile().as_text(), 1)["flops"]
    remat = ha.analyze(mk(True).lower(ws, xs).compile().as_text(), 1)["flops"]
    assert remat > base


def test_group_size_parsing():
    hc = ha.HloCost("", 8)
    assert hc._group_size("replica_groups=[16,16]<=[256]") == 16
    assert hc._group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
    assert hc._group_size("source_target_pairs={{0,1}}") == 2
    assert hc._group_size("no groups here") == 8
