"""Spatially tiled Pallas superpack kernels: halo'd output tiles so no
plane ever leaves the Pallas route.

What this file proves:

- **bit-compatibility**: the tiled kernels accumulate each output pixel in
  exactly the order of the whole-plane kernels (tap-major inside a C tile,
  C tiles outer), so tiled and untiled outputs are bit-identical at equal
  (C_t, N_t) — asserted with ``array_equal``, not a tolerance;
- **oracle parity**: tiled outputs sit inside the ULP-scaled float64-oracle
  bound (``tests/conftest.py``) across strides, dilations, ragged channel
  tiles, ragged spatial tiles, and empty deconv phases;
- **plan-level fwd+VJP parity**: with the VMEM budget shrunk so small test
  geometries take the routes real segmentation/decoder planes take, the
  planned executors (both kinds) match the lax oracle forward and through
  ``jax.vjp`` on the superpack — and every batch bucket, B=64 included,
  stays on the Pallas route;
- **jaxpr proofs on reclaimed geometries**: layers that routed to ``taps``
  (big atrous planes: whole-plane VMEM infeasible *and* the fused tap-stack
  over the byte cap) or to an XLA fallback at HEAD now lower to exactly ONE
  ``pallas_call`` with zero ``dot_general`` outside it;
- the ``vmem_bytes_estimate_tiled`` accounting: double-buffered halo tile
  at the input itemsize, f32 accumulator at a fixed 4 bytes/elem.

The hypothesis sweep drives the same checkers as the fixed-case tests (thin
strategy plumbing over ``check_tiled_single`` / ``check_tiled_deconv``), so
hosts without hypothesis still exercise every code path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.plan as planmod
from repro.core import reference as ref
from repro.core.plan import (BATCH_BUCKETS, conv_spec, pick_vmem_tiles,
                             plan_conv)
from repro.kernels.untangled_conv import (untangled_conv2d_superpack_pallas,
                                          untangled_deconv2d_pallas)

from tests.conftest import (assert_close, assert_close_ulp, conv_oracle_f64,
                            count_eqns, vmem_budget)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # pragma: no cover - exercised on minimal hosts
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# checkers (shared between fixed cases and the hypothesis sweep)
# ---------------------------------------------------------------------------

def check_tiled_single(b, hp, wp, c, n, r, s, strides, dil, c_tile, n_tile,
                       sp_tiles, seed=0):
    """Tiled vs untiled bit-compat + f64-oracle parity for one valid
    (pre-padded) single-correlation case."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (b, hp, wp, c), jnp.float32)
    k = jax.random.normal(k2, (r, s, c, n), jnp.float32)
    sp = k.reshape(r * s * c, n)
    got = untangled_conv2d_superpack_pallas(
        x, sp, taps_hw=(r, s), strides=strides, rhs_dilation=dil,
        c_tile=c_tile, n_tile=n_tile, sp_tiles=sp_tiles, interpret=True)
    untiled = untangled_conv2d_superpack_pallas(
        x, sp, taps_hw=(r, s), strides=strides, rhs_dilation=dil,
        c_tile=c_tile, n_tile=n_tile, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(untiled))
    y64, amax64 = conv_oracle_f64(x, k, strides=strides, dilation=dil)
    assert_close_ulp(got, y64, amax64, n_terms=r * s * c)


def check_tiled_deconv(b, h, w, c, n, r, s, strides, pads, c_tile, n_tile,
                       sp_tiles, seed=0):
    """Tiled vs untiled bit-compat + lax-oracle parity for one transposed
    case (uniform phases — tile sizes are phase-output coordinates)."""
    plan = plan_conv(conv_spec("transposed", (b, h, w, c), (r, s, c, n),
                               strides=strides, padding=pads))
    assert plan.uniform, "tiled deconv checker needs uniform phases"
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (b, h, w, c), jnp.float32)
    k = jax.random.normal(k2, (r, s, c, n), jnp.float32)
    packed = plan.pack(k)
    xg = planmod._global_plane(plan, x)
    kw = dict(phases=plan.phases, out_hw=plan.out_hw, strides=strides,
              sum_uv=plan.sum_uv, c_tile=c_tile, n_tile=n_tile,
              out_dtype=x.dtype, interpret=True)
    got = untangled_deconv2d_pallas(xg, packed, sp_tiles=sp_tiles, **kw)
    untiled = untangled_deconv2d_pallas(xg, packed, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(untiled))
    want = ref.oracle_conv_transpose2d(x, k, strides=strides, padding=pads)
    assert_close(got, want, tol=2e-5)


# ---------------------------------------------------------------------------
# fixed-case kernel sweeps (run everywhere tier-1 runs)
# ---------------------------------------------------------------------------

SINGLE_CASES = [
    # (b, hp, wp, c, n, r, s, strides, dil, c_t, n_t, sp_tiles)
    (2, 13, 11, 5, 7, 3, 2, (1, 1), (1, 1), 8, 8, (4, 4)),    # ragged edge
    (1, 17, 17, 8, 8, 3, 3, (2, 2), (1, 1), 8, 8, (3, 5)),    # strided
    (1, 21, 21, 4, 4, 3, 3, (1, 1), (3, 3), 4, 4, (8, 8)),    # big halo
    (2, 14, 14, 130, 40, 2, 2, (2, 2), (2, 2), 128, 32, (2, 7)),  # ragged C
    (1, 9, 9, 3, 4, 1, 1, (1, 1), (1, 1), 8, 8, (4, 4)),      # 1x1, no halo
    (1, 16, 16, 6, 5, 3, 3, (1, 1), (1, 1), 8, 8, (16, 16)),  # 1 tile = plane
]


@pytest.mark.parametrize("case", SINGLE_CASES)
def test_tiled_single_bit_compat_and_oracle(case):
    check_tiled_single(*case, seed=abs(hash(case)) % (2 ** 31))


DECONV_CASES = [
    # (b, h, w, c, n, r, s, strides, pads, c_t, n_t, sp_tiles)
    (2, 8, 8, 6, 4, 5, 5, (2, 2), ((2, 3), (2, 3)), 8, 8, (3, 3)),  # DCGAN
    (1, 8, 8, 5, 4, 4, 4, (2, 2), ((1, 3), (1, 3)), 8, 8, (8, 2)),  # cGAN
    (2, 6, 6, 5, 4, 2, 2, (3, 3), ((0, 0), (0, 0)), 8, 8, (2, 3)),  # empty q
    (1, 7, 5, 4, 3, 3, 3, (1, 1), ((1, 1), (1, 1)), 4, 8, (3, 2)),  # stride 1
]


@pytest.mark.parametrize("case", DECONV_CASES)
def test_tiled_deconv_bit_compat_and_oracle(case):
    check_tiled_deconv(*case, seed=abs(hash(case)) % (2 ** 31))


# ---------------------------------------------------------------------------
# hypothesis property sweep over (plane, stride, dilation, halo, tile size)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 2), st.integers(6, 18), st.integers(6, 18),
           st.integers(1, 9), st.integers(1, 9), st.integers(1, 3),
           st.integers(1, 3), st.integers(1, 3), st.integers(1, 3),
           st.integers(1, 9), st.integers(1, 9), st.integers(0, 1))
    def test_tiled_single_property(b, hp, wp, c, n, r, s, stride, dil,
                                   toh, tow, ragged_c):
        if hp < (r - 1) * dil + 1 or wp < (s - 1) * dil + 1:
            return                      # no valid output
        c_t = max(1, c - 1) if ragged_c else c
        check_tiled_single(b, hp, wp, c, n, r, s, (stride, stride),
                           (dil, dil), c_t, 8, (toh, tow),
                           seed=b + hp * 13 + c * 7 + toh)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 2), st.integers(3, 8), st.integers(3, 8),
           st.integers(1, 6), st.integers(1, 5), st.integers(1, 5),
           st.integers(1, 3), st.integers(1, 6), st.integers(1, 6))
    def test_tiled_deconv_property(b, h, w, c, n, k, stride, tu, tv):
        from repro.models.gan import deconv_padding
        pads = deconv_padding(k, stride)    # out = stride*in -> uniform
        check_tiled_deconv(b, h, w, c, n, k, k, (stride, stride), pads,
                           8, 8, (tu, tv), seed=h * 11 + k + tu)


# ---------------------------------------------------------------------------
# plan-level: forced tiled routes, fwd + VJP vs the oracle, both kinds
# ---------------------------------------------------------------------------

TILED_ROUTE_CASES = [
    # (budget, h, w, c, n, r, s, strides, dil, pads)
    (48 * 1024, 24, 24, 8, 8, 3, 3, (1, 1), (2, 2), ((2, 2), (2, 2))),
    (20 * 1024, 24, 20, 6, 8, 3, 3, (2, 2), (1, 1), ((1, 1), (1, 1))),
    (20 * 1024, 33, 31, 4, 3, 4, 3, (3, 2), (2, 2), ((3, 2), (2, 2))),
]


@pytest.mark.parametrize("case", TILED_ROUTE_CASES)
def test_single_tiled_route_fwd_and_vjp_parity(case):
    budget, h, w, c, n, r, s, strides, dil, pads = case
    kind = "dilated" if dil != (1, 1) else "conv"
    with vmem_budget(budget):
        plan = plan_conv(conv_spec(kind, (1, h, w, c), (r, s, c, n),
                                   strides=strides, padding=pads,
                                   dilation=dil, backend="pallas"))
        route = plan.routes[0]
        assert route.path == "pallas" and route.sp_tiles is not None, route
        key = jax.random.PRNGKey(h)
        x = jax.random.normal(key, (2, h, w, c), jnp.float32)
        k = jax.random.normal(key, (r, s, c, n), jnp.float32)
        packed = plan.pack(k)
        want = ref.oracle_dilated_conv2d(x, k, dilation=dil, strides=strides,
                                         padding=pads)
        assert_close(plan.apply(x, packed), want)
        y, vjp = jax.vjp(plan.apply, x, packed)
        _, vjp_o = jax.vjp(lambda x, k: ref.oracle_dilated_conv2d(
            x, k, dilation=dil, strides=strides, padding=pads), x, k)
        dy = jax.random.normal(key, y.shape)
        (dx, dpk), (dx_o, dk_o) = vjp(dy), vjp_o(dy)
        assert dpk.shape == packed.shape       # grads stay superpacked
        assert_close(dx, dx_o, tol=1e-3)
        assert_close(plan.unpack(dpk), dk_o, tol=1e-3)


TILED_TRANSPOSED_CASES = [
    # (budget, h, w, c, n, r, s, strides, pads)
    (48 * 1024, 16, 16, 8, 8, 5, 5, (2, 2), ((2, 3), (2, 3))),   # DCGAN
    (48 * 1024, 16, 16, 8, 8, 4, 4, (2, 2), ((1, 3), (1, 3))),   # cGAN
]


@pytest.mark.parametrize("case", TILED_TRANSPOSED_CASES)
def test_transposed_tiled_route_fwd_and_vjp_parity(case):
    budget, h, w, c, n, r, s, strides, pads = case
    with vmem_budget(budget):
        plan = plan_conv(conv_spec("transposed", (1, h, w, c), (r, s, c, n),
                                   strides=strides, padding=pads,
                                   backend="pallas"))
        route = plan.routes[0]
        assert route.path == "pallas" and route.sp_tiles is not None, route
        key = jax.random.PRNGKey(h + r)
        x = jax.random.normal(key, (2, h, w, c), jnp.float32)
        k = jax.random.normal(key, (r, s, c, n), jnp.float32)
        packed = plan.pack(k)
        want = ref.oracle_conv_transpose2d(x, k, strides=strides,
                                           padding=pads)
        assert_close(plan.apply(x, packed), want)
        y, vjp = jax.vjp(plan.apply, x, packed)
        _, vjp_o = jax.vjp(lambda x, k: ref.oracle_conv_transpose2d(
            x, k, strides=strides, padding=pads), x, k)
        dy = jax.random.normal(key, y.shape)
        (dx, dpk), (dx_o, dk_o) = vjp(dy), vjp_o(dy)
        assert dpk.shape == packed.shape
        assert_close(dx, dx_o, tol=1e-3)
        assert_close(plan.unpack(dpk), dk_o, tol=1e-3)


def test_every_bucket_stays_on_the_pallas_route():
    """Under a tight budget the whole bucket table — B=64 included — rides
    the tiled Pallas route (the old verdict sent big buckets to 'taps')."""
    with vmem_budget(48 * 1024):
        plan = plan_conv(conv_spec("dilated", (1, 24, 24, 8), (3, 3, 8, 8),
                                   dilation=(2, 2), padding=((2, 2), (2, 2)),
                                   backend="pallas"))
        assert tuple(r.batch for r in plan.routes) == BATCH_BUCKETS
        for route in plan.routes:
            assert route.path == "pallas" and route.sp_tiles is not None
        assert plan.route_for_batch(64).sp_tiles is not None


# ---------------------------------------------------------------------------
# jaxpr proofs: reclaimed geometries lower to exactly ONE pallas_call
# ---------------------------------------------------------------------------

def test_big_atrous_plane_reclaims_pallas_from_taps():
    """DeepLab-scale 385x385 atrous layer (the BENCH_dilated addition): at
    HEAD the pallas verdict failed (whole plane over the VMEM budget even at
    the smallest C tile) and the fused tap-stack busted _PLANE_BYTES_MAX, so
    backend='pallas' fell all the way to 'taps'.  Now it routes to the tiled
    kernel: one pallas_call, no XLA GEMM, at every bucket."""
    h, c, n, k, d = 385, 32, 32, 3, 2
    pad = ((d, d), (d, d))
    itemsize = 4
    # the HEAD verdicts, re-derived from the plan constants
    assert pick_vmem_tiles(h + 2 * d, h + 2 * d, c, n, k, k, h, h,
                           itemsize) is None
    assert 4 * 1 * h * h * k * k * c > planmod._PLANE_BYTES_MAX
    plan = plan_conv(conv_spec("dilated", (1, h, h, c), (k, k, c, n),
                               dilation=(d, d), padding=pad,
                               backend="pallas"))
    for route in plan.routes:
        assert route.path == "pallas" and route.sp_tiles is not None, route
    x = jnp.zeros((1, h, h, c), jnp.float32)
    packed = jnp.zeros((k * k * c, n), jnp.float32)
    jaxpr = jax.make_jaxpr(plan.apply)(x, packed)
    assert count_eqns(jaxpr.jaxpr, "pallas_call") == 1
    assert count_eqns(jaxpr.jaxpr, "dot_general") == 0


def test_big_decoder_plane_reclaims_pallas_from_xla():
    """A 256->512 px VAE-decoder-scale deconv: at HEAD the whole-plane fused
    kernel was VMEM-infeasible so backend='pallas' fell back to an XLA wide
    GEMM; now the tiled kernel keeps it on the Pallas route — one
    pallas_call, zero dot_general."""
    from repro.core.plan import pick_fused_tiles
    from repro.models.gan import deconv_padding
    h, c, n, k, s = 256, 32, 16, 4, 2
    pads = deconv_padding(k, s)
    plan = plan_conv(conv_spec("transposed", (1, h, h, c), (k, k, c, n),
                               strides=(s, s), padding=pads,
                               backend="pallas"))
    (glh, ghh), (glw, ghw) = plan.gpad
    assert pick_fused_tiles(h + glh + ghh, h + glw + ghw, c, n,
                            plan.total_taps, plan.sum_uv, *plan.out_hw,
                            itemsize=4) is None      # HEAD: no whole-plane fit
    for route in plan.routes:
        assert route.path == "pallas" and route.sp_tiles is not None, route
    x = jnp.zeros((1, h, h, c), jnp.float32)
    packed = jnp.zeros((plan.total_taps * c, n), jnp.float32)
    jaxpr = jax.make_jaxpr(plan.apply)(x, packed)
    assert count_eqns(jaxpr.jaxpr, "pallas_call") == 1
    assert count_eqns(jaxpr.jaxpr, "dot_general") == 0


# ---------------------------------------------------------------------------
# the tiled VMEM estimate: double buffer at input itemsize, f32 accumulator
# ---------------------------------------------------------------------------

def test_vmem_estimate_tiled_accounting():
    from repro.kernels.untangled_conv import (halo_extent,
                                              vmem_bytes_estimate_tiled)
    tin_h = halo_extent(8, 3, 1, 2)      # (8-1)*1 + (3-1)*2 + 1 = 12
    assert tin_h == 12
    assert halo_extent(8, 3, 2, 1) == 17  # strided footprint dominates
    for itemsize in (1, 2, 4):
        est = vmem_bytes_estimate_tiled(12, 12, 8, 9, 8, 64, itemsize)
        streamed = itemsize * (2 * 12 * 12 * 8 + 9 * 8 * 8 + 64 * 8)
        # f32 accumulator contribution is itemsize-independent
        assert est - streamed == 4 * 64 * 8
    # the double buffer is charged twice: halving the halo tile saves
    # exactly one tile of bytes per slot
    a = vmem_bytes_estimate_tiled(12, 12, 8, 9, 8, 64)
    b = vmem_bytes_estimate_tiled(6, 12, 8, 9, 8, 64)
    assert a - b == 4 * 2 * 6 * 12 * 8


def test_route_tiles_fit_budget():
    """The tile search's winning (C_t, N_t, sp_tiles) actually fits the
    budget it was searched against."""
    from repro.kernels.untangled_conv import (halo_extent,
                                              vmem_bytes_estimate_tiled)
    h, c, n, k, d = 385, 32, 32, 3, 2
    plan = plan_conv(conv_spec("dilated", (1, h, h, c), (k, k, c, n),
                               dilation=(d, d), padding=((d, d), (d, d)),
                               backend="pallas"))
    route = plan.routes[0]
    c_t, n_t = route.tiles
    toh, tow = route.sp_tiles
    est = vmem_bytes_estimate_tiled(
        halo_extent(toh, k, 1, d), halo_extent(tow, k, 1, d),
        c_t, k * k, n_t, toh * tow)
    assert est <= planmod._VMEM_BUDGET
