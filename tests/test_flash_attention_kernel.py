"""Pallas flash-attention kernel vs the pure-jnp oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ref import flash_attention_ref
from repro.layers.attention import flash_attention


def ref_attention(q, k, v, causal, window, scale=None):
    return flash_attention_ref(q.astype(jnp.float32),
                               k.astype(jnp.float32),
                               v.astype(jnp.float32),
                               causal=causal, window=window, scale=scale)


@pytest.mark.parametrize("b,s,h,kh,d,causal,window", [
    (1, 256, 4, 4, 64, True, 0),
    (2, 256, 8, 2, 32, True, 0),        # GQA
    (1, 512, 4, 1, 64, True, 128),      # MQA + sliding window
    (1, 256, 2, 2, 64, False, 0),       # bidirectional (encoder)
])
def test_flash_kernel_matches_ref(b, s, h, kh, d, causal, window):
    key = jax.random.PRNGKey(s + h)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kh, d), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 bq=128, ck=128, interpret=True)
    want = ref_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_kernel_matches_jnp_flash_bf16():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, causal=True, bq=128, ck=128,
                                 interpret=True)
    want = flash_attention(q, k, v, causal=True, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)
