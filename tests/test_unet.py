"""The diffusion U-Net workload (``models/unet.py``).

What this file proves:

- **every kind, one pass**: the planned site list covers 'conv' (stem /
  strided downs / skip-fuse / head), 'dilated' (bottleneck), and
  'transposed' (ups) — and the ups plan the sub-pixel route, so a single
  forward exercises every route family the engine has.
- **shapes + schedule**: ``unet_apply`` is shape-preserving, the cosine
  ``alpha_bar`` is monotone on [0, 1] with the right endpoints.
- **gradients through the packed layout**: the DSM loss is finite and
  every parameter leaf — including both halves of every skip concat and
  the timestep projections — receives a nonzero cotangent.
- **int8 twin**: flipping ``wdtype`` re-plans every site onto quantized
  superpacks with identical route paths, and its forward tracks the f32
  twin (weights quantized from the same f32 draw) within the documented
  serving bound.
- **the denoising loop**: ``denoise_loop`` == ``steps`` sequential
  applications of ``denoise_step`` — the contract the serving bench's
  chained-request driver depends on.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import unet
from repro.models.unet import UNET_TINY, UNetConfig

from tests.conftest import assert_close


@pytest.fixture(scope="module")
def tiny_params():
    p, _ = unet.unet_init(jax.random.PRNGKey(0), UNET_TINY)
    return p


def x_of(cfg, b=2, seed=1):
    return jax.random.normal(
        jax.random.PRNGKey(seed),
        (b, cfg.image_hw, cfg.image_hw, cfg.in_c), jnp.float32)


def test_sites_cover_every_kind_and_plan_the_subpixel_route():
    routes = unet.unet_route_summary(UNET_TINY)
    assert {k for k, _ in routes.values()} == {"conv", "dilated",
                                               "transposed"}
    ups = {s: p for s, (k, p) in routes.items() if k == "transposed"}
    assert ups and all(p == "pixel_shuffle" for p in ups.values()), routes
    # forward order, one entry per site, both decoder halves present
    names = list(routes)
    assert names[0] == "stem" and names[-1] == "head"
    assert {"up0", "fuse0", "up1", "fuse1"} <= set(names)


def test_apply_preserves_shape_and_is_finite(tiny_params):
    cfg = UNET_TINY
    x = x_of(cfg)
    t = jnp.array([0.1, 0.9], jnp.float32)
    eps = unet.unet_apply(tiny_params, x, t, cfg)
    assert eps.shape == x.shape and eps.dtype == x.dtype
    assert bool(jnp.all(jnp.isfinite(eps)))


def test_alpha_bar_schedule_shape():
    t = jnp.linspace(0.0, 1.0, 33)
    ab = unet.alpha_bar(t)
    assert float(ab[0]) == pytest.approx(1.0, abs=2e-3)
    assert float(ab[-1]) == pytest.approx(0.0, abs=1e-3)
    assert bool(jnp.all(jnp.diff(ab) < 0))          # strictly decreasing


def test_loss_finite_and_every_leaf_gets_gradient(tiny_params):
    cfg = UNET_TINY
    loss, grads = jax.value_and_grad(unet.unet_loss)(
        tiny_params, x_of(cfg), jax.random.PRNGKey(7), cfg)
    assert bool(jnp.isfinite(loss))
    dead = [k for k, g in grads.items() if not bool(jnp.any(g))]
    assert not dead, f"zero-gradient leaves: {dead}"
    assert set(grads) == set(tiny_params)


def test_int8_twin_same_routes_and_bounded_forward():
    cfg = UNET_TINY
    cfg8 = dataclasses.replace(cfg, name="unet-tiny-w8", wdtype="int8")
    assert ({s: p for s, (_, p) in unet.unet_route_summary(cfg8).items()}
            == {s: p for s, (_, p) in unet.unet_route_summary(cfg).items()})
    p32, _ = unet.unet_init(jax.random.PRNGKey(0), cfg)
    p8, _ = unet.unet_init(jax.random.PRNGKey(0), cfg8)
    x = x_of(cfg)
    t = jnp.full((2,), 0.5, jnp.float32)
    y32 = unet.unet_apply(p32, x, t, cfg)
    y8 = unet.unet_apply(p8, x, t, cfg8)
    # int8 weight grids: small relative drift, never garbage
    dev = float(jnp.max(jnp.abs(y8 - y32)))
    ref = float(jnp.max(jnp.abs(y32)))
    assert dev < 0.15 * ref + 1e-3, (dev, ref)


def test_denoise_loop_is_sequential_steps(tiny_params):
    cfg = UNET_TINY
    steps = 3
    x_t = x_of(cfg, b=1, seed=9)
    want = x_t
    for s in reversed(range(steps)):
        tf = jnp.full((1,), (s + 1) / steps, jnp.float32)
        want = unet.denoise_step(tiny_params, want, tf, cfg, 1.0 / steps)
    got = unet.denoise_loop(tiny_params, x_t, cfg, steps)
    assert_close(got, want, tol=1e-6)
    assert bool(jnp.all(jnp.isfinite(got)))


def test_config_widths_and_site_count():
    cfg = UNetConfig("u", image_hw=32, base=16, depth=3)
    assert [cfg.width(i) for i in range(4)] == [16, 32, 64, 128]
    assert [cfg.hw(i) for i in range(4)] == [32, 16, 8, 4]
    sites = unet.unet_sites(cfg)
    # stem + depth downs + mids + depth·(up+fuse) + head
    assert len(sites) == 1 + cfg.depth + len(cfg.mid_dilations) \
        + 2 * cfg.depth + 1
