"""SegNet (DilatedNet-style segmentation) on the engine: planned sites,
superpacked weights, shapes, and a training step through the custom VJPs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import segnet
from repro.models.segnet import SegNetConfig

CFG = segnet.SEGNET_TINY


def test_plans_cover_all_sites_and_kinds():
    plans = segnet.segnet_plans(CFG)
    assert len(plans) == len(CFG.layers)
    kinds = [p.spec.kind for p in plans]
    assert kinds.count("dilated") == 5             # context module
    assert kinds.count("conv") == 5                # front-end + head
    dils = [p.spec.dilation[0] for p in plans if p.spec.kind == "dilated"]
    assert dils == [1, 2, 4, 8, 1]                 # DilatedNet schedule
    # every site rides a planned single-correlation route
    assert all(p.path in ("fused_tap", "taps", "pallas") for p in plans)


def test_params_are_superpacked():
    p, s = segnet.segnet_init(jax.random.PRNGKey(0), CFG)
    for i, (l, plan) in enumerate(zip(CFG.layers, segnet.segnet_plans(CFG))):
        assert p[f"w{i}"].shape == (l.kernel * l.kernel * l.in_c, l.out_c)
        assert plan.unpack(p[f"w{i}"]).shape == (l.kernel, l.kernel,
                                                 l.in_c, l.out_c)


def test_forward_shapes_and_finiteness():
    p, _ = segnet.segnet_init(jax.random.PRNGKey(1), CFG)
    x = jax.random.normal(jax.random.PRNGKey(2),
                          (2, CFG.in_hw, CFG.in_hw, CFG.in_c), jnp.float32)
    y = segnet.segnet_apply(p, x, CFG)
    assert y.shape == (2, CFG.out_hw, CFG.out_hw, CFG.num_classes)
    assert np.isfinite(np.asarray(y)).all()
    up = segnet.upsample_logits(y)
    assert up.shape == (2, CFG.in_hw, CFG.in_hw, CFG.num_classes)


def test_atrous_padding_preserves_resolution():
    for k, d in ((3, 1), (3, 2), (3, 4), (3, 8)):
        (pl, ph), _ = segnet.atrous_padding(k, d)
        # out = in + pl + ph - (k-1)*d  (stride 1)
        assert pl + ph == (k - 1) * d


def test_train_step_reduces_loss():
    key = jax.random.PRNGKey(3)
    kx, kl, kp = jax.random.split(key, 3)
    p, _ = segnet.segnet_init(kp, CFG)
    x = jax.random.normal(kx, (4, CFG.in_hw, CFG.in_hw, CFG.in_c),
                          jnp.float32)
    labels = jax.random.randint(kl, (4, CFG.out_hw, CFG.out_hw), 0,
                                CFG.num_classes)

    @jax.jit
    def step(p):
        l, g = jax.value_and_grad(
            lambda p: segnet.segnet_loss(p, x, labels, CFG))(p)
        return jax.tree.map(lambda a, b: a - 0.2 * b, p, g), l

    losses = []
    for _ in range(8):
        p, l = step(p)
        losses.append(float(l))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_grads_stay_superpacked():
    p, _ = segnet.segnet_init(jax.random.PRNGKey(4), CFG)
    x = jax.random.normal(jax.random.PRNGKey(5),
                          (1, CFG.in_hw, CFG.in_hw, CFG.in_c), jnp.float32)
    labels = jnp.zeros((1, CFG.out_hw, CFG.out_hw), jnp.int32)
    g = jax.grad(lambda p: segnet.segnet_loss(p, x, labels, CFG))(p)
    for k in p:
        assert g[k].shape == p[k].shape


def test_pallas_backend_matches_xla():
    cfg_pl = SegNetConfig("tiny-pallas", in_hw=CFG.in_hw, width=CFG.width,
                          num_classes=CFG.num_classes, backend="pallas")
    p, _ = segnet.segnet_init(jax.random.PRNGKey(6), CFG)
    x = jax.random.normal(jax.random.PRNGKey(7),
                          (1, CFG.in_hw, CFG.in_hw, CFG.in_c), jnp.float32)
    y_x = segnet.segnet_apply(p, x, CFG)
    y_p = segnet.segnet_apply(p, x, cfg_pl)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_x),
                               rtol=2e-4, atol=2e-4)
