"""SLO-aware control plane: admission, shed, priority/starvation,
backfill, multi-model routing, straggler plumbing, elastic degrade, and
the acceptance fault-injection integration tests — device loss mid-batch
must re-queue + replay with zero drops, zero duplicates, and responses
bit-equal to a fault-free run (image bucket launches AND LM decode)."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import segnet, transformer as tfm
from repro.runtime.fault import FailureInjector
from repro.serving.control_plane import ControlPlane, ServeRequest

ECHO_COSTS = {1: 1e-4, 4: 2e-4, 16: 5e-4, 64: 1e-3}


def echo_plane(*, buckets=(1, 4, 16, 64), costs=None, **kw):
    """Control plane over a trivially-verifiable jitted backend (x * 2)."""
    cp = ControlPlane(**kw)
    be = cp.register_image_model("echo", lambda x: x * 2.0,
                                 np.zeros((4,), np.float32),
                                 buckets=buckets)
    if costs is not None:
        be.batcher.bucket_cost_s = {b: c for b, c in costs.items()
                                    if b in be.batcher.buckets}
        be.batcher._sched_memo = {0: (0.0, 0)}
    return cp, be


def payloads(n, dim=4, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(dim).astype(np.float32) for _ in range(n)]


# ---------------------------------------------------------------------------
# admission + shed
# ---------------------------------------------------------------------------

def test_admission_rejects_when_backlog_blows_slo():
    cp, _ = echo_plane(costs=ECHO_COSTS)
    for i, z in enumerate(payloads(16)):          # backlog: 16 * >=0.1 ms
        assert cp.submit(ServeRequest(rid=i, model="echo", payload=z))
    late = ServeRequest(rid=99, model="echo", payload=payloads(1)[0],
                        slo_ms=0.01)              # deadline < backlog estimate
    assert not cp.submit(late)
    assert late.status == "rejected" and late.reason.startswith("admission:")
    ok = ServeRequest(rid=100, model="echo", payload=payloads(1)[0],
                      slo_ms=10_000.0)
    assert cp.submit(ok)                          # generous slo admits
    cp.run()
    st = cp.stats()
    assert st["rejected"] == 1 and st["served"] == 17
    assert st["submitted"] == st["served"] + st["rejected"] + st["shed"]


def test_admission_permissive_without_measured_costs():
    cp, be = echo_plane()                         # no costs measured yet
    assert not be.batcher.bucket_cost_s
    assert cp.submit(ServeRequest(rid=0, model="echo",
                                  payload=payloads(1)[0], slo_ms=1e-6))
    assert cp.queues["echo"]["interactive"]


def test_admission_disabled_never_rejects():
    cp, _ = echo_plane(costs=ECHO_COSTS, admission=False)
    for i, z in enumerate(payloads(32)):
        assert cp.submit(ServeRequest(rid=i, model="echo", payload=z,
                                      slo_ms=1e-6))
    assert cp.stats()["rejected"] == 0


def test_shed_on_expiry_before_launch():
    cp, _ = echo_plane()
    expired = ServeRequest(rid=0, model="echo", payload=payloads(1)[0],
                           slo_ms=1.0, t_arrival=time.perf_counter() - 1.0)
    live = ServeRequest(rid=1, model="echo", payload=payloads(1, seed=1)[0],
                        slo_ms=60_000.0)
    cp.run([expired, live])
    assert expired.status == "shed" and expired.reason.startswith("shed:")
    assert expired.out is None                    # never computed
    assert live.status == "served" and live.in_slo
    st = cp.stats()
    assert st["shed"] == 1 and st["served"] == 1 and st["queued"] == 0
    assert st["per_class"]["interactive"]["shed"] == 1


# ---------------------------------------------------------------------------
# priority, starvation bound, backfill
# ---------------------------------------------------------------------------

def test_interactive_launches_before_fresh_batch():
    cp, _ = echo_plane(buckets=(1,))
    b = ServeRequest(rid=0, model="echo", payload=payloads(1)[0],
                     priority="batch")
    i = ServeRequest(rid=1, model="echo", payload=payloads(1, seed=1)[0],
                     priority="interactive")
    cp.run([b, i])                                # batch arrived first...
    assert [r.rid for r in cp.done] == [1, 0]     # ...interactive still wins


def test_starvation_bound_flips_to_batch():
    cp, _ = echo_plane(buckets=(1,), starvation_ms=50.0)
    old_batch = ServeRequest(rid=0, model="echo", payload=payloads(1)[0],
                             priority="batch",
                             t_arrival=time.perf_counter() - 1.0)
    fresh = ServeRequest(rid=1, model="echo",
                         payload=payloads(1, seed=1)[0])
    cp.run([old_batch, fresh])
    assert [r.rid for r in cp.done] == [0, 1]     # starved batch goes first


def test_launch_backfills_other_class():
    cp, be = echo_plane(buckets=(1, 4))
    reqs = [ServeRequest(rid=i, model="echo", payload=z,
                         priority="interactive" if i < 3 else "batch")
            for i, z in enumerate(payloads(4))]
    cp.run(reqs)
    # one bucket-4 launch: 3 interactive + 1 batch backfilled into the pad
    assert be.batcher.launches == [(4, 4)]
    assert cp.stats()["per_model"]["echo"]["pad_fraction"] == 0.0
    assert sorted(r.rid for r in cp.done) == [0, 1, 2, 3]


def test_bad_priority_and_unknown_model_raise():
    cp, _ = echo_plane()
    with pytest.raises(ValueError, match="priority"):
        ServeRequest(rid=0, model="echo", payload=payloads(1)[0],
                     priority="realtime")
    with pytest.raises(ValueError, match="unknown model"):
        cp.submit(ServeRequest(rid=0, model="nope", payload=payloads(1)[0]))
    with pytest.raises(ValueError, match="already registered"):
        cp.register_image_model("echo", lambda x: x,
                                np.zeros((4,), np.float32))


# ---------------------------------------------------------------------------
# multi-model hosting
# ---------------------------------------------------------------------------

def test_multi_model_routing_and_per_model_stats():
    cp = ControlPlane()
    cp.register_image_model("x2", lambda x: x * 2.0,
                            np.zeros((4,), np.float32), buckets=(1, 4))
    cp.register_image_model("x3", lambda x: x * 3.0,
                            np.zeros((4,), np.float32), buckets=(1, 4))
    zs = payloads(8)
    cp.run([ServeRequest(rid=i, model="x2" if i % 2 == 0 else "x3",
                         payload=z) for i, z in enumerate(zs)])
    assert len(cp.done) == 8 and cp.pending() == 0
    for r in cp.done:
        np.testing.assert_array_equal(
            r.out, zs[r.rid] * (2.0 if r.model == "x2" else 3.0))
    pm = cp.stats()["per_model"]
    assert pm["x2"]["served"] == 4 and pm["x3"]["served"] == 4


def test_edf_across_models_picks_earliest_deadline():
    cp = ControlPlane()
    cp.register_image_model("a", lambda x: x + 1.0,
                            np.zeros((4,), np.float32), buckets=(1,))
    cp.register_image_model("b", lambda x: x - 1.0,
                            np.zeros((4,), np.float32), buckets=(1,))
    # model b's head has the earlier deadline: it must launch first even
    # though a's request arrived first
    cp.submit(ServeRequest(rid=0, model="a", payload=payloads(1)[0],
                           slo_ms=60_000.0))
    cp.submit(ServeRequest(rid=1, model="b", payload=payloads(1, seed=1)[0],
                           slo_ms=5_000.0))
    done = cp.pump(drain=True)
    assert [r.rid for r in done] == [1]
    cp.run()
    assert sorted(r.rid for r in cp.done) == [0, 1]


# ---------------------------------------------------------------------------
# fault injection: re-queue + replay (the acceptance tests)
# ---------------------------------------------------------------------------

def test_fault_replay_echo_bit_equal_zero_drops_zero_dups():
    zs = payloads(24)
    reqs = lambda: [ServeRequest(rid=i, model="echo", payload=z)  # noqa: E731
                    for i, z in enumerate(zs)]
    ref, _ = echo_plane(costs=ECHO_COSTS)
    ref.run(reqs())
    # kill the first launch mid-batch: its requests re-queue + replay
    cp, _ = echo_plane(costs=ECHO_COSTS,
                       injector=FailureInjector((1,)))
    cp.run(reqs())
    st = cp.stats()
    assert st["faults"]["events"] == 1
    assert st["faults"]["records"][0]["live"] == 16   # the bucket-16 launch
    assert st["replayed_requests"] == 16
    assert st["served"] == 24 and st["queued"] == 0   # zero drops
    rids = [r.rid for r in cp.done]
    assert len(rids) == len(set(rids))                # zero duplicates
    got, want = cp.results(), ref.results()
    assert sorted(got) == sorted(want)
    for rid in got:                                   # bit-equal replay
        np.testing.assert_array_equal(got[rid], want[rid])


def test_fault_replay_preserves_arrival_order_and_priority():
    zs = payloads(4)
    cp, _ = echo_plane(buckets=(1, 4), injector=FailureInjector((1,)))
    reqs = [ServeRequest(rid=i, model="echo", payload=z,
                         priority="interactive" if i < 2 else "batch")
            for i, z in enumerate(zs)]
    cp.run(reqs)
    # the killed launch's requests went back to the FRONT of their own
    # class queues in arrival order, so the replay serves rid order again
    assert sorted(r.rid for r in cp.done) == [0, 1, 2, 3]
    assert all(r.replays == 1 for r in cp.done)
    by_rid = {r.rid: r for r in cp.done}
    assert by_rid[2].priority == "batch"              # class survived replay


def test_fault_replay_segnet_integration_bit_equal():
    """Device loss mid-batch on a real planned model: the second bucket
    launch dies, its live requests re-queue + replay, and every response
    is bit-equal to the fault-free reference run."""
    cfg = segnet.SEGNET_TINY
    params, _ = segnet.segnet_init(jax.random.PRNGKey(0), cfg)

    def serve_fn(x):
        return jnp.argmax(segnet.segnet_apply(params, x, cfg), axis=-1)

    proto = np.zeros((cfg.in_hw, cfg.in_hw, cfg.in_c), np.float32)
    rng = np.random.default_rng(0)
    xs = [rng.uniform(-1, 1, proto.shape).astype(np.float32)
          for _ in range(8)]
    reqs = lambda: [ServeRequest(rid=i, model="seg", payload=x)  # noqa: E731
                    for i, x in enumerate(xs)]

    ref = ControlPlane()
    ref.register_image_model("seg", serve_fn, proto, buckets=(1, 4))
    ref.run(reqs())
    cp = ControlPlane(injector=FailureInjector((2,)))
    cp.register_image_model("seg", serve_fn, proto, buckets=(1, 4))
    cp.run(reqs())

    st = cp.stats()
    assert st["faults"]["events"] == 1 and st["replayed_requests"] == 4
    assert st["served"] == 8 and st["queued"] == 0
    rids = [r.rid for r in cp.done]
    assert len(rids) == len(set(rids))
    got, want = cp.results(), ref.results()
    assert sorted(got) == sorted(want) == list(range(8))
    for rid in got:
        np.testing.assert_array_equal(got[rid], want[rid])


def test_fault_replay_lm_decode_bit_equal():
    """NodeFailure mid-decode evicts every live slot; the control plane
    re-queues the prompts and the replayed greedy decode produces tokens
    bit-equal to a fault-free run (deterministic argmax)."""
    cfg = registry.get_reduced("llama3.2-1b")
    params, _ = tfm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, p).astype(np.int32)
               for p in (3, 5, 2, 4)]
    reqs = lambda: [ServeRequest(rid=i, model="lm", payload=p,  # noqa: E731
                                 max_new=4) for i, p in enumerate(prompts)]

    ref = ControlPlane()
    ref.register_lm_model("lm", cfg, params, slots=2, max_len=16)
    ref.run(reqs())
    cp = ControlPlane(injector=FailureInjector((3,)))
    be = cp.register_lm_model("lm", cfg, params, slots=2, max_len=16)
    cp.run(reqs())

    st = cp.stats()
    assert st["faults"]["events"] == 1
    assert st["replayed_requests"] >= 1
    assert st["served"] == 4 and st["queued"] == 0 and not be.active()
    rids = [r.rid for r in cp.done]
    assert len(rids) == len(set(rids))
    got, want = cp.results(), ref.results()
    assert sorted(got) == sorted(want) == list(range(4))
    for rid in got:
        np.testing.assert_array_equal(got[rid], want[rid])
    assert st["per_model"]["lm"]["steps"] > 0
    assert st["per_model"]["lm"]["step_cost_ms"] > 0


def test_duplicate_commit_guard():
    cp, _ = echo_plane()
    r = ServeRequest(rid=7, model="echo", payload=payloads(1)[0])
    cp._commit(dataclasses.replace(r))
    with pytest.raises(AssertionError, match="answered twice"):
        cp._commit(dataclasses.replace(r))


# ---------------------------------------------------------------------------
# stragglers + elastic degrade
# ---------------------------------------------------------------------------

def test_straggler_alert_surfaces_in_stats():
    cp, _ = echo_plane(straggler_warmup=3)
    for _ in range(10):
        cp._observe("echo", 16, 0.01)
    cp._observe("echo", 16, 1.0)                  # 100x spike on one bucket
    for _ in range(10):
        cp._observe("echo", 4, 0.01)              # healthy bucket
    st = cp.stats()["stragglers"]
    assert st["events"] == 1 and st["slow_buckets"] == ["echo/b16"]


def test_degrade_then_serve():
    cp, _ = echo_plane()
    mesh = cp.degrade(1)                          # all but one replica lost
    assert mesh.shape["data"] == 1
    zs = payloads(4)
    cp.run([ServeRequest(rid=i, model="echo", payload=z)
            for i, z in enumerate(zs)])
    assert len(cp.done) == 4
    for r in cp.done:
        np.testing.assert_array_equal(r.out, zs[r.rid] * 2.0)
    deg = cp.stats()["faults"]["degraded"]
    assert deg["devices_left"] == 1


def test_on_fault_hook_can_degrade():
    calls = []
    cp, _ = echo_plane(injector=FailureInjector((1,)),
                       on_fault=lambda plane, err: calls.append(
                           plane.degrade(1)))
    cp.run([ServeRequest(rid=i, model="echo", payload=z)
            for i, z in enumerate(payloads(4))])
    assert len(calls) == 1                        # rung two reached
    assert len(cp.done) == 4                      # served on the shrunk mesh


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------

def test_conservation_and_goodput_accounting():
    cp, _ = echo_plane(costs=ECHO_COSTS)
    reqs = [ServeRequest(rid=i, model="echo", payload=z,
                         slo_ms=0.01 if i % 3 == 0 else 60_000.0)
            for i, z in enumerate(payloads(30))]
    cp.run(reqs)
    st = cp.stats()
    assert st["queued"] == 0
    assert st["submitted"] == 30
    assert st["submitted"] == st["served"] + st["rejected"] + st["shed"]
    assert st["rejected"] + st["shed"] > 0        # tight slos did fail
    good = sum(1 for r in cp.done if r.in_slo is not False)
    assert st["goodput_under_slo"] == pytest.approx(good / 30)
    for cls in ("interactive", "batch"):
        assert set(st["per_class"][cls]) >= {
            "p50_ms", "p95_ms", "p99_ms", "slo_miss",
            "rejected", "shed", "goodput_rps", "goodput_under_slo"}


def test_no_slo_requests_never_rejected_or_shed():
    cp, _ = echo_plane(costs=ECHO_COSTS)
    cp.run([ServeRequest(rid=i, model="echo", payload=z)
            for i, z in enumerate(payloads(70))])
    st = cp.stats()
    assert st["served"] == 70 and st["rejected"] == 0 and st["shed"] == 0
    assert st["goodput_under_slo"] == 1.0
    assert all(r.in_slo is None for r in cp.done)


# ---------------------------------------------------------------------------
# one injected clock across both scheduling layers
# ---------------------------------------------------------------------------

def test_injected_clock_is_shared_and_max_wait_boundary_is_exact():
    """Admission/shed (control plane) and max-wait coalescing (batcher)
    run on ONE injected monotonic clock.  The boundary probe — a partial
    bucket whose oldest request has waited exactly ``max_wait`` — launches
    at the boundary and not a tick before.  Under mixed clocks this test
    fails: a real ``perf_counter`` "now" against a fake-clock arrival
    stamp makes the wait look like hours, launching on the first pump."""
    t = [0.0]                     # epoch 0: boundary sums stay exact floats
    cp, be = echo_plane(costs=ECHO_COSTS, clock=lambda: t[0])
    assert be.batcher.clock is cp.clock           # one clock, both layers

    req = ServeRequest(rid=0, model="echo", payload=payloads(1)[0])
    assert cp.submit(req)
    assert req.t_arrival == 0.0                   # stamped by the fake clock

    wait = be.max_wait_s
    t[0] = wait - 1e-6                            # one microsecond early
    assert cp.pump() == []                        # partial bucket: coalesce
    assert req.status == "queued"

    t[0] = wait                                   # exactly max_wait
    done = cp.pump()
    assert [r.rid for r in done] == [0]
    np.testing.assert_allclose(done[0].out, req.payload * 2.0)
    # completion timestamps come from the same clock domain
    assert done[0].t_done == t[0]
    assert done[0].latency_s == pytest.approx(wait)


def test_injected_clock_governs_shed_and_deadline():
    """Deadline math (admission estimate, shed-on-expiry) must use the
    injected clock too — a request whose SLO expires in fake time is shed
    even though zero real time elapsed."""
    t = [0.0]
    cp, _ = echo_plane(costs=ECHO_COSTS, clock=lambda: t[0])
    req = ServeRequest(rid=1, model="echo", payload=payloads(1)[0],
                       slo_ms=5.0)
    assert cp.submit(req)
    t[0] = 0.1                                    # 100 ms of fake time
    assert cp.pump(drain=True) == []
    assert req.status == "shed" and "deadline passed" in req.reason
    st = cp.stats()
    assert st["shed"] == 1 and st["served"] == 0
