"""Per-architecture smoke tests: reduced config, one forward + train step on
CPU, asserting output shapes and finiteness; plus one decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.models import transformer as tfm

B, S = 2, 16


def make_batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.frontend != "none":
        batch["embeds"] = jax.random.normal(
            ks[0], (B, S, cfg.d_model), jnp.bfloat16)
    batch["inputs"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    batch["targets"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size)
    if cfg.is_encoder_decoder:
        batch["src_embeds"] = jax.random.normal(
            ks[0], (B, 12, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = registry.get_reduced(arch)
    assert cfg.total_layers() >= 2
    key = jax.random.PRNGKey(0)
    params, specs = tfm.init(key, cfg)
    # specs mirror params
    jax.tree.map(lambda a, b: None, params,
                 jax.tree.map(lambda x: x, specs,
                              is_leaf=lambda x: hasattr(x, "index")))
    batch = make_batch(cfg, key)
    logits = tfm.forward(params, batch, cfg, kv_chunk=8)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    loss, grads = jax.value_and_grad(
        lambda p: tfm.loss_fn(p, batch, cfg, kv_chunk=8))(params)
    assert np.isfinite(float(loss))
    gnorm = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0)
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0.0
    # one SGD step changes the loss
    new_params = jax.tree.map(
        lambda p, g: (p - 0.1 * g.astype(p.dtype)).astype(p.dtype)
        if jnp.issubdtype(p.dtype, jnp.floating) else p, params, grads)
    loss2 = tfm.loss_fn(new_params, batch, cfg, kv_chunk=8)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_decode_step(arch):
    cfg = registry.get_reduced(arch)
    key = jax.random.PRNGKey(1)
    params, _ = tfm.init(key, cfg)
    cache, _ = tfm.init_cache(cfg, B, 32)
    memory = None
    if cfg.is_encoder_decoder:
        memory = jax.random.normal(key, (B, 12, cfg.d_model), jnp.bfloat16)
    k1, k2 = jax.random.split(key)
    tok = jax.random.randint(k1, (B, 1), 0, cfg.vocab_size)
    tok2 = jax.random.randint(k2, (B, 1), 1, cfg.vocab_size)
    logits, cache = tfm.decode_step(params, cache, tok, 0, cfg, memory=memory)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    logits2, cache = tfm.decode_step(params, cache, tok2, 1, cfg,
                                     memory=memory)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    # cache actually advanced: feeding a different token changes the logits
    assert not np.allclose(np.asarray(logits, np.float32),
                           np.asarray(logits2, np.float32))


def test_decode_matches_forward_llama():
    """Greedy decode logits == teacher-forced forward logits (llama reduced)."""
    cfg = registry.get_reduced("llama3.2-1b")
    key = jax.random.PRNGKey(2)
    params, _ = tfm.init(key, cfg)
    toks = jax.random.randint(key, (B, 6), 0, cfg.vocab_size)
    batch = {"inputs": toks, "targets": toks}
    full = tfm.forward(params, batch, cfg, kv_chunk=8)
    cache, _ = tfm.init_cache(cfg, B, 8)
    outs = []
    for t in range(6):
        lg, cache = tfm.decode_step(params, cache, toks[:, t:t + 1], t, cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_decode_matches_forward_mamba2():
    """Recurrent decode == chunked SSD prefill (state-space duality check)."""
    cfg = registry.get_reduced("mamba2-130m")
    key = jax.random.PRNGKey(3)
    params, _ = tfm.init(key, cfg)
    toks = jax.random.randint(key, (B, 6), 0, cfg.vocab_size)
    batch = {"inputs": toks, "targets": toks}
    full = tfm.forward(params, batch, cfg, kv_chunk=8)
    cache, _ = tfm.init_cache(cfg, B, 8)
    outs = []
    for t in range(6):
        lg, cache = tfm.decode_step(params, cache, toks[:, t:t + 1], t, cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_decode_matches_forward_recurrentgemma():
    cfg = registry.get_reduced("recurrentgemma-2b")
    key = jax.random.PRNGKey(4)
    params, _ = tfm.init(key, cfg)
    toks = jax.random.randint(key, (B, 6), 0, cfg.vocab_size)
    batch = {"inputs": toks, "targets": toks}
    full = tfm.forward(params, batch, cfg, kv_chunk=8)
    cache, _ = tfm.init_cache(cfg, B, 8)
    outs = []
    for t in range(6):
        lg, cache = tfm.decode_step(params, cache, toks[:, t:t + 1], t, cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=3e-2, atol=3e-2)
