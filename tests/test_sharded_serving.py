"""Sharded superpacks end-to-end (subprocess with forced host devices):
DistContext-aware init places generator weights over the mesh, the dynamic
image batcher serves data-parallel, output == single-device.

Unlike ``test_distributed.py`` this needs no ``jax.shard_map`` — only the
classic ``Mesh``/``NamedSharding`` APIs — so it gets its own (weaker)
capability probe.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=4",
           PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))


def _mesh_capability() -> str | None:
    probe = (
        "import numpy as np, jax\n"
        "from jax.sharding import Mesh, NamedSharding, PartitionSpec as P\n"
        "mesh = Mesh(np.array(jax.devices()).reshape(2, 2),\n"
        "            ('data', 'model'))\n"
        "x = jax.device_put(jax.numpy.ones((4, 4)),\n"
        "                   NamedSharding(mesh, P(None, 'model')))\n"
        "print(len(mesh.devices.flat))\n")
    try:
        r = subprocess.run([sys.executable, "-c", probe], env=ENV,
                           capture_output=True, text=True, timeout=120)
    except Exception as e:  # noqa: BLE001 - any probe failure means skip
        return f"mesh probe failed to run: {e}"
    if r.returncode != 0:
        tail = (r.stderr.strip().splitlines() or ["unknown error"])[-1]
        return f"host mesh unavailable: {tail}"
    if int(r.stdout.strip() or 0) < 4:
        return "need 4 forced host devices"
    return None


_SKIP_REASON = _mesh_capability()

pytestmark = pytest.mark.skipif(
    _SKIP_REASON is not None,
    reason=f"sharded-serving prerequisites not met: {_SKIP_REASON}")


def run_py(code: str, timeout=600):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=ENV, capture_output=True, text=True,
                       timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_dp_sharded_superpack_serving_matches_single_device():
    """Generator superpacks sharded over 'model' out-channels, requests
    batched data-parallel over 'data' through the image batcher."""
    run_py("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.models import gan
    from repro.serving.image_batcher import DynamicImageBatcher, ImageRequest
    from repro.sharding import DistContext

    cfg = gan.CGAN
    key = jax.random.PRNGKey(0)
    ref_p, _ = gan.generator_init(key, cfg)
    mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ('data', 'model'))
    dist = DistContext(mesh=mesh)
    p, _ = gan.generator_init(key, cfg, dist=dist)
    sh = p['dc0'].sharding
    assert isinstance(sh, NamedSharding), sh
    assert sh.spec == P(None, 'model'), sh.spec
    assert p['b0'].sharding.spec == P('model'), p['b0'].sharding.spec

    z = np.asarray(jax.random.normal(jax.random.PRNGKey(1),
                                     (8, cfg.z_dim)), np.float32)
    with mesh:
        b = DynamicImageBatcher(
            lambda zz: gan.generator_apply(p, zz, cfg), dist=dist)
        done = b.run([ImageRequest(rid=i, payload=z[i]) for i in range(8)])
    want = gan.generator_apply(ref_p, jnp.asarray(z), cfg)
    got = np.stack([r.out for r in sorted(done, key=lambda r: r.rid)])
    np.testing.assert_allclose(got, np.asarray(want), rtol=2e-4, atol=2e-4)
    print('DP sharded superpack serving OK')
    """)


def test_segnet_dist_init_places_params():
    run_py("""
    import numpy as np, jax
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.models import segnet
    from repro.sharding import DistContext

    mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ('data', 'model'))
    p, _ = segnet.segnet_init(jax.random.PRNGKey(0), segnet.SEGNET_TINY,
                              dist=DistContext(mesh=mesh))
    assert p['w0'].sharding.spec == P(None, 'model'), p['w0'].sharding.spec
    print('segnet dist init OK')
    """)
