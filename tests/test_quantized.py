"""Quantized superpacks: int8 tap GEMMs with f32 accumulation, proved
against the float64 conftest oracle under the analytic per-tap-row bound.

What this file proves:

- **round-trip**: ``pack`` -> ``QuantizedSuperpack`` -> ``unpack`` lands
  within one quantization step (``0.5 · scale[row]``: symmetric round-to-
  nearest on the int8 grid) of the original HWIO kernel, per element, for
  every kind — so f32 checkpoints survive the int8 layout migration.
- **forward parity under the composed bound**: the quantized executor's
  output sits inside ``γ-bound(conv(x, K_deq)) + Σ|x|·E_max`` of the f64
  oracle on the ORIGINAL kernel, where ``E_max`` is the per-element
  quantization step mapped to HWIO through the layout.  The first term is
  the existing ULP-scaled accumulation bound (the executor computes
  ``conv(x, K_deq)`` exactly as an f32 contraction); the second is the
  worst-case leverage of the weight error — analytic, not an eyeballed
  rtol.  Checked on conv / dilated / transposed kinds, both backends, and
  at every batch bucket.
- **VJP parity**: ``jax.vjp`` through the quantized plan matches the f32
  plan evaluated on the dequantized weights (same math, different code
  path) for ``dx``, and the weight cotangent comes back as a
  ``QuantizedSuperpack`` whose ``q`` leaf is ``float0`` (int leaves have
  no tangent space) and whose ``dscale = Σ_n dK[row,:]·q[row,:]`` — the
  exact chain rule through ``W = q · scale``.
- **jaxpr proofs**: quantized ``fused_tap`` / ``fused_plane`` still lower
  to exactly ONE ``dot_general`` (the dequant is a broadcast-multiply XLA
  fuses into the GEMM read, not a second contraction), and quantized
  Pallas routes to ONE ``pallas_call`` with zero dot_generals outside.
- **model-zoo threading**: a full int8 SegNet (config ``wdtype``) tracks
  its f32 twin within the documented ``L/127`` serving bound, and the
  autotune ``spec_key`` gains the ``:wint8`` suffix without perturbing
  f32 keys (cache back-compat).

The scale-mapping subtlety everything above leans on: per-row scales live
in *superpack row order* (transposed rows are phase-concatenated, NOT
(r,s,c) row-major), so mapping them to HWIO must go through the **f32
twin's** ``unpack`` — the int8 plan's own ``unpack`` would re-quantize a
float buffer on the way in (``as_superpack``).

No hypothesis dependency — this file must run everywhere tier-1 runs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import reference as ref
from repro.core.plan import (BATCH_BUCKETS, ConvSpec, QuantizedSuperpack,
                             conv_spec, plan_conv)
from repro.models.gan import deconv_padding
from repro.models.segnet import atrous_padding

from tests.conftest import (TOL_GRAD, assert_close, assert_close_ulp,
                            conv_oracle_f64, count_eqns, ulp_bound)


# ---------------------------------------------------------------------------
# builders + the f64 transposed oracle
# ---------------------------------------------------------------------------

def twin_plans(kind, x_shape, k_shape, *, strides=(1, 1),
               padding=((0, 0), (0, 0)), dilation=(1, 1), backend="xla"):
    """(f32 plan, int8 twin) over the same geometry."""
    spec = conv_spec(kind, x_shape, k_shape, strides=strides,
                     padding=padding, dilation=dilation, backend=backend)
    return plan_conv(spec), plan_conv(dataclasses.replace(spec,
                                                          wdtype="int8"))


def transposed_oracle_f64(x, k, *, strides, padding):
    """Float64 transposed-conv oracle: lhs-dilate the input by ``strides``
    (zeros between pixels), then the stride-1 f64 correlation — exactly
    ``lax.conv_general_dilated(lhs_dilation=strides)``'s formulation, so
    the ``(y64, amax64)`` pair feeds the same ULP bound as the single-
    correlation kinds."""
    x64 = np.asarray(x, np.float64)
    sh, sw = strides
    b, h, w, c = x64.shape
    xd = np.zeros((b, (h - 1) * sh + 1, (w - 1) * sw + 1, c))
    xd[:, ::sh, ::sw] = x64
    return conv_oracle_f64(xd, k, padding=padding)


def scale_to_hwio(pf, wq):
    """Per-element quantization step bound ``E_max`` in HWIO coordinates:
    broadcast the (rows, 1) scale column over the rows and map it through
    the **f32 twin's** unpack (see module docstring for why the twin)."""
    sc = pf.unpack(jnp.broadcast_to(wq.scale, wq.q.shape))
    return 0.5 * np.asarray(sc, np.float64) * (1 + 1e-5) \
        + np.finfo(np.float32).tiny


def oracle_pair(kind, x, k, *, strides, padding, dilation):
    if kind == "transposed":
        return transposed_oracle_f64(x, k, strides=strides, padding=padding)
    return conv_oracle_f64(x, k, strides=strides, dilation=dilation,
                           padding=padding)


# the fixed geometry suite: every kind, strides, dilation, ragged channels
CASES = [
    # (kind, b, h, w, c, n, r, s, strides, dil, pads)
    ("conv", 2, 8, 8, 16, 8, 3, 3, (1, 1), (1, 1), ((1, 1), (1, 1))),
    ("conv", 1, 9, 7, 7, 5, 3, 2, (2, 2), (1, 1), ((1, 0), (1, 1))),
    ("dilated", 1, 13, 13, 8, 8, 3, 3, (1, 1), (2, 2), atrous_padding(3, 2)),
    ("transposed", 2, 4, 4, 16, 8, 5, 5, (2, 2), (1, 1),
     deconv_padding(5, 2)),
    ("transposed", 1, 5, 4, 6, 4, 3, 2, (2, 3), (1, 1), ((2, 0), (1, 1))),
]


def check_quant_fwd(kind, b, h, w, c, n, r, s, strides, dil, pads,
                    backend="xla", seed=0):
    """Forward within the composed analytic bound (see module docstring)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (b, h, w, c), jnp.float32)
    kern = jax.random.normal(k2, (r, s, c, n), jnp.float32)
    pf, pq = twin_plans(kind, x.shape, kern.shape, strides=strides,
                        padding=pads, dilation=dil, backend=backend)
    wq = pq.pack(kern)
    assert isinstance(wq, QuantizedSuperpack) and wq.q.dtype == jnp.int8
    kd = pq.unpack(wq)                      # dequantized HWIO twin kernel
    got = np.asarray(pq.apply(x, wq), np.float64)

    # (1) the executor computes conv(x, K_deq) within the γ-bound
    y64d, amaxd = oracle_pair(kind, x, kd, strides=strides, padding=pads,
                              dilation=dil)
    n_terms = r * s * c
    assert_close_ulp(got, y64d, amaxd, n_terms)

    # (2) composed with the quantization term, it stays within the bound
    # of the ORIGINAL kernel's oracle: |y_q - y(K)| <= γ·amax + Σ|x|·E_max
    emax = scale_to_hwio(pf, wq)
    y64, _ = oracle_pair(kind, x, kern, strides=strides, padding=pads,
                         dilation=dil)
    qterm, _ = oracle_pair(kind, np.abs(np.asarray(x, np.float64)), emax,
                           strides=strides, padding=pads, dilation=dil)
    bound = ulp_bound(y64d, amaxd, n_terms) + qterm
    err = np.abs(got - y64)
    assert np.all(err <= bound), (
        f"max excess over composed quant bound: {np.max(err - bound):.3e}")


@pytest.mark.parametrize("case", CASES)
def test_quant_fwd_within_composed_bound_xla(case):
    check_quant_fwd(*case, backend="xla")


@pytest.mark.parametrize("case", CASES)
def test_quant_fwd_within_composed_bound_pallas(case):
    check_quant_fwd(*case, backend="pallas")


def test_transposed_oracle_f64_matches_lax():
    """Self-validation of the f64 transposed oracle against XLA's
    lhs-dilated conv (the repo-wide transposed correctness oracle)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(k1, (2, 4, 5, 6), jnp.float32)
    k = jax.random.normal(k2, (4, 3, 6, 5), jnp.float32)
    pads = deconv_padding(4, 2), deconv_padding(3, 2)
    pads = (pads[0][0], pads[1][1])
    want = ref.oracle_conv_transpose2d(x, k, strides=(2, 2), padding=pads)
    y64, amax64 = transposed_oracle_f64(x, k, strides=(2, 2), padding=pads)
    assert_close_ulp(want, y64, amax64, k.shape[0] * k.shape[1] * k.shape[2])


# ---------------------------------------------------------------------------
# round-trip: pack -> quantize -> unpack within one quantization step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", CASES)
def test_roundtrip_within_one_step(case):
    kind, b, h, w, c, n, r, s, strides, dil, pads = case
    kern = jax.random.normal(jax.random.PRNGKey(7), (r, s, c, n),
                             jnp.float32)
    pf, pq = twin_plans(kind, (b, h, w, c), kern.shape, strides=strides,
                        padding=pads, dilation=dil)
    wq = pq.pack(kern)
    assert wq.scale.shape == (wq.q.shape[0], 1)
    assert wq.scale.dtype == jnp.float32
    kd = np.asarray(pq.unpack(wq), np.float64)
    step = scale_to_hwio(pf, wq)            # 0.5·scale/elem (+ f32 slop)
    err = np.abs(kd - np.asarray(kern, np.float64))
    assert np.all(err <= step), (
        f"round-trip exceeds one quantization step by "
        f"{np.max(err - step):.3e}")
    # stored bytes: 1/elem codes + f32 scale rows <= half the f32 buffer
    wf = pf.pack(kern)
    assert wq.nbytes() <= 0.5 * int(wf.nbytes)
    # a QuantizedSuperpack passes through adaptation untouched (no
    # double quantization); f32 HWIO checkpoints load quantized on the
    # single-correlation kinds (transposed legacy layouts are phase dicts)
    assert pq.as_superpack(wq) is wq
    if kind != "transposed":
        adapted = pq.as_superpack(kern)
        np.testing.assert_array_equal(np.asarray(adapted.q),
                                      np.asarray(wq.q))


# ---------------------------------------------------------------------------
# VJP: dx parity vs the dequantized f32 plan, exact dscale chain rule
# ---------------------------------------------------------------------------

def check_quant_vjp(kind, b, h, w, c, n, r, s, strides, dil, pads,
                    backend="xla", seed=1):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (b, h, w, c), jnp.float32)
    kern = jax.random.normal(k2, (r, s, c, n), jnp.float32)
    pf, pq = twin_plans(kind, x.shape, kern.shape, strides=strides,
                        padding=pads, dilation=dil, backend=backend)
    wq = pq.pack(kern)
    # the f32 plan on the dequantized kernel: pack is a layout gather, so
    # its rows are bit-equal to dequant(wq) — same math, f32 code path
    wf = pf.pack(pq.unpack(wq))

    yq, vjp_q = jax.vjp(pq.apply, x, wq)
    yf, vjp_f = jax.vjp(pf.apply, x, wf)
    ct = jax.random.normal(k3, yq.shape, jnp.float32)
    dxq, dwq = vjp_q(ct)
    dxf, dwf = vjp_f(ct)

    assert_close(yq, yf, TOL_GRAD)
    assert_close(dxq, dxf, TOL_GRAD)
    # weight cotangent rides back on the quantized layout: float0 for the
    # int codes (no tangent space), dscale = Σ_n dK·q per row
    assert isinstance(dwq, QuantizedSuperpack)
    assert dwq.q.shape == wq.q.shape
    assert dwq.q.dtype == jax.dtypes.float0
    want_dscale = jnp.sum(dwf * wq.q.astype(jnp.float32), axis=1,
                          keepdims=True)
    assert_close(dwq.scale, want_dscale, TOL_GRAD)


@pytest.mark.parametrize("case", CASES)
def test_quant_vjp_xla(case):
    check_quant_vjp(*case, backend="xla")


@pytest.mark.parametrize("case", [CASES[0], CASES[3]])
def test_quant_vjp_pallas(case):
    check_quant_vjp(*case, backend="pallas")


def test_quant_grad_allow_int():
    """``jax.grad`` over a quantized param tree works with
    ``allow_int=True`` (the documented training entry for int8 leaves)."""
    kind, b, h, w, c, n, r, s, strides, dil, pads = CASES[0]
    x = jax.random.normal(jax.random.PRNGKey(5), (b, h, w, c), jnp.float32)
    kern = jax.random.normal(jax.random.PRNGKey(6), (r, s, c, n),
                             jnp.float32)
    _, pq = twin_plans(kind, x.shape, kern.shape, strides=strides,
                       padding=pads, dilation=dil)
    wq = pq.pack(kern)
    dx, dw = jax.grad(lambda a, w: jnp.sum(pq.apply(a, w) ** 2),
                      (0, 1), allow_int=True)(x, wq)
    assert dx.shape == x.shape
    assert dw.q.dtype == jax.dtypes.float0
    assert dw.scale.shape == wq.scale.shape


# ---------------------------------------------------------------------------
# every batch bucket, both backends (tiny zoo-scale geometries)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("bucket", BATCH_BUCKETS)
def test_quant_every_bucket_dilated(bucket, backend):
    """SegNet-context-shaped dilated site at every serving bucket."""
    check_quant_fwd("dilated", bucket, 6, 6, 8, 8, 3, 3, (1, 1), (2, 2),
                    atrous_padding(3, 2), backend=backend, seed=bucket)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("bucket", BATCH_BUCKETS)
def test_quant_every_bucket_transposed(bucket, backend):
    """DCGAN-decoder-shaped transposed site at every serving bucket."""
    check_quant_fwd("transposed", bucket, 4, 4, 8, 8, 4, 4, (2, 2), (1, 1),
                    deconv_padding(4, 2), backend=backend, seed=bucket)


# ---------------------------------------------------------------------------
# jaxpr proofs: ONE dot_general / ONE pallas_call, quantized
# ---------------------------------------------------------------------------

def _quant_jaxpr(kind, h, w, c, n, r, s, strides, pads, backend,
                 dilation=(1, 1)):
    _, pq = twin_plans(kind, (2, h, w, c), (r, s, c, n), strides=strides,
                       padding=pads, dilation=dilation, backend=backend)
    x = jnp.zeros((2, h, w, c), jnp.float32)
    wq = pq.pack(jnp.zeros((r, s, c, n), jnp.float32))
    return pq, jax.make_jaxpr(pq.apply)(x, wq)


def test_quant_fused_tap_is_single_gemm():
    """The DCGAN geometry routes fused_tap; quantized it still lowers to
    exactly one dot_general (dequant fuses into the GEMM read)."""
    pq, jaxpr = _quant_jaxpr("transposed", 4, 4, 16, 8, 5, 5, (2, 2),
                             ((2, 3), (2, 3)), "xla")
    assert pq.path == "fused_tap", pq.path
    assert count_eqns(jaxpr.jaxpr, "dot_general") == 1
    assert count_eqns(jaxpr.jaxpr, "pallas_call") == 0


def test_quant_fused_plane_is_single_gemm():
    """fused_plane quantized: one dot_general.  The cGAN k=4/s=2 geometry
    now routes pixel_shuffle by heuristic (the sub-pixel rewrite — also a
    single dequantized GEMM, proved in tests/test_pixel_shuffle.py), so
    the interleaved executor's proof forces the route it replaced."""
    pq, jaxpr0 = _quant_jaxpr("transposed", 8, 8, 16, 8, 4, 4, (2, 2),
                              ((1, 3), (1, 3)), "xla")
    assert pq.path == "pixel_shuffle", pq.path
    assert count_eqns(jaxpr0.jaxpr, "dot_general") == 1
    forced = pq.with_routes(tuple(
        dataclasses.replace(r, path="fused_plane") for r in pq.routes))
    x = jnp.zeros((2, 8, 8, 16), jnp.float32)
    wq = forced.pack(jnp.zeros((4, 4, 16, 8), jnp.float32))
    jaxpr = jax.make_jaxpr(forced.apply)(x, wq)
    assert count_eqns(jaxpr.jaxpr, "dot_general") == 1
    assert count_eqns(jaxpr.jaxpr, "pallas_call") == 0


def test_quant_single_correlation_is_single_gemm():
    """conv/dilated fused route quantized: still one wide GEMM."""
    for dil in ((1, 1), (2, 2)):
        kind = "dilated" if dil != (1, 1) else "conv"
        pq, jaxpr = _quant_jaxpr(kind, 9, 9, 8, 8, 3, 3, (1, 1),
                                 atrous_padding(3, dil[0]), "xla",
                                 dilation=dil)
        assert pq.path in ("fused_tap", "fused_plane"), pq.path
        assert count_eqns(jaxpr.jaxpr, "dot_general") == 1
        assert count_eqns(jaxpr.jaxpr, "pallas_call") == 0


@pytest.mark.parametrize("kind,strides,pads", [
    ("transposed", (2, 2), ((2, 3), (2, 3))),
    ("conv", (1, 1), ((1, 1), (1, 1))),
])
def test_quant_pallas_is_single_launch(kind, strides, pads):
    r = 5 if kind == "transposed" else 3
    pq, jaxpr = _quant_jaxpr(kind, 4, 4, 32, 16, r, r, strides, pads,
                             "pallas")
    assert pq.path == "pallas" and pq.tiles is not None
    assert count_eqns(jaxpr.jaxpr, "pallas_call") == 1
    assert count_eqns(jaxpr.jaxpr, "dot_general") == 0


# ---------------------------------------------------------------------------
# model-zoo threading: int8 SegNet vs its f32 twin, spec_key back-compat
# ---------------------------------------------------------------------------

def test_segnet_int8_tracks_f32_twin():
    """Full int8 SegNet (config ``wdtype``) within the documented serving
    bound: rel L∞ ≤ L/127 (each of the L conv layers contributes at most
    ~half an int8 grid step of relative weight error; measured ~3x
    headroom — the serve_segnet gate asserts the same inequality)."""
    from repro.models import segnet
    cfg = dataclasses.replace(segnet.SEGNET_TINY, wdtype="int8")
    twin = dataclasses.replace(cfg, name=cfg.name + "-f32", wdtype="float32")
    key = jax.random.PRNGKey(0)
    pq, _ = segnet.segnet_init(key, cfg)
    pf, _ = segnet.segnet_init(key, twin)
    plans = segnet.segnet_plans(cfg)
    assert all(isinstance(pq[f"w{i}"], QuantizedSuperpack)
               for i in range(len(plans)))
    x = jax.random.uniform(jax.random.PRNGKey(1),
                           (2, cfg.in_hw, cfg.in_hw, cfg.in_c),
                           minval=-1.0, maxval=1.0)
    lq = segnet.segnet_apply(pq, x, cfg)
    lf = segnet.segnet_apply(pf, x, twin)
    rel = float(jnp.max(jnp.abs(lq - lf)) / jnp.max(jnp.abs(lf)))
    assert rel <= len(plans) / 127.0, rel
    # the int8 param tree really is smaller than half the f32 one
    qb = sum(w.nbytes() for k, w in pq.items() if k.startswith("w"))
    fb = sum(int(w.nbytes) for k, w in pf.items() if k.startswith("w"))
    assert qb <= 0.5 * fb


def test_spec_key_wdtype_suffix_is_backcompat():
    """f32 keys are byte-identical to pre-quantization keys (no suffix);
    int8 twins differ only by the ``:wint8`` tail — existing route-cache
    entries keep their keys."""
    from repro.core.autotune import spec_key
    spec = ConvSpec(kind="conv", in_hw=(8, 8), in_c=4, out_c=4,
                    kernel_hw=(3, 3), padding=((1, 1), (1, 1)))
    kf = spec_key(spec)
    kq = spec_key(dataclasses.replace(spec, wdtype="int8"))
    assert ":w" not in kf
    assert kq == kf + ":wint8"
