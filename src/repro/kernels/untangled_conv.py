"""Pallas TPU kernel for the untangled (tap-accumulated GEMM) convolution.

One kernel instance computes a standard / strided / dilated correlation of an
NHWC input with an HWIO kernel as the paper's §3.2 sum of per-tap 1x1 convs:

    acc[(OH*OW), N_t] += X_vmem[tap-slice].reshape(OH*OW, C_t) @ K[m, n][C_t, N_t]

TPU mapping decisions (the HUGE2 "cache locality" story, restated for VMEM/MXU):

* the whole (padded) spatial plane of one batch item lives in VMEM for the
  duration of a (C_t, N_t) tile — every tap re-reads it from VMEM, never HBM.
  Edge-generative workloads have small planes (4..64 px) and fat channels,
  exactly the regime where this blocking wins (paper §4.1).
* the kernel is held tap-major ``(R·S, C_t, N_t)`` — the superpack layout
  ``ConvPlan.pack`` emits: each tap's (C_t, N_t) panel is a contiguous VMEM
  tile feeding the MXU with N on the lane axis — the TPU analogue of the
  paper's C×N×R×S coalescing layout.  Strided and dilated correlations run
  the *same* kernel; dilation only moves each tap's read origin inside the
  resident plane (no zero-inserted kernel exists anywhere).
* taps are a *static* unrolled loop of MXU matmuls with an f32 VMEM
  accumulator; the C grid axis is innermost-sequential so the accumulator
  carries across C tiles (revisiting semantics).

``_deconv_kernel`` extends the same mapping to the *fused* transposed conv:
ONE launch computes every s_h*s_w output phase over a single VMEM residency
of the globally padded plane.  Each phase's taps accumulate into its segment
of a shared f32 scratch (plan-time ``acc_off`` row offsets), the superpack
weight buffer rides in tap-major ``(ΣT, C_t, N_t)``, and the flush writes
the **interleaved** output block directly with strided in-kernel stores —
no per-phase launches, no per-phase input copies, no stack/transpose
interleave pass.

Grid: ``(B, N/N_t, C/C_t)`` — C innermost (reduction).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Pair = tuple[int, int]


def _kernel(x_ref, k_ref, o_ref, acc_ref, *, taps_hw: Pair, strides: Pair,
            dilation: Pair, out_hw: Pair, n_c_tiles: int):
    """Single-correlation kernel over the tap-major superpack: ``k_ref`` is
    ``(R·S, C_t, N_t)`` — tap ``t = m·S + n``'s panel is one contiguous VMEM
    tile, the same row order ``ConvPlan.pack`` emits, so the strided and the
    dilated kind run the *same* kernel (dilation only moves the tap's read
    origin inside the resident plane)."""
    r, s = taps_hw
    sh, sw = strides
    dh, dw = dilation
    oh, ow = out_hw
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                       # (Hp, Wp, C_t) resident in VMEM
    acc = acc_ref[...]
    for m in range(r):                 # static tap unroll -> MXU matmul chain
        for n in range(s):
            xs = jax.lax.slice(
                x, (m * dh, n * dw, 0),
                (m * dh + (oh - 1) * sh + 1, n * dw + (ow - 1) * sw + 1,
                 x.shape[2]),
                (sh, sw, 1))
            acc += jnp.dot(xs.reshape(oh * ow, xs.shape[2]), k_ref[m * s + n],
                           preferred_element_type=jnp.float32)
    acc_ref[...] = acc

    @pl.when(ci == n_c_tiles - 1)
    def _flush():
        o_ref[0] = acc.reshape(oh, ow, acc.shape[-1]).astype(o_ref.dtype)


def untangled_conv2d_superpack_pallas(x: jax.Array, superpack: jax.Array, *,
                                      taps_hw: Pair,
                                      strides: Pair = (1, 1),
                                      rhs_dilation: Pair = (1, 1),
                                      c_tile: int = 128, n_tile: int = 128,
                                      out_dtype=None,
                                      interpret: bool | None = None
                                      ) -> jax.Array:
    """ONE launch of the valid (pre-padded) untangled correlation, weights in
    the superpacked layout.  x:(B,Hp,Wp,C); superpack:(R·S·C, N) tap-major
    (``ConvPlan.pack``).  Covers the strided and the dilated kind — the
    dilated kernel is never zero-inserted; taps read the raw plane at
    ``m·d_h`` / ``n·d_w`` offsets."""
    b, hp, wp, c = x.shape
    r, s = taps_hw
    n = superpack.shape[1]
    assert superpack.shape[0] == r * s * c, (superpack.shape, taps_hw, c)
    sh, sw = strides
    dh, dw = rhs_dilation
    oh = (hp - (r - 1) * dh - 1) // sh + 1
    ow = (wp - (s - 1) * dw - 1) // sw + 1
    assert oh > 0 and ow > 0, (oh, ow)
    out_dtype = out_dtype or x.dtype
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    k3 = superpack.reshape(r * s, c, n)
    c_tile = min(c_tile, c)
    n_tile = min(n_tile, n)
    cp = -(-c // c_tile) * c_tile
    np_ = -(-n // n_tile) * n_tile
    if cp != c:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, cp - c)))
        k3 = jnp.pad(k3, ((0, 0), (0, cp - c), (0, 0)))
    if np_ != n:
        k3 = jnp.pad(k3, ((0, 0), (0, 0), (0, np_ - n)))
    n_c_tiles = cp // c_tile

    grid = (b, np_ // n_tile, n_c_tiles)
    out = pl.pallas_call(
        functools.partial(_kernel, taps_hw=(r, s), strides=strides,
                          dilation=rhs_dilation, out_hw=(oh, ow),
                          n_c_tiles=n_c_tiles),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hp, wp, c_tile), lambda b_, n_, c_: (b_, 0, 0, c_)),
            pl.BlockSpec((r * s, c_tile, n_tile),
                         lambda b_, n_, c_: (0, c_, n_)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, n_tile),
                               lambda b_, n_, c_: (b_, 0, 0, n_)),
        out_shape=jax.ShapeDtypeStruct((b, oh, ow, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((oh * ow, n_tile), jnp.float32)],
        interpret=interpret,
    )(x, k3)
    return out[..., :n]


def untangled_conv2d_pallas(x: jax.Array, kernel: jax.Array, *,
                            strides: Pair = (1, 1),
                            rhs_dilation: Pair = (1, 1),
                            c_tile: int = 128, n_tile: int = 128,
                            out_dtype=None,
                            interpret: bool | None = None) -> jax.Array:
    """Valid (pre-padded) untangled convolution. x:(B,Hp,Wp,C), K:(R,S,C,N).

    Full-kernel entry: flattens into the tap-major superpack (free — same
    memory order) and runs the superpack kernel."""
    r, s, kc, n = kernel.shape
    assert kc == x.shape[-1], (kernel.shape, x.shape)
    return untangled_conv2d_superpack_pallas(
        x, kernel.reshape(r * s * kc, n), taps_hw=(r, s), strides=strides,
        rhs_dilation=rhs_dilation, c_tile=c_tile, n_tile=n_tile,
        out_dtype=out_dtype, interpret=interpret)


def _deconv_kernel(x_ref, k_ref, o_ref, acc_ref, *, phases, strides: Pair,
                   n_c_tiles: int):
    """Multi-phase transposed conv: every phase's taps over one VMEM
    residency of the padded plane, flushed as direct interleaved writes.

    ``phases`` is a static tuple of per-phase records
    ``(q_h, q_w, tap_off, T_h, T_w, xoff_h, xoff_w, U, V, acc_off)`` — all
    plan-time constants, so the loop fully unrolls into an MXU matmul chain.
    """
    sh, sw = strides
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                       # (Hg, Wg, C_t) resident in VMEM
    for (qh, qw, tap_off, th, tw, xh, xw, u, v, acc_off) in phases:
        if th * tw == 0 or u * v == 0:
            continue
        acc = acc_ref[pl.ds(acc_off, u * v), :]
        for t in range(th * tw):       # static tap unroll -> MXU matmuls
            ti, tj = divmod(t, tw)
            xs = jax.lax.slice(x, (xh + ti, xw + tj, 0),
                               (xh + ti + u, xw + tj + v, x.shape[2]))
            acc += jnp.dot(xs.reshape(u * v, xs.shape[2]),
                           k_ref[tap_off + t],
                           preferred_element_type=jnp.float32)
        acc_ref[pl.ds(acc_off, u * v), :] = acc

    @pl.when(ci == n_c_tiles - 1)
    def _flush():
        for (qh, qw, tap_off, th, tw, xh, xw, u, v, acc_off) in phases:
            if u * v == 0:
                continue
            blk = acc_ref[pl.ds(acc_off, u * v), :]
            o_ref[0, pl.Slice(qh, u, sh), pl.Slice(qw, v, sw), :] = (
                blk.reshape(u, v, blk.shape[-1]).astype(o_ref.dtype))


def untangled_deconv2d_pallas(xg: jax.Array, superpack: jax.Array, *,
                              phases, out_hw: Pair, strides: Pair,
                              sum_uv: int, c_tile: int = 128,
                              n_tile: int = 128, out_dtype=None,
                              interpret: bool | None = None) -> jax.Array:
    """Fused transposed conv: ONE kernel launch for all s_h*s_w phases.

    xg: (B, Hg, Wg, C) globally padded plane; superpack: (ΣT·C, N) tap-major
    phase sub-kernels (``ConvPlan.pack`` layout); ``phases`` the plan's
    ``PhaseExec`` records.  Output (B, out_h, out_w, N), written interleaved
    inside the kernel — no stack/transpose pass afterwards.
    """
    b, hg, wg, c = xg.shape
    n = superpack.shape[1]
    total_taps = superpack.shape[0] // max(1, c)
    oh, ow = out_hw
    out_dtype = out_dtype or xg.dtype
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    k3 = superpack.reshape(total_taps, c, n)
    c_tile = min(c_tile, c)
    n_tile = min(n_tile, n)
    cp = -(-c // c_tile) * c_tile
    np_ = -(-n // n_tile) * n_tile
    if cp != c:
        xg = jnp.pad(xg, ((0, 0), (0, 0), (0, 0), (0, cp - c)))
        k3 = jnp.pad(k3, ((0, 0), (0, cp - c), (0, 0)))
    if np_ != n:
        k3 = jnp.pad(k3, ((0, 0), (0, 0), (0, np_ - n)))
    n_c_tiles = cp // c_tile

    meta = tuple(
        (ex.q[0], ex.q[1], ex.tap_off, ex.taps[0], ex.taps[1],
         ex.xoff[0], ex.xoff[1], ex.out_hw[0], ex.out_hw[1], ex.acc_off)
        for ex in phases)
    grid = (b, np_ // n_tile, n_c_tiles)
    out = pl.pallas_call(
        functools.partial(_deconv_kernel, phases=meta, strides=strides,
                          n_c_tiles=n_c_tiles),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hg, wg, c_tile), lambda b_, n_, c_: (b_, 0, 0, c_)),
            pl.BlockSpec((total_taps, c_tile, n_tile),
                         lambda b_, n_, c_: (0, c_, n_)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, n_tile),
                               lambda b_, n_, c_: (b_, 0, 0, n_)),
        out_shape=jax.ShapeDtypeStruct((b, oh, ow, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((sum_uv, n_tile), jnp.float32)],
        interpret=interpret,
    )(xg, k3)
    return out[..., :n]


def vmem_bytes_estimate(hp, wp, c_tile, r, s, n_tile, oh, ow, itemsize=4):
    """Working-set estimate used by the dispatcher to pick tile sizes.

    Thin (r, s) wrapper over ``vmem_bytes_estimate_superpack`` — one owner
    for the formula.  The accumulator scratch is always f32 (4 bytes/elem)
    regardless of the input dtype; only the plane, kernel, and output blocks
    scale with ``itemsize``.
    """
    return vmem_bytes_estimate_superpack(hp, wp, c_tile, r * s, n_tile,
                                         oh, ow, itemsize)


def vmem_bytes_estimate_fused(hg, wg, c_tile, total_taps, n_tile, sum_uv,
                              oh, ow, itemsize=4):
    """Working set of the fused multi-phase kernel: global plane block +
    superpack tile + full interleaved output block, plus the per-phase f32
    accumulator scratch (always 4 bytes/elem)."""
    return itemsize * (hg * wg * c_tile + total_taps * c_tile * n_tile +
                       oh * ow * n_tile) + 4 * sum_uv * n_tile


def vmem_bytes_estimate_superpack(hp, wp, c_tile, total_taps, n_tile,
                                  oh, ow, itemsize=4):
    """Working set of the single-correlation superpack kernel — the
    dilation-aware estimate: ``hp``/``wp`` are padded-plane dims that grow
    with the dilated tap reach ``(R-1)·d``, while the superpack tile stays
    ``total_taps = R·S`` rows no matter the dilation (no zero-inserted
    kernel is ever resident).  f32 accumulator always at 4 bytes/elem."""
    return itemsize * (hp * wp * c_tile + total_taps * c_tile * n_tile +
                       oh * ow * n_tile) + 4 * oh * ow * n_tile
