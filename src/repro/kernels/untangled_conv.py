"""Pallas TPU kernel for the untangled (tap-accumulated GEMM) convolution.

One kernel instance computes a standard / strided / dilated correlation of an
NHWC input with an HWIO kernel as the paper's §3.2 sum of per-tap 1x1 convs:

    acc[(OH*OW), N_t] += X_vmem[tap-slice].reshape(OH*OW, C_t) @ K[m, n][C_t, N_t]

TPU mapping decisions (the HUGE2 "cache locality" story, restated for VMEM/MXU):

* the whole (padded) spatial plane of one batch item lives in VMEM for the
  duration of a (C_t, N_t) tile — every tap re-reads it from VMEM, never HBM.
  Edge-generative workloads have small planes (4..64 px) and fat channels,
  exactly the regime where this blocking wins (paper §4.1).
* the kernel is held tap-major ``(R·S, C_t, N_t)`` — the superpack layout
  ``ConvPlan.pack`` emits: each tap's (C_t, N_t) panel is a contiguous VMEM
  tile feeding the MXU with N on the lane axis — the TPU analogue of the
  paper's C×N×R×S coalescing layout.  Strided and dilated correlations run
  the *same* kernel; dilation only moves each tap's read origin inside the
  resident plane (no zero-inserted kernel exists anywhere).
* taps are a *static* unrolled loop of MXU matmuls with an f32 VMEM
  accumulator; the C grid axis is innermost-sequential so the accumulator
  carries across C tiles (revisiting semantics).

``_deconv_kernel`` extends the same mapping to the *fused* transposed conv:
ONE launch computes every s_h*s_w output phase over a single VMEM residency
of the globally padded plane.  Each phase's taps accumulate into its segment
of a shared f32 scratch (plan-time ``acc_off`` row offsets), the superpack
weight buffer rides in tap-major ``(ΣT, C_t, N_t)``, and the flush writes
the **interleaved** output block directly with strided in-kernel stores —
no per-phase launches, no per-phase input copies, no stack/transpose
interleave pass.

Grid: ``(B, N/N_t, C/C_t)`` — C innermost (reduction).

**Spatially tiled variants** (``sp_tiles`` on both public entries): when the
whole padded plane does not fit VMEM, the grid grows ``(oh_tiles, ow_tiles)``
axes — ``(B, OH/T_oh, OW/T_ow, N/N_t, C/C_t)``, C still innermost — and the
kernel computes one **halo'd output tile** per step.  The input stays whole
in ``pltpu.ANY`` (compiler-placed, HBM for big planes) and each step's
halo'd input slice — output-tile footprint plus the stride/dilation-aware
tap reach ``(T-1)·d`` (phase-aware tap-origin span for the multi-phase
deconv) — is fetched by an explicit **double-buffered DMA**: the next
step's halo slice streams into the other slot while the MXU runs the
current tap loop.  Per-output-pixel accumulation order (tap-major inside a
C tile, C tiles outer) is identical to the whole-plane kernels, so tiled
and untiled outputs are bit-compatible.  Plane size alone never pushes a
site off the Pallas route (the plan layer keeps XLA fallbacks only for
non-uniform-phase transposed shapes and halos beyond the VMEM budget).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Pair = tuple[int, int]


def _tap_panel(k_ref, s_ref, t: int):
    """Tap ``t``'s ``(C_t, N_t)`` MXU panel.  Dense superpacks read the raw
    VMEM tile; quantized superpacks carry per-tap-row scales in ``s_ref``
    (``(ΣT, C_t, 1)``) and dequantize here — int8 tile → f32 row-broadcast
    multiply — so the MXU dot below runs f32 into the existing f32 scratch.
    The scale sits on the *contraction* dim C, so it cannot be folded into
    the accumulator after the dot; per-panel pre-scaling is the exact
    placement."""
    panel = k_ref[t]
    if s_ref is None:
        return panel
    return panel.astype(jnp.float32) * s_ref[t]


def _kernel(x_ref, k_ref, *rest, taps_hw: Pair, strides: Pair,
            dilation: Pair, out_hw: Pair, n_c_tiles: int):
    """Single-correlation kernel over the tap-major superpack: ``k_ref`` is
    ``(R·S, C_t, N_t)`` — tap ``t = m·S + n``'s panel is one contiguous VMEM
    tile, the same row order ``ConvPlan.pack`` emits, so the strided and the
    dilated kind run the *same* kernel (dilation only moves the tap's read
    origin inside the resident plane).  An int8 superpack rides with a third
    input ref of per-tap-row scales (see ``_tap_panel``)."""
    s_ref, o_ref, acc_ref = rest if len(rest) == 3 else (None, *rest)
    r, s = taps_hw
    sh, sw = strides
    dh, dw = dilation
    oh, ow = out_hw
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                       # (Hp, Wp, C_t) resident in VMEM
    acc = acc_ref[...]
    for m in range(r):                 # static tap unroll -> MXU matmul chain
        for n in range(s):
            xs = jax.lax.slice(
                x, (m * dh, n * dw, 0),
                (m * dh + (oh - 1) * sh + 1, n * dw + (ow - 1) * sw + 1,
                 x.shape[2]),
                (sh, sw, 1))
            acc += jnp.dot(xs.reshape(oh * ow, xs.shape[2]),
                           _tap_panel(k_ref, s_ref, m * s + n),
                           preferred_element_type=jnp.float32)
    acc_ref[...] = acc

    @pl.when(ci == n_c_tiles - 1)
    def _flush():
        o_ref[0] = acc.reshape(oh, ow, acc.shape[-1]).astype(o_ref.dtype)


def _halo_stream(x_any, buf, sem, origin):
    """Double-buffered halo'd-tile fetch shared by both tiled kernels.

    ``origin(i, j)`` maps a spatial tile index to the slice origin (rows,
    cols) inside the ``pltpu.ANY``-resident plane; the channel slice comes
    from the innermost grid axis.  Ravels the ``(b, i, j, n, c)`` grid into
    a linear step (the halo slice depends on everything but the N tile),
    starts the *next* step's DMA into the other slot so it streams while
    the caller's MXU loop runs, then waits on and returns the current
    step's tile (a ``(tin_h, tin_w, C_t)`` VMEM view)."""
    bi, oi, oj, ni, ci = (pl.program_id(d) for d in range(5))
    nb, n_oi, n_oj, nn, nc = (pl.num_programs(d) for d in range(5))
    step = (((bi * n_oi + oi) * n_oj + oj) * nn + ni) * nc + ci
    total = nb * n_oi * n_oj * nn * nc
    _, tin_h, tin_w, c_t = buf.shape

    def tile_dma(slot, st):
        c_ = jax.lax.rem(st, nc)
        st = jax.lax.div(st, nc * nn)
        j_ = jax.lax.rem(st, n_oj)
        st = jax.lax.div(st, n_oj)
        i_ = jax.lax.rem(st, n_oi)
        b_ = jax.lax.div(st, n_oi)
        r0, c0 = origin(i_, j_)
        return pltpu.make_async_copy(
            x_any.at[b_, pl.ds(r0, tin_h), pl.ds(c0, tin_w),
                     pl.ds(c_ * c_t, c_t)],
            buf.at[slot], sem.at[slot])

    slot = jax.lax.rem(step, 2)

    @pl.when(step == 0)
    def _warmup():
        tile_dma(0, 0).start()

    @pl.when(step + 1 < total)
    def _prefetch():                    # streams while the MXU loop runs
        tile_dma(jax.lax.rem(step + 1, 2), step + 1).start()

    tile_dma(slot, step).wait()
    return buf[slot]


def _tiled_kernel(x_any, k_ref, *rest, taps_hw: Pair,
                  strides: Pair, dilation: Pair, tile_hw: Pair,
                  n_c_tiles: int):
    """Spatially tiled single-correlation kernel: one halo'd output tile per
    grid step, the input whole in ``pltpu.ANY`` and each step's halo slice
    DMA'd into a double-buffered VMEM scratch (the next slice streams while
    the MXU runs the current tap loop).  Tap/C-tile accumulation order is
    identical to ``_kernel``, so the output is bit-compatible with the
    whole-plane route."""
    s_ref, o_ref, buf, sem, acc_ref = \
        rest if len(rest) == 5 else (None, *rest)
    r, s = taps_hw
    sh, sw = strides
    dh, dw = dilation
    toh, tow = tile_hw
    ci = pl.program_id(4)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = _halo_stream(x_any, buf, sem,
                     lambda i_, j_: (i_ * toh * sh, j_ * tow * sw))
    acc = acc_ref[...]
    for m in range(r):                  # static tap unroll -> MXU matmuls
        for n in range(s):
            xs = jax.lax.slice(
                x, (m * dh, n * dw, 0),
                (m * dh + (toh - 1) * sh + 1, n * dw + (tow - 1) * sw + 1,
                 x.shape[2]),
                (sh, sw, 1))
            acc += jnp.dot(xs.reshape(toh * tow, xs.shape[2]),
                           _tap_panel(k_ref, s_ref, m * s + n),
                           preferred_element_type=jnp.float32)
    acc_ref[...] = acc

    @pl.when(ci == n_c_tiles - 1)
    def _flush():
        o_ref[0] = acc.reshape(toh, tow, acc.shape[-1]).astype(o_ref.dtype)


def halo_extent(tile: int, taps: int, stride: int, dilation: int) -> int:
    """Input rows one halo'd output tile needs along one dim: the strided
    tile footprint plus the dilated tap reach ``(T-1)·d``."""
    return (tile - 1) * stride + (taps - 1) * dilation + 1


def _scale_tiles(scales, total_taps: int, c: int, cp: int):
    """Per-tap-row scales ``(ΣT·C, 1)`` → the kernel's ``(ΣT, C, 1)`` view,
    zero-padded along C to the C-tile grid (the matching q rows are zero
    there too, so padded lanes contribute exactly nothing)."""
    assert scales.shape == (total_taps * c, 1), (scales.shape, total_taps, c)
    s3 = scales.reshape(total_taps, c, 1)
    if cp != c:
        s3 = jnp.pad(s3, ((0, 0), (0, cp - c), (0, 0)))
    return s3


def untangled_conv2d_superpack_pallas(x: jax.Array, superpack: jax.Array, *,
                                      taps_hw: Pair,
                                      strides: Pair = (1, 1),
                                      rhs_dilation: Pair = (1, 1),
                                      scales: jax.Array | None = None,
                                      c_tile: int = 128, n_tile: int = 128,
                                      sp_tiles: Pair | None = None,
                                      out_dtype=None,
                                      interpret: bool | None = None
                                      ) -> jax.Array:
    """ONE launch of the valid (pre-padded) untangled correlation, weights in
    the superpacked layout.  x:(B,Hp,Wp,C); superpack:(R·S·C, N) tap-major
    (``ConvPlan.pack``).  Covers the strided and the dilated kind — the
    dilated kernel is never zero-inserted; taps read the raw plane at
    ``m·d_h`` / ``n·d_w`` offsets.  ``sp_tiles=(T_oh, T_ow)`` selects the
    spatially tiled grid (halo'd output tiles, double-buffered input DMA)
    instead of whole-plane VMEM residency.  ``scales`` (``(R·S·C, 1)`` f32)
    marks an int8 quantized superpack: 1-byte weight tiles in VMEM,
    dequantized per tap panel into the same f32 MXU chain."""
    b, hp, wp, c = x.shape
    r, s = taps_hw
    n = superpack.shape[1]
    assert superpack.shape[0] == r * s * c, (superpack.shape, taps_hw, c)
    sh, sw = strides
    dh, dw = rhs_dilation
    oh = (hp - (r - 1) * dh - 1) // sh + 1
    ow = (wp - (s - 1) * dw - 1) // sw + 1
    assert oh > 0 and ow > 0, (oh, ow)
    out_dtype = out_dtype or x.dtype
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    if sp_tiles is not None:
        return _conv_superpack_tiled(
            x, superpack, taps_hw=taps_hw, strides=strides,
            rhs_dilation=rhs_dilation, scales=scales, c_tile=c_tile,
            n_tile=n_tile, sp_tiles=sp_tiles, out_hw=(oh, ow),
            out_dtype=out_dtype, interpret=interpret)

    k3 = superpack.reshape(r * s, c, n)
    c_tile = min(c_tile, c)
    n_tile = min(n_tile, n)
    cp = -(-c // c_tile) * c_tile
    np_ = -(-n // n_tile) * n_tile
    if cp != c:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, cp - c)))
        k3 = jnp.pad(k3, ((0, 0), (0, cp - c), (0, 0)))
    if np_ != n:
        k3 = jnp.pad(k3, ((0, 0), (0, 0), (0, np_ - n)))
    n_c_tiles = cp // c_tile

    grid = (b, np_ // n_tile, n_c_tiles)
    in_specs = [
        pl.BlockSpec((1, hp, wp, c_tile), lambda b_, n_, c_: (b_, 0, 0, c_)),
        pl.BlockSpec((r * s, c_tile, n_tile),
                     lambda b_, n_, c_: (0, c_, n_)),
    ]
    operands = [x, k3]
    if scales is not None:
        in_specs.append(pl.BlockSpec((r * s, c_tile, 1),
                                     lambda b_, n_, c_: (0, c_, 0)))
        operands.append(_scale_tiles(scales, r * s, c, cp))
    out = pl.pallas_call(
        functools.partial(_kernel, taps_hw=(r, s), strides=strides,
                          dilation=rhs_dilation, out_hw=(oh, ow),
                          n_c_tiles=n_c_tiles),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, oh, ow, n_tile),
                               lambda b_, n_, c_: (b_, 0, 0, n_)),
        out_shape=jax.ShapeDtypeStruct((b, oh, ow, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((oh * ow, n_tile), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[..., :n]


def _conv_superpack_tiled(x, superpack, *, taps_hw, strides, rhs_dilation,
                          scales, c_tile, n_tile, sp_tiles, out_hw,
                          out_dtype, interpret):
    """Spatially tiled grid for the single-correlation superpack kernel:
    ``(B, OH/T_oh, OW/T_ow, N/N_t, C/C_t)``, C innermost."""
    b, hp, wp, c = x.shape
    r, s = taps_hw
    n = superpack.shape[1]
    sh, sw = strides
    dh, dw = rhs_dilation
    oh, ow = out_hw
    toh, tow = min(sp_tiles[0], oh), min(sp_tiles[1], ow)
    n_oi, n_oj = -(-oh // toh), -(-ow // tow)
    tin_h = halo_extent(toh, r, sh, dh)
    tin_w = halo_extent(tow, s, sw, dw)
    # grow the plane so every tile's halo read (incl. the ragged edge) is in
    # bounds; the zero rows only feed output pixels that are sliced off
    hp_need = (n_oi - 1) * toh * sh + tin_h
    wp_need = (n_oj - 1) * tow * sw + tin_w
    k3 = superpack.reshape(r * s, c, n)
    c_tile = min(c_tile, c)
    n_tile = min(n_tile, n)
    cp = -(-c // c_tile) * c_tile
    np_ = -(-n // n_tile) * n_tile
    pads = ((0, 0), (0, max(0, hp_need - hp)), (0, max(0, wp_need - wp)),
            (0, cp - c))
    if any(p != (0, 0) for p in pads):
        x = jnp.pad(x, pads)
    if cp != c:
        k3 = jnp.pad(k3, ((0, 0), (0, cp - c), (0, 0)))
    if np_ != n:
        k3 = jnp.pad(k3, ((0, 0), (0, 0), (0, np_ - n)))
    n_c_tiles = cp // c_tile

    grid = (b, n_oi, n_oj, np_ // n_tile, n_c_tiles)
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec((r * s, c_tile, n_tile),
                     lambda b_, i_, j_, n_, c_: (0, c_, n_)),
    ]
    operands = [x, k3]
    if scales is not None:
        in_specs.append(pl.BlockSpec((r * s, c_tile, 1),
                                     lambda b_, i_, j_, n_, c_: (0, c_, 0)))
        operands.append(_scale_tiles(scales, r * s, c, cp))
    out = pl.pallas_call(
        functools.partial(_tiled_kernel, taps_hw=(r, s), strides=strides,
                          dilation=rhs_dilation, tile_hw=(toh, tow),
                          n_c_tiles=n_c_tiles),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, toh, tow, n_tile),
                               lambda b_, i_, j_, n_, c_: (b_, i_, j_, n_)),
        out_shape=jax.ShapeDtypeStruct((b, n_oi * toh, n_oj * tow, np_),
                                       out_dtype),
        scratch_shapes=[pltpu.VMEM((2, tin_h, tin_w, c_tile), x.dtype),
                        pltpu.SemaphoreType.DMA((2,)),
                        pltpu.VMEM((toh * tow, n_tile), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[:, :oh, :ow, :n]


def untangled_conv2d_pallas(x: jax.Array, kernel: jax.Array, *,
                            strides: Pair = (1, 1),
                            rhs_dilation: Pair = (1, 1),
                            c_tile: int = 128, n_tile: int = 128,
                            out_dtype=None,
                            interpret: bool | None = None) -> jax.Array:
    """Valid (pre-padded) untangled convolution. x:(B,Hp,Wp,C), K:(R,S,C,N).

    Full-kernel entry: flattens into the tap-major superpack (free — same
    memory order) and runs the superpack kernel."""
    r, s, kc, n = kernel.shape
    assert kc == x.shape[-1], (kernel.shape, x.shape)
    return untangled_conv2d_superpack_pallas(
        x, kernel.reshape(r * s * kc, n), taps_hw=(r, s), strides=strides,
        rhs_dilation=rhs_dilation, c_tile=c_tile, n_tile=n_tile,
        out_dtype=out_dtype, interpret=interpret)


def _deconv_kernel(x_ref, k_ref, *rest, phases, strides: Pair,
                   n_c_tiles: int):
    """Multi-phase transposed conv: every phase's taps over one VMEM
    residency of the padded plane, flushed as direct interleaved writes.

    ``phases`` is a static tuple of per-phase records
    ``(q_h, q_w, tap_off, T_h, T_w, xoff_h, xoff_w, U, V, acc_off)`` — all
    plan-time constants, so the loop fully unrolls into an MXU matmul chain.
    An int8 superpack rides with a third input ref of per-tap-row scales
    (see ``_tap_panel``).
    """
    s_ref, o_ref, acc_ref = rest if len(rest) == 3 else (None, *rest)
    sh, sw = strides
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                       # (Hg, Wg, C_t) resident in VMEM
    for (qh, qw, tap_off, th, tw, xh, xw, u, v, acc_off) in phases:
        if th * tw == 0 or u * v == 0:
            continue
        acc = acc_ref[pl.ds(acc_off, u * v), :]
        for t in range(th * tw):       # static tap unroll -> MXU matmuls
            ti, tj = divmod(t, tw)
            xs = jax.lax.slice(x, (xh + ti, xw + tj, 0),
                               (xh + ti + u, xw + tj + v, x.shape[2]))
            acc += jnp.dot(xs.reshape(u * v, xs.shape[2]),
                           _tap_panel(k_ref, s_ref, tap_off + t),
                           preferred_element_type=jnp.float32)
        acc_ref[pl.ds(acc_off, u * v), :] = acc

    @pl.when(ci == n_c_tiles - 1)
    def _flush():
        for (qh, qw, tap_off, th, tw, xh, xw, u, v, acc_off) in phases:
            if u * v == 0:
                continue
            blk = acc_ref[pl.ds(acc_off, u * v), :]
            o_ref[0, pl.Slice(qh, u, sh), pl.Slice(qw, v, sw), :] = (
                blk.reshape(u, v, blk.shape[-1]).astype(o_ref.dtype))


def untangled_deconv2d_pallas(xg: jax.Array, superpack: jax.Array, *,
                              phases, out_hw: Pair, strides: Pair,
                              sum_uv: int,
                              scales: jax.Array | None = None,
                              c_tile: int = 128,
                              n_tile: int = 128,
                              sp_tiles: Pair | None = None, out_dtype=None,
                              interpret: bool | None = None) -> jax.Array:
    """Fused transposed conv: ONE kernel launch for all s_h*s_w phases.

    xg: (B, Hg, Wg, C) globally padded plane; superpack: (ΣT·C, N) tap-major
    phase sub-kernels (``ConvPlan.pack`` layout); ``phases`` the plan's
    ``PhaseExec`` records.  Output (B, out_h, out_w, N), written interleaved
    inside the kernel — no stack/transpose pass afterwards.
    ``sp_tiles=(T_u, T_v)`` (phase-output coordinates; uniform phases only)
    selects the spatially tiled grid with halo'd, double-buffered input
    slices instead of whole-plane VMEM residency.  ``scales`` (``(ΣT·C, 1)``
    f32) marks an int8 quantized superpack, dequantized per tap panel.
    """
    b, hg, wg, c = xg.shape
    n = superpack.shape[1]
    total_taps = superpack.shape[0] // max(1, c)
    oh, ow = out_hw
    out_dtype = out_dtype or xg.dtype
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    if sp_tiles is not None:
        return _deconv_tiled(xg, superpack, phases=phases, out_hw=out_hw,
                             strides=strides, scales=scales, c_tile=c_tile,
                             n_tile=n_tile, sp_tiles=sp_tiles,
                             out_dtype=out_dtype, interpret=interpret)

    k3 = superpack.reshape(total_taps, c, n)
    c_tile = min(c_tile, c)
    n_tile = min(n_tile, n)
    cp = -(-c // c_tile) * c_tile
    np_ = -(-n // n_tile) * n_tile
    if cp != c:
        xg = jnp.pad(xg, ((0, 0), (0, 0), (0, 0), (0, cp - c)))
        k3 = jnp.pad(k3, ((0, 0), (0, cp - c), (0, 0)))
    if np_ != n:
        k3 = jnp.pad(k3, ((0, 0), (0, 0), (0, np_ - n)))
    n_c_tiles = cp // c_tile

    meta = tuple(
        (ex.q[0], ex.q[1], ex.tap_off, ex.taps[0], ex.taps[1],
         ex.xoff[0], ex.xoff[1], ex.out_hw[0], ex.out_hw[1], ex.acc_off)
        for ex in phases)
    grid = (b, np_ // n_tile, n_c_tiles)
    in_specs = [
        pl.BlockSpec((1, hg, wg, c_tile), lambda b_, n_, c_: (b_, 0, 0, c_)),
        pl.BlockSpec((total_taps, c_tile, n_tile),
                     lambda b_, n_, c_: (0, c_, n_)),
    ]
    operands = [xg, k3]
    if scales is not None:
        in_specs.append(pl.BlockSpec((total_taps, c_tile, 1),
                                     lambda b_, n_, c_: (0, c_, 0)))
        operands.append(_scale_tiles(scales, total_taps, c, cp))
    out = pl.pallas_call(
        functools.partial(_deconv_kernel, phases=meta, strides=strides,
                          n_c_tiles=n_c_tiles),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, oh, ow, n_tile),
                               lambda b_, n_, c_: (b_, 0, 0, n_)),
        out_shape=jax.ShapeDtypeStruct((b, oh, ow, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((sum_uv, n_tile), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[..., :n]


def deconv_tap_span(phases) -> tuple[Pair, Pair]:
    """((min_h, max_h), (min_w, max_w)) tap-origin span over the non-empty
    phases: phase q's taps read the padded plane at rows ``xoff_h + t_i + u``
    — the halo'd tile must cover every phase's origin, so its extent along
    one dim is ``(max - min) + T_u`` (the phase-aware halo)."""
    live = [ex for ex in phases if ex.taps[0] * ex.taps[1] > 0]
    assert live, "deconv_tap_span needs at least one non-empty phase"
    min_h = min(ex.xoff[0] for ex in live)
    max_h = max(ex.xoff[0] + ex.taps[0] - 1 for ex in live)
    min_w = min(ex.xoff[1] for ex in live)
    max_w = max(ex.xoff[1] + ex.taps[1] - 1 for ex in live)
    return ((min_h, max_h), (min_w, max_w))


def _deconv_tiled_kernel(x_any, k_ref, *rest, phases,
                         strides: Pair, tile_uv: Pair, min_off: Pair,
                         n_c_tiles: int):
    """Spatially tiled multi-phase transposed conv: one interleaved output
    tile of (T_u·s_h, T_v·s_w) pixels per grid step.  ``phases`` is a static
    tuple ``(q_h, q_w, tap_off, T_h, T_w, xoff_h, xoff_w)``; every phase's
    taps read the one double-buffered halo'd input tile at plan-time offsets
    relative to the phase-origin span ``min_off``."""
    s_ref, o_ref, buf, sem, acc_ref = \
        rest if len(rest) == 5 else (None, *rest)
    sh, sw = strides
    tu, tv = tile_uv
    mh, mw = min_off
    ci = pl.program_id(4)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = _halo_stream(x_any, buf, sem,
                     lambda i_, j_: (i_ * tu + mh, j_ * tv + mw))
    for pi, (qh, qw, tap_off, th, tw, xh, xw) in enumerate(phases):
        if th * tw == 0:
            continue                    # empty phase: its acc stays zero
        acc = acc_ref[pl.ds(pi * tu * tv, tu * tv), :]
        for t in range(th * tw):        # static tap unroll -> MXU matmuls
            ti, tj = divmod(t, tw)
            xs = jax.lax.slice(x, (xh - mh + ti, xw - mw + tj, 0),
                               (xh - mh + ti + tu, xw - mw + tj + tv,
                                x.shape[2]))
            acc += jnp.dot(xs.reshape(tu * tv, xs.shape[2]),
                           _tap_panel(k_ref, s_ref, tap_off + t),
                           preferred_element_type=jnp.float32)
        acc_ref[pl.ds(pi * tu * tv, tu * tv), :] = acc

    @pl.when(ci == n_c_tiles - 1)
    def _flush():
        for pi, (qh, qw, *_rest) in enumerate(phases):
            blk = acc_ref[pl.ds(pi * tu * tv, tu * tv), :]
            o_ref[0, pl.Slice(qh, tu, sh), pl.Slice(qw, tv, sw), :] = (
                blk.reshape(tu, tv, blk.shape[-1]).astype(o_ref.dtype))


def _deconv_tiled(xg, superpack, *, phases, out_hw, strides, scales, c_tile,
                  n_tile, sp_tiles, out_dtype, interpret):
    """Spatially tiled grid for the multi-phase deconv kernel:
    ``(B, U/T_u, V/T_v, N/N_t, C/C_t)``, C innermost.  Requires uniform
    phases (all share (U, V) — equivalently ``out % stride == 0``)."""
    b, hg, wg, c = xg.shape
    n = superpack.shape[1]
    total_taps = superpack.shape[0] // max(1, c)
    sh, sw = strides
    oh, ow = out_hw
    uu, vv = phases[0].out_hw
    assert all(ex.out_hw == (uu, vv) for ex in phases), \
        "sp_tiles requires uniform phases"
    assert uu * sh == oh and vv * sw == ow, (out_hw, (uu, vv), strides)
    tu, tv = min(sp_tiles[0], uu), min(sp_tiles[1], vv)
    n_oi, n_oj = -(-uu // tu), -(-vv // tv)
    ((mh, xh_max), (mw, xw_max)) = deconv_tap_span(phases)
    tin_h = xh_max - mh + tu
    tin_w = xw_max - mw + tv
    hg_need = mh + (n_oi - 1) * tu + tin_h
    wg_need = mw + (n_oj - 1) * tv + tin_w
    k3 = superpack.reshape(total_taps, c, n)
    c_tile = min(c_tile, c)
    n_tile = min(n_tile, n)
    cp = -(-c // c_tile) * c_tile
    np_ = -(-n // n_tile) * n_tile
    pads = ((0, 0), (0, max(0, hg_need - hg)), (0, max(0, wg_need - wg)),
            (0, cp - c))
    if any(p != (0, 0) for p in pads):
        xg = jnp.pad(xg, pads)
    if cp != c:
        k3 = jnp.pad(k3, ((0, 0), (0, cp - c), (0, 0)))
    if np_ != n:
        k3 = jnp.pad(k3, ((0, 0), (0, 0), (0, np_ - n)))
    n_c_tiles = cp // c_tile

    meta = tuple((ex.q[0], ex.q[1], ex.tap_off, ex.taps[0], ex.taps[1],
                  ex.xoff[0], ex.xoff[1]) for ex in phases)
    grid = (b, n_oi, n_oj, np_ // n_tile, n_c_tiles)
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec((total_taps, c_tile, n_tile),
                     lambda b_, i_, j_, n_, c_: (0, c_, n_)),
    ]
    operands = [xg, k3]
    if scales is not None:
        in_specs.append(pl.BlockSpec((total_taps, c_tile, 1),
                                     lambda b_, i_, j_, n_, c_: (0, c_, 0)))
        operands.append(_scale_tiles(scales, total_taps, c, cp))
    out = pl.pallas_call(
        functools.partial(_deconv_tiled_kernel, phases=meta, strides=strides,
                          tile_uv=(tu, tv), min_off=(mh, mw),
                          n_c_tiles=n_c_tiles),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, tu * sh, tv * sw, n_tile),
                               lambda b_, i_, j_, n_, c_: (b_, i_, j_, n_)),
        out_shape=jax.ShapeDtypeStruct(
            (b, n_oi * tu * sh, n_oj * tv * sw, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((2, tin_h, tin_w, c_tile), xg.dtype),
                        pltpu.SemaphoreType.DMA((2,)),
                        pltpu.VMEM((len(phases) * tu * tv, n_tile),
                                   jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[:, :oh, :ow, :n]


def _weight_tile_bytes(total_taps, c_tile, n_tile, itemsize, witemsize):
    """Superpack-tile VMEM bytes.  ``witemsize`` is the *weight* element
    width when it differs from the activation ``itemsize`` (int8 superpacks:
    1 byte/elem) — the quantized tile also carries its per-tap-row f32 scale
    column (``ΣT · C_t`` values, 4 bytes each).  ``witemsize=None`` means
    weights ride at the activation width (the dense f32 layout)."""
    if witemsize is None:
        witemsize = itemsize
    bytes_ = witemsize * total_taps * c_tile * n_tile
    if witemsize != itemsize:
        bytes_ += 4 * total_taps * c_tile        # scale rows (always f32)
    return bytes_


def vmem_bytes_estimate(hp, wp, c_tile, r, s, n_tile, oh, ow, itemsize=4,
                        witemsize=None):
    """Working-set estimate used by the dispatcher to pick tile sizes.

    Thin (r, s) wrapper over ``vmem_bytes_estimate_superpack`` — one owner
    for the formula.  The accumulator scratch is always f32 (4 bytes/elem)
    regardless of the input dtype; only the plane, kernel, and output blocks
    scale with ``itemsize`` (the kernel block with ``witemsize`` when
    quantized weights make them differ).
    """
    return vmem_bytes_estimate_superpack(hp, wp, c_tile, r * s, n_tile,
                                         oh, ow, itemsize, witemsize)


def vmem_bytes_estimate_fused(hg, wg, c_tile, total_taps, n_tile, sum_uv,
                              oh, ow, itemsize=4, witemsize=None):
    """Working set of the fused multi-phase kernel: global plane block +
    superpack tile (1-byte elements + f32 scale rows when quantized) + full
    interleaved output block, plus the per-phase f32 accumulator scratch
    (always 4 bytes/elem)."""
    return itemsize * (hg * wg * c_tile + oh * ow * n_tile) \
        + _weight_tile_bytes(total_taps, c_tile, n_tile, itemsize,
                             witemsize) \
        + 4 * sum_uv * n_tile


def vmem_bytes_estimate_superpack(hp, wp, c_tile, total_taps, n_tile,
                                  oh, ow, itemsize=4, witemsize=None):
    """Working set of the single-correlation superpack kernel — the
    dilation-aware estimate: ``hp``/``wp`` are padded-plane dims that grow
    with the dilated tap reach ``(R-1)·d``, while the superpack tile stays
    ``total_taps = R·S`` rows no matter the dilation (no zero-inserted
    kernel is ever resident).  The superpack tile shrinks to 1 byte/elem
    (+ f32 scale rows) for int8 weights.  f32 accumulator always at
    4 bytes/elem."""
    return itemsize * (hp * wp * c_tile + oh * ow * n_tile) \
        + _weight_tile_bytes(total_taps, c_tile, n_tile, itemsize,
                             witemsize) \
        + 4 * oh * ow * n_tile


def vmem_bytes_estimate_tiled(tin_h, tin_w, c_tile, total_taps, n_tile,
                              acc_rows, itemsize=4, witemsize=None):
    """Working set of the spatially tiled kernels (both kinds):

    - ``2 · tin_h · tin_w · C_t`` — the halo'd input tile, **twice** (the
      double buffer: one slot computing, one streaming the next halo
      slice), at the input itemsize;
    - ``total_taps · C_t · N_t`` — the superpack tile (R·S taps for the
      single-correlation kind, ΣT for the multi-phase deconv), at the
      weight itemsize (1 byte + f32 scale rows when quantized);
    - ``acc_rows · N_t`` — the output block at the input itemsize *plus*
      the f32 accumulator at a fixed 4 bytes/elem.  ``acc_rows`` is the
      output-tile pixel count: ``T_oh·T_ow`` (single) or ``s_h·s_w·T_u·T_v``
      (deconv — every phase's segment of the shared scratch).

    ``tin_* = halo_extent(tile, taps, stride, dilation)`` for the single
    kind; the deconv's halo is the phase tap-origin span plus the tile
    (``deconv_tap_span``)."""
    return itemsize * (2 * tin_h * tin_w * c_tile + acc_rows * n_tile) \
        + _weight_tile_bytes(total_taps, c_tile, n_tile, itemsize,
                             witemsize) \
        + 4 * acc_rows * n_tile
