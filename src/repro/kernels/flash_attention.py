"""Pallas TPU flash attention (forward) for the LM substrate's hot path.

Blocking: grid over (batch*heads, q-blocks).  One (b,h)'s full K/V panels
live in VMEM (bf16, S x D — 1 MB each at S=4096, D=128) and the kernel
streams q-blocks against KV *chunks* with the online-softmax recurrence, so
the f32 score tile never exceeds (BQ x CK).  GQA is handled in the index
map: head h reads KV head h // group_size — no repeated KV in HBM.

VMEM budget at defaults (BQ=256, CK=512, D=128, S<=8192):
  q 64KB + K,V 2*S*D*2B (<=4MB) + scores 512KB + acc 128KB  << 16 MB.
Sequences beyond ``max_kv_resident`` fall back to the jnp flash path
(layers.attention.flash_attention) — same math, XLA fusion.

Validated in interpret mode against the pure-jnp oracle (tests/).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, ck: int, seq_k: int,
            causal: bool, window: int, scale: float, q_offset: int):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                  # (BQ, D)
    acc = jnp.zeros((bq, q.shape[-1]), jnp.float32)
    m = jnp.full((bq,), NEG_INF, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)
    qpos = q_offset + qi * bq + jax.lax.iota(jnp.int32, bq)

    n_chunks = seq_k // ck
    for c in range(n_chunks):                         # static unroll
        k = k_ref[0, pl.ds(c * ck, ck)].astype(jnp.float32)   # (CK, D)
        v = v_ref[0, pl.ds(c * ck, ck)].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = c * ck + jax.lax.iota(jnp.int32, ck)
        mask = jnp.ones((bq, ck), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window > 0:
            mask &= qpos[:, None] - kpos[None, :] < window
        s = jnp.where(mask, s, NEG_INF)
        m2 = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m2[:, None])
        r = jnp.exp(m - m2)
        acc = acc * r[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        l = l * r + jnp.sum(p, axis=-1)
        m = m2
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=0, q_offset=0,
                           bq=256, ck=512, scale=None,
                           interpret: bool | None = None):
    """q: (B, Sq, H, D); k, v: (B, Sk, Kh, D) with H % Kh == 0."""
    b, sq, h, d = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = scale if scale is not None else d ** -0.5
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bq = min(bq, sq)
    ck = min(ck, sk)
    assert sq % bq == 0 and sk % ck == 0, (sq, bq, sk, ck)

    # (B, Sq, H, D) -> (B*H, Sq, D); KV stay per-kv-head, indexed via map
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * kh, sk, d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * kh, sk, d)

    def kv_index(bh, qi):
        return (bh // g, 0, 0)        # head h -> kv head h // g (flattened)

    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, ck=ck, seq_k=sk, causal=causal,
                          window=window, scale=scale, q_offset=q_offset),
        grid=(b * h, sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, sk, d), kv_index),
            pl.BlockSpec((1, sk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
