"""jit'd dispatch wrapper around the Pallas untangled-conv kernel.

Handles padding/cropping, VMEM-aware tile selection, and the pure-JAX
fallback when a plane does not fit the whole-plane blocking (large
segmentation maps) — the public entry the engine's ``backend='pallas'``
path uses.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.untangle import pad_or_crop, untangled_conv2d as _xla_untangled
from repro.kernels.untangled_conv import (untangled_conv2d_pallas,
                                          vmem_bytes_estimate)

Pair = tuple[int, int]

# leave headroom below the 16 MiB/core VMEM of v5e
_VMEM_BUDGET = 12 * 1024 * 1024


def _pick_tiles(hp, wp, c, n, r, s, oh, ow, itemsize):
    """Largest MXU-aligned (C_t, N_t) whose working set fits VMEM."""
    for n_t in (256, 128, 64, 32, 16, 8):
        for c_t in (256, 128, 64, 32, 16, 8):
            if c_t > max(c, 8) * 2 or n_t > max(n, 8) * 2:
                continue
            if vmem_bytes_estimate(hp, wp, min(c_t, c), r, s, min(n_t, n),
                                   oh, ow, itemsize) <= _VMEM_BUDGET:
                return min(c_t, c), min(n_t, n)
    return None


@partial(jax.jit, static_argnames=("strides", "padding", "rhs_dilation",
                                   "interpret"))
def untangled_conv2d(x: jax.Array, kernel: jax.Array, *,
                     strides: Pair = (1, 1),
                     padding: Sequence[Pair] = ((0, 0), (0, 0)),
                     rhs_dilation: Pair = (1, 1),
                     interpret: bool | None = None) -> jax.Array:
    """Untangled convolution, Pallas-tiled when the plane fits VMEM."""
    r, s, c, n = kernel.shape
    xp = pad_or_crop(x, padding)
    lead = xp.shape[:-3]
    xp4 = xp.reshape((-1,) + xp.shape[-3:])
    hp, wp = xp4.shape[1], xp4.shape[2]
    sh, sw = strides
    dh, dw = rhs_dilation
    oh = (hp - (r - 1) * dh - 1) // sh + 1
    ow = (wp - (s - 1) * dw - 1) // sw + 1
    tiles = _pick_tiles(hp, wp, c, n, r, s, oh, ow, 4)
    if tiles is None:
        # plane too large for whole-plane VMEM blocking: XLA fallback
        y = _xla_untangled(x, kernel, strides=strides, padding=padding,
                           rhs_dilation=rhs_dilation)
        return y
    c_t, n_t = tiles
    y = untangled_conv2d_pallas(xp4, kernel, strides=strides,
                                rhs_dilation=rhs_dilation, c_tile=c_t,
                                n_tile=n_t, interpret=interpret)
    return y.reshape(lead + y.shape[1:])
