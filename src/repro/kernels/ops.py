"""jit'd dispatch wrapper around the Pallas untangled-conv kernel.

Since the plan/executor refactor this is a thin shim: padding geometry,
VMEM-aware tile selection, and the Pallas-vs-XLA fallback decision all live
in ``repro.core.plan`` (made once per ``ConvSpec``, not per call).  The shim
exists so kernel-level callers and tests keep a stable entry point with an
explicit ``interpret`` knob.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax

from repro.core.plan import (conv_spec, pick_vmem_tiles, plan_conv,
                             _single_fwd, _transposed_fwd)

Pair = tuple[int, int]

# kept under the old private name for any in-tree callers
_pick_tiles = pick_vmem_tiles


@partial(jax.jit, static_argnames=("strides", "padding", "rhs_dilation",
                                   "interpret"))
def untangled_conv2d(x: jax.Array, kernel: jax.Array, *,
                     strides: Pair = (1, 1),
                     padding: Sequence[Pair] = ((0, 0), (0, 0)),
                     rhs_dilation: Pair = (1, 1),
                     interpret: bool | None = None) -> jax.Array:
    """Untangled convolution, Pallas-tiled when the plane fits VMEM.

    Forward-only kernel entry (packs the HWIO kernel into the superpack per
    call); training and serving go through ``ConvPlan.apply`` on held
    superpacked weights."""
    kind = "dilated" if tuple(rhs_dilation) != (1, 1) else "conv"
    spec = conv_spec(kind, x.shape, kernel.shape, strides=strides,
                     padding=padding, dilation=rhs_dilation, dtype=x.dtype,
                     backend="pallas")
    plan = plan_conv(spec)
    return _single_fwd(plan, x, plan.as_superpack(kernel), interpret)


@partial(jax.jit, static_argnames=("strides", "padding", "interpret"))
def untangled_deconv2d(x: jax.Array, kernel: jax.Array, *,
                       strides: Pair = (2, 2),
                       padding: Sequence[Pair] = ((2, 2), (2, 2)),
                       interpret: bool | None = None) -> jax.Array:
    """Fused transposed conv (forward only): every phase in one launch.

    Kernel-level entry with an explicit ``interpret`` knob — packs per call,
    so it is for kernel tests and experimentation; serving holds the
    superpack and goes through ``ConvPlan.apply``.
    """
    spec = conv_spec("transposed", x.shape, kernel.shape, strides=strides,
                     padding=padding, dtype=x.dtype, backend="pallas")
    plan = plan_conv(spec)
    return _transposed_fwd(plan, x, plan.pack(kernel), interpret)
