"""Pure-jnp oracles for the Pallas kernels (untangled conv, flash attn)."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

Pair = tuple[int, int]


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """Dense-softmax oracle for kernels/flash_attention.py.
    q: (B,Sq,H,D); k,v: (B,Sk,Kh,D)."""
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = scale or d ** -0.5
    qr = q.reshape(b, sq, kh, g, d).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qr, k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None, None], s, -2.0 ** 30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d).astype(q.dtype)


def untangled_conv2d_ref(x: jax.Array, kernel: jax.Array, *,
                         strides: Pair = (1, 1),
                         padding: Sequence[Pair] = ((0, 0), (0, 0)),
                         rhs_dilation: Pair = (1, 1)) -> jax.Array:
    """XLA's conv as the independent oracle (NHWC/HWIO, correlation)."""
    (ph, pw) = padding
    h_lo, h_hi = max(0, -ph[0]), max(0, -ph[1])
    w_lo, w_hi = max(0, -pw[0]), max(0, -pw[1])
    if h_lo or h_hi or w_lo or w_hi:
        x = x[..., h_lo:x.shape[-3] - h_hi, w_lo:x.shape[-2] - w_hi, :]
        ph = (max(0, ph[0]), max(0, ph[1]))
        pw = (max(0, pw[0]), max(0, pw[1]))
    lead = x.shape[:-3]
    x4 = x.reshape((-1,) + x.shape[-3:])
    y = jax.lax.conv_general_dilated(
        x4.astype(jnp.float32), kernel.astype(jnp.float32),
        window_strides=tuple(strides), padding=(tuple(ph), tuple(pw)),
        rhs_dilation=tuple(rhs_dilation),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y.reshape(lead + y.shape[1:]).astype(x.dtype)
