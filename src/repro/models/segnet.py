"""DilatedNet-style semantic segmentation on the HUGE² plan/executor engine.

The paper motivates the dilated (atrous) convolution with the semantic-
segmentation workload (DeepLab / DilatedNet context aggregation); this model
makes that scenario an end-to-end resident of the engine rather than a
benchmark docstring:

- a small strided **front-end** (3x3 convs, two stride-2 downsamples) built
  from planned 'conv' sites, and
- an **atrous context module** (3x3 dilated convs, exponentially growing
  dilation 1,2,4,8,1 at constant resolution — the DilatedNet trick for
  growing receptive field without losing resolution or inserting a single
  kernel zero) built from planned 'dilated' sites, capped by a 1x1
  classifier head.

Every convolution site gets a ``ConvPlan`` built once at model load
(``segnet_plans``), and **all** weights are stored in the single-phase
tap-major superpack ``(R·S·C, N)`` — mirroring ``models/gan.py``'s packed
convention — so inference never re-slices a kernel and training runs the
§3.2.3 custom VJPs directly on the packed layout.  The ``backend`` field is
a plan policy ('xla' | 'pallas' | 'auto') consumed at plan-build time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.autotune import AutotunePolicy
from repro.core.plan import ConvPlan, ConvSpec, plan_conv
from repro.layers import common as cm


@dataclasses.dataclass(frozen=True)
class SegLayer:
    kind: str          # 'conv' (front-end / head) | 'dilated' (context)
    in_hw: int
    in_c: int
    out_c: int
    kernel: int = 3
    stride: int = 1
    dilation: int = 1


def atrous_padding(kernel: int, dilation: int):
    """'SAME'-style padding for an odd kernel at dilation d: the dilated tap
    reach is (k-1)·d + 1, so pad d·(k-1)/2 per side keeps the resolution
    (stride 1) or halves it exactly (stride 2, even input)."""
    half = dilation * (kernel - 1) // 2
    return ((half, half), (half, half))


def _front_end(in_hw: int, in_c: int, width: int) -> tuple[SegLayer, ...]:
    return (
        SegLayer("conv", in_hw, in_c, width // 4),
        SegLayer("conv", in_hw, width // 4, width // 2, stride=2),
        SegLayer("conv", in_hw // 2, width // 2, width // 2),
        SegLayer("conv", in_hw // 2, width // 2, width, stride=2),
    )


def _context(hw: int, width: int) -> tuple[SegLayer, ...]:
    return tuple(SegLayer("dilated", hw, width, width, dilation=d)
                 for d in (1, 2, 4, 8, 1))


@dataclasses.dataclass(frozen=True)
class SegNetConfig:
    name: str
    in_hw: int = 64
    in_c: int = 3
    width: int = 128
    num_classes: int = 21
    backend: str = "xla"            # plan policy: 'xla' | 'pallas' | 'auto'
    # measured-route policy (None = heuristic routes)
    autotune: Optional[AutotunePolicy] = None
    # plane-parallel policy: (D_h, D_w) requested device tiling per site
    # (see ``GANConfig.spatial``); single-device fallback is always kept
    spatial: tuple[int, int] = (1, 1)
    # weight storage dtype for every conv site: 'float32' (dense) or 'int8'
    # (quantized superpacks — ``ConvSpec.wdtype``); activations stay f32
    wdtype: str = "float32"

    @property
    def layers(self) -> tuple[SegLayer, ...]:
        front = _front_end(self.in_hw, self.in_c, self.width)
        ctx = _context(self.in_hw // 4, self.width)
        head = (SegLayer("conv", self.in_hw // 4, self.width,
                         self.num_classes, kernel=1),)
        return front + ctx + head

    @property
    def out_hw(self) -> int:
        return self.in_hw // 4


SEGNET = SegNetConfig("segnet")                        # edge default
SEGNET_TINY = SegNetConfig("segnet-tiny", in_hw=32, width=32, num_classes=5)


# ---------------------------------------------------------------------------
# load-time planning: one ConvPlan per convolution site
# ---------------------------------------------------------------------------

def segnet_plans(cfg: SegNetConfig, dtype=jnp.float32) -> tuple[ConvPlan, ...]:
    """Plans for every front-end / context / head site (cached; the build
    cost is paid once at model load)."""
    plans = []
    for l in cfg.layers:
        plans.append(plan_conv(ConvSpec(
            kind=l.kind, in_hw=(l.in_hw, l.in_hw), in_c=l.in_c,
            out_c=l.out_c, kernel_hw=(l.kernel, l.kernel),
            strides=(l.stride, l.stride),
            padding=atrous_padding(l.kernel, l.dilation),
            dilation=(l.dilation, l.dilation),
            dtype=str(jnp.dtype(dtype)), backend=cfg.backend,
            spatial=cfg.spatial, wdtype=cfg.wdtype),
            autotune=cfg.autotune))
    return tuple(plans)


# ---------------------------------------------------------------------------
# params: every conv weight stored superpacked (R·S·C, N)
# ---------------------------------------------------------------------------

def segnet_init(key, cfg: SegNetConfig, dtype=jnp.float32, dist=None):
    """Superpacked params with ``(conv_taps, conv_out)`` logical specs;
    pass a ``DistContext`` to get them placed on its mesh (out-channels
    sharded under the default rules) for data-parallel serving."""
    plans = segnet_plans(cfg, dtype)
    ks = jax.random.split(key, len(cfg.layers))
    p, s = {}, {}
    for i, (l, plan) in enumerate(zip(cfg.layers, plans)):
        fan_in = l.kernel * l.kernel * l.in_c
        kernel = jax.random.normal(
            ks[i], (l.kernel, l.kernel, l.in_c, l.out_c),
            dtype) * (2.0 / fan_in) ** 0.5
        p[f"w{i}"] = plan.pack(kernel)          # (R·S·C, N) superpack
        p[f"b{i}"] = jnp.zeros((l.out_c,), dtype)
        s[f"w{i}"] = cm.spec("conv_taps", "conv_out")   # shard out-channels
        s[f"b{i}"] = cm.spec("conv_out")
    if dist is not None:
        p = dist.shard_params(p, s)
    return p, s


def segnet_apply(p, x, cfg: SegNetConfig):
    """x: (B, in_hw, in_hw, in_c) -> logits (B, in_hw/4, in_hw/4, classes).

    Every conv is ``plan.apply`` on the stored superpack — one launch / one
    wide GEMM per site, custom VJP on the packed layout under ``jax.grad``.
    """
    plans = segnet_plans(cfg, x.dtype)          # cache hits after model load
    n_layers = len(plans)
    for i, plan in enumerate(plans):
        x = plan.apply(x, p[f"w{i}"]) + p[f"b{i}"]
        if i < n_layers - 1:
            x = jax.nn.relu(x)
    return x


def segnet_unpack(p, cfg: SegNetConfig):
    """Packed params -> full (R,S,C,N) HWIO kernels (offline export)."""
    plans = segnet_plans(cfg)
    out = dict(p)
    for i, plan in enumerate(plans):
        out[f"w{i}"] = plan.unpack(p[f"w{i}"])
    return out


def upsample_logits(logits, factor: int = 4):
    """Nearest-neighbour upsample back to input resolution (the DilatedNet
    paper uses learned/bilinear upsampling; nearest keeps the example pure
    engine work)."""
    return jnp.repeat(jnp.repeat(logits, factor, axis=-3), factor, axis=-2)


def segnet_loss(p, x, labels, cfg: SegNetConfig):
    """Mean pixel cross-entropy at feature resolution.

    labels: (B, out_hw, out_hw) int class ids.
    """
    logits = segnet_apply(p, x, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)
    return -ll.mean()
