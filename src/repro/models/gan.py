"""DCGAN / cGAN (paper Table 1) built on the HUGE² plan/executor engine.

Generators stack the exact Table-1 transposed-conv layers; discriminators
mirror them with strided convs.  Every convolution site gets a ``ConvPlan``
built **once at model load** (``generator_plans`` / ``discriminator_plans``,
backed by the keyed plan cache) and the generator's deconv weights are stored
**superpacked** — every phase sub-kernel concatenated into one tap-major
``(Σ T_h·T_w·C, N)`` buffer per layer — so the generator never re-slices a
kernel inside a jitted call, every transposed conv executes as a single
launch, and each layer's weights are one shardable array.  The plans'
custom VJPs implement the paper's §3.2.3 training formulation directly on
the superpacked layout, so both inference *and* training exercise the
engine.  (Pre-superpack checkpoints that stored per-phase dicts still load:
``ConvPlan.apply`` / ``unpack`` adapt them via ``as_superpack``.)
The discriminator now follows the same convention: its strided-conv weights
are stored as single-phase ``(R·S·C, N)`` superpacks, and its custom VJP
runs the §3.2.3 backward directly on that layout (pre-superpack checkpoints
holding HWIO kernels adapt via ``as_superpack``).

The ``backend`` field of ``GANConfig`` is a plan policy ('xla' | 'pallas' |
'auto') consumed at plan-build time; it is no longer threaded through the
apply functions call-by-call.  ``autotune`` is the second plan policy: an
optional ``repro.core.autotune.AutotunePolicy`` that replaces the heuristic
per-bucket routes with measured winners (per-host cache hits at model load,
live microbenchmarks on a miss) — see ``docs/ARCHITECTURE.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.autotune import AutotunePolicy
from repro.core.plan import ConvPlan, ConvSpec, plan_conv
from repro.layers import common as cm


@dataclasses.dataclass(frozen=True)
class DeconvLayer:
    in_hw: int
    in_c: int
    out_c: int
    kernel: int
    stride: int


# paper Table 1
DCGAN_LAYERS = (
    DeconvLayer(4, 1024, 512, 5, 2),
    DeconvLayer(8, 512, 256, 5, 2),
    DeconvLayer(16, 256, 128, 5, 2),
    DeconvLayer(32, 128, 3, 5, 2),
)
CGAN_LAYERS = (
    DeconvLayer(8, 256, 128, 4, 2),
    DeconvLayer(16, 128, 3, 4, 2),
)


def deconv_padding(kernel: int, stride: int):
    """'SAME'-style transposed padding: out = stride * in.

    out = (h-1)*s + pl + ph - k + 2 == s*h  =>  pl + ph = k + s - 2.
    """
    total = kernel + stride - 2
    pl = max(0, (kernel - stride + 1) // 2)
    ph = total - pl
    return ((pl, ph), (pl, ph))


@dataclasses.dataclass(frozen=True)
class GANConfig:
    name: str
    layers: tuple[DeconvLayer, ...]
    z_dim: int = 100
    backend: str = "xla"            # plan policy: 'xla' | 'pallas' | 'auto'
    # measured-route policy (None = heuristic routes); model load pays any
    # cache-miss microbenchmarks once, apply only ever sees tuned plans
    autotune: Optional[AutotunePolicy] = None
    # plane-parallel policy: (D_h, D_w) device tiling requested for every
    # conv site (``ConvSpec.spatial``).  Plans keep single-device routes as
    # the fallback, so (2, 1) on a mesh-less host is still correct — set
    # from ``DistContext.spatial_tiles()`` when serving over a spatial mesh
    spatial: tuple[int, int] = (1, 1)
    # weight storage dtype for every conv site: 'float32' (dense) or 'int8'
    # (quantized superpacks — ``ConvSpec.wdtype``); activations stay f32
    wdtype: str = "float32"


DCGAN = GANConfig("dcgan", DCGAN_LAYERS)
CGAN = GANConfig("cgan", CGAN_LAYERS, z_dim=110)   # z + 10-class condition


# ---------------------------------------------------------------------------
# load-time planning: one ConvPlan per convolution site
# ---------------------------------------------------------------------------

def generator_plans(cfg: GANConfig, dtype=jnp.float32) -> tuple[ConvPlan, ...]:
    """Plans for every generator deconv site (cached; build cost paid once
    — including any autotune microbenchmarks the config's policy asks for)."""
    plans = []
    for l in cfg.layers:
        plans.append(plan_conv(ConvSpec(
            kind="transposed", in_hw=(l.in_hw, l.in_hw), in_c=l.in_c,
            out_c=l.out_c, kernel_hw=(l.kernel, l.kernel),
            strides=(l.stride, l.stride),
            padding=deconv_padding(l.kernel, l.stride),
            dtype=str(jnp.dtype(dtype)), backend=cfg.backend,
            spatial=cfg.spatial, wdtype=cfg.wdtype),
            autotune=cfg.autotune))
    return tuple(plans)


def discriminator_plans(cfg: GANConfig,
                        dtype=jnp.float32) -> tuple[ConvPlan, ...]:
    """Plans for the mirrored strided-conv sites (image -> features)."""
    plans = []
    for l in reversed(cfg.layers):
        k = l.kernel
        plans.append(plan_conv(ConvSpec(
            kind="conv", in_hw=(l.in_hw * l.stride, l.in_hw * l.stride),
            in_c=l.out_c, out_c=l.in_c, kernel_hw=(k, k),
            strides=(l.stride, l.stride),
            padding=((k // 2, (k - 1) // 2), (k // 2, (k - 1) // 2)),
            dtype=str(jnp.dtype(dtype)), backend=cfg.backend,
            spatial=cfg.spatial, wdtype=cfg.wdtype),
            autotune=cfg.autotune))
    return tuple(plans)


# ---------------------------------------------------------------------------
# generator: packed deconv weights, planned execution
# ---------------------------------------------------------------------------

def generator_init(key, cfg: GANConfig, dtype=jnp.float32, dist=None):
    """Init generator params with the deconv weights already *packed* into
    the plans' GEMM-ready per-phase layout (the load-time decomposition).

    Each superpack is ONE shardable buffer with logical axes
    ``(conv_taps, conv_out)`` (``sharding.SUPERPACK_SPEC``); pass a
    ``DistContext`` and the params come back placed on its mesh
    (out-channels sharded under the default rules), ready for
    data-parallel serving/training under ``jax.jit``."""
    plans = generator_plans(cfg, dtype)
    l0 = cfg.layers[0]
    ks = jax.random.split(key, len(cfg.layers) + 1)
    p = {"proj": jax.random.normal(
        ks[0], (cfg.z_dim, l0.in_hw * l0.in_hw * l0.in_c), dtype) * 0.02}
    s = {"proj": cm.spec(None, "conv_out")}
    for i, l in enumerate(cfg.layers):
        kernel = jax.random.normal(
            ks[i + 1], (l.kernel, l.kernel, l.in_c, l.out_c), dtype) * 0.02
        p[f"dc{i}"] = plans[i].pack(kernel)
        p[f"b{i}"] = jnp.zeros((l.out_c,), dtype)
        # the superpack is one (Σ T_h*T_w*C, N) buffer: shard out-channels
        s[f"dc{i}"] = cm.spec("conv_taps", "conv_out")
        s[f"b{i}"] = cm.spec("conv_out")
    if dist is not None:
        p = dist.shard_params(p, s)
    return p, s


def generator_apply(p, z, cfg: GANConfig):
    plans = generator_plans(cfg, z.dtype)      # cache hits after model load
    l0 = cfg.layers[0]
    x = (z @ p["proj"]).reshape(z.shape[0], l0.in_hw, l0.in_hw, l0.in_c)
    x = jax.nn.relu(x)
    for i, plan in enumerate(plans):
        x = plan.apply(x, p[f"dc{i}"])
        x = x + p[f"b{i}"]
        x = jnp.tanh(x) if i == len(plans) - 1 else jax.nn.relu(x)
    return x


def generator_unpack(p, cfg: GANConfig):
    """Packed generator params -> full (R,S,C,N) HWIO kernels (offline use:
    export, or feeding baselines that expect undecomposed weights)."""
    plans = generator_plans(cfg)
    out = dict(p)
    for i, plan in enumerate(plans):
        out[f"dc{i}"] = plan.unpack(p[f"dc{i}"])
    return out


# ---------------------------------------------------------------------------
# discriminator: planned strided convs (identity packing)
# ---------------------------------------------------------------------------

def discriminator_init(key, cfg: GANConfig, dtype=jnp.float32, dist=None):
    plans = discriminator_plans(cfg, dtype)
    layers = tuple(reversed(cfg.layers))
    ks = jax.random.split(key, len(layers) + 1)
    p, s = {}, {}
    for i, l in enumerate(layers):
        # mirror: out_c -> in_c, stride-2 downsample; stored superpacked
        # (R*S*C, N) like the generator deconvs — one shardable buffer
        kernel = jax.random.normal(
            ks[i], (l.kernel, l.kernel, l.out_c, l.in_c), dtype) * 0.02
        p[f"c{i}"] = plans[i].pack(kernel)
        s[f"c{i}"] = cm.spec("conv_taps", "conv_out")
    l_last = layers[-1]
    fdim = l_last.in_hw ** 2 * l_last.in_c
    p["head"] = jax.random.normal(ks[-1], (fdim, 1), dtype) * 0.02
    s["head"] = cm.spec("model", None)
    if dist is not None:
        p = dist.shard_params(p, s)
    return p, s


def discriminator_apply(p, x, cfg: GANConfig):
    plans = discriminator_plans(cfg, x.dtype)
    for i, plan in enumerate(plans):
        x = plan.apply(x, p[f"c{i}"])       # superpack or legacy HWIO kernel
        x = jax.nn.leaky_relu(x, 0.2)
    return x.reshape(x.shape[0], -1) @ p["head"]


def discriminator_unpack(p, cfg: GANConfig):
    """Packed discriminator params -> full (R,S,C,N) HWIO kernels."""
    plans = discriminator_plans(cfg)
    out = dict(p)
    for i, plan in enumerate(plans):
        out[f"c{i}"] = plan.unpack(p[f"c{i}"])
    return out


def gan_losses(gp, dp, z, real, cfg: GANConfig):
    """Non-saturating GAN loss pair."""
    fake = generator_apply(gp, z, cfg)
    d_fake = discriminator_apply(dp, fake, cfg)
    d_real = discriminator_apply(dp, real, cfg)
    d_loss = (jax.nn.softplus(-d_real) + jax.nn.softplus(d_fake)).mean()
    g_loss = jax.nn.softplus(-d_fake).mean()
    return g_loss, d_loss
