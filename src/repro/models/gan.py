"""DCGAN / cGAN (paper Table 1) built on the HUGE2 engine ops.

Generators stack the exact Table-1 transposed-conv layers; discriminators
mirror them with strided convs.  All convolutions run through
``huge_conv_transpose2d`` / ``huge_conv2d`` whose custom VJPs implement the
paper's §3.2.3 training formulation, so both inference *and* training
exercise the engine.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import huge_conv2d, huge_conv_transpose2d
from repro.layers import common as cm


@dataclasses.dataclass(frozen=True)
class DeconvLayer:
    in_hw: int
    in_c: int
    out_c: int
    kernel: int
    stride: int


# paper Table 1
DCGAN_LAYERS = (
    DeconvLayer(4, 1024, 512, 5, 2),
    DeconvLayer(8, 512, 256, 5, 2),
    DeconvLayer(16, 256, 128, 5, 2),
    DeconvLayer(32, 128, 3, 5, 2),
)
CGAN_LAYERS = (
    DeconvLayer(8, 256, 128, 4, 2),
    DeconvLayer(16, 128, 3, 4, 2),
)


def deconv_padding(kernel: int, stride: int):
    """'SAME'-style transposed padding: out = stride * in.

    out = (h-1)*s + pl + ph - k + 2 == s*h  =>  pl + ph = k + s - 2.
    """
    total = kernel + stride - 2
    pl = max(0, (kernel - stride + 1) // 2)
    ph = total - pl
    return ((pl, ph), (pl, ph))


@dataclasses.dataclass(frozen=True)
class GANConfig:
    name: str
    layers: tuple[DeconvLayer, ...]
    z_dim: int = 100
    backend: str = "xla"            # 'xla' | 'pallas'


DCGAN = GANConfig("dcgan", DCGAN_LAYERS)
CGAN = GANConfig("cgan", CGAN_LAYERS, z_dim=110)   # z + 10-class condition


def generator_init(key, cfg: GANConfig, dtype=jnp.float32):
    l0 = cfg.layers[0]
    ks = jax.random.split(key, len(cfg.layers) + 1)
    p = {"proj": jax.random.normal(
        ks[0], (cfg.z_dim, l0.in_hw * l0.in_hw * l0.in_c), dtype) * 0.02}
    s = {"proj": cm.spec(None, "model")}
    for i, l in enumerate(cfg.layers):
        p[f"dc{i}"] = jax.random.normal(
            ks[i + 1], (l.kernel, l.kernel, l.in_c, l.out_c), dtype) * 0.02
        p[f"b{i}"] = jnp.zeros((l.out_c,), dtype)
        s[f"dc{i}"] = cm.spec(None, None, None, "model")
        s[f"b{i}"] = cm.spec("model")
    return p, s


def generator_apply(p, z, cfg: GANConfig):
    l0 = cfg.layers[0]
    x = (z @ p["proj"]).reshape(z.shape[0], l0.in_hw, l0.in_hw, l0.in_c)
    x = jax.nn.relu(x)
    for i, l in enumerate(cfg.layers):
        pad = deconv_padding(l.kernel, l.stride)
        x = huge_conv_transpose2d(x, p[f"dc{i}"], (l.stride, l.stride), pad,
                                  cfg.backend)
        x = x + p[f"b{i}"]
        x = jnp.tanh(x) if i == len(cfg.layers) - 1 else jax.nn.relu(x)
    return x


def discriminator_init(key, cfg: GANConfig, dtype=jnp.float32):
    layers = tuple(reversed(cfg.layers))
    ks = jax.random.split(key, len(layers) + 1)
    p, s = {}, {}
    for i, l in enumerate(layers):
        # mirror: out_c -> in_c, stride-2 downsample
        p[f"c{i}"] = jax.random.normal(
            ks[i], (l.kernel, l.kernel, l.out_c, l.in_c), dtype) * 0.02
        s[f"c{i}"] = cm.spec(None, None, None, "model")
    l_last = layers[-1]
    fdim = l_last.in_hw ** 2 * l_last.in_c
    p["head"] = jax.random.normal(ks[-1], (fdim, 1), dtype) * 0.02
    s["head"] = cm.spec("model", None)
    return p, s


def discriminator_apply(p, x, cfg: GANConfig):
    layers = tuple(reversed(cfg.layers))
    for i, l in enumerate(layers):
        pad = ((l.kernel // 2, (l.kernel - 1) // 2),
               (l.kernel // 2, (l.kernel - 1) // 2))
        x = huge_conv2d(x, p[f"c{i}"], (l.stride, l.stride), pad, cfg.backend)
        x = jax.nn.leaky_relu(x, 0.2)
    return x.reshape(x.shape[0], -1) @ p["head"]


def gan_losses(gp, dp, z, real, cfg: GANConfig):
    """Non-saturating GAN loss pair."""
    fake = generator_apply(gp, z, cfg)
    d_fake = discriminator_apply(dp, fake, cfg)
    d_real = discriminator_apply(dp, real, cfg)
    d_loss = (jax.nn.softplus(-d_real) + jax.nn.softplus(d_fake)).mean()
    g_loss = jax.nn.softplus(-d_fake).mean()
    return g_loss, d_loss
