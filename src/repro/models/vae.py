"""Convolutional VAE on the HUGE² plan/executor engine (paper Fig. 1).

The abstract names GANs *and* VAEs as the upsampling-bound generative
workloads; this module makes the VAE an end-to-end resident of the engine:

- **encoder** — strided 'conv' sites (kernel 4, stride 2, the DCGAN-
  discriminator mirror) down to a small feature plane, then dense heads for
  ``mu`` / ``logvar``;
- **decoder** — the paper's Fig. 1 shape: a dense projection up to the
  feature plane followed by transposed-conv sites back to image resolution
  (the part HUGE² untangles — every deconv is phase-decomposed at plan
  time and executes as a single launch).

Every convolution site gets a ``ConvPlan`` built once at model load
(``vae_plans``) and every conv weight is stored **superpacked** — the
encoder's single-phase ``(R·S·C, N)`` flatten, the decoder's multi-phase
``(Σ T_h·T_w·C, N)`` concatenation — with logical sharding axes
``(conv_taps, conv_out)`` like ``models/gan.py`` / ``models/segnet.py``.
Training maximizes the ELBO with a Gaussian likelihood (MSE reconstruction
+ KL to the unit prior), differentiating **through the packed custom
VJPs** in both halves: the encoder backward runs the mirrored transposed-
tap schedule, the decoder backward the §3.2.3 strided/dilated forms,
directly on the superpacked layout.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.autotune import AutotunePolicy
from repro.core.plan import ConvPlan, ConvSpec, plan_conv
from repro.layers import common as cm
from repro.models.gan import DeconvLayer, deconv_padding


@dataclasses.dataclass(frozen=True)
class VAEConfig:
    name: str
    image_hw: int = 32
    in_c: int = 3
    widths: tuple[int, ...] = (64, 128)   # one stride-2 stage per width
    latent_dim: int = 64
    kernel: int = 4
    backend: str = "xla"            # plan policy: 'xla' | 'pallas' | 'auto'
    # measured-route policy (None = heuristic routes)
    autotune: Optional[AutotunePolicy] = None
    # plane-parallel policy: (D_h, D_w) requested device tiling per site
    # (see ``GANConfig.spatial``); single-device fallback is always kept
    spatial: tuple[int, int] = (1, 1)
    # weight storage dtype for every conv site: 'float32' (dense) or 'int8'
    # (quantized superpacks — ``ConvSpec.wdtype``); activations stay f32
    wdtype: str = "float32"

    @property
    def feat_hw(self) -> int:
        return self.image_hw // (2 ** len(self.widths))

    @property
    def feat_c(self) -> int:
        return self.widths[-1]

    @property
    def encoder_layers(self) -> tuple[DeconvLayer, ...]:
        """Strided 'conv' stages, image -> feature plane (in_hw is the
        stage's input resolution; reusing DeconvLayer keeps one layer
        record across all engine model zoos)."""
        chans = (self.in_c,) + self.widths
        return tuple(
            DeconvLayer(self.image_hw // 2 ** i, chans[i], chans[i + 1],
                        self.kernel, 2)
            for i in range(len(self.widths)))

    @property
    def decoder_layers(self) -> tuple[DeconvLayer, ...]:
        """Transposed stages, feature plane -> image (the Fig. 1 decoder) —
        the exact mirror of the encoder."""
        chans = (self.in_c,) + self.widths
        return tuple(
            DeconvLayer(self.image_hw // 2 ** (i + 1), chans[i + 1], chans[i],
                        self.kernel, 2)
            for i in reversed(range(len(self.widths))))


VAE = VAEConfig("vae")                                       # 32px CIFAR-ish
VAE_TINY = VAEConfig("vae-tiny", image_hw=16, widths=(16, 32), latent_dim=8)


# ---------------------------------------------------------------------------
# load-time planning: one ConvPlan per site, both halves
# ---------------------------------------------------------------------------

def encoder_plans(cfg: VAEConfig, dtype=jnp.float32) -> tuple[ConvPlan, ...]:
    plans = []
    for l in cfg.encoder_layers:
        k = l.kernel
        plans.append(plan_conv(ConvSpec(
            kind="conv", in_hw=(l.in_hw, l.in_hw), in_c=l.in_c,
            out_c=l.out_c, kernel_hw=(k, k), strides=(l.stride, l.stride),
            padding=((k // 2, (k - 1) // 2), (k // 2, (k - 1) // 2)),
            dtype=str(jnp.dtype(dtype)), backend=cfg.backend,
            spatial=cfg.spatial, wdtype=cfg.wdtype),
            autotune=cfg.autotune))
    return tuple(plans)


def decoder_plans(cfg: VAEConfig, dtype=jnp.float32) -> tuple[ConvPlan, ...]:
    plans = []
    for l in cfg.decoder_layers:
        plans.append(plan_conv(ConvSpec(
            kind="transposed", in_hw=(l.in_hw, l.in_hw), in_c=l.in_c,
            out_c=l.out_c, kernel_hw=(l.kernel, l.kernel),
            strides=(l.stride, l.stride),
            padding=deconv_padding(l.kernel, l.stride),
            dtype=str(jnp.dtype(dtype)), backend=cfg.backend,
            spatial=cfg.spatial, wdtype=cfg.wdtype),
            autotune=cfg.autotune))
    return tuple(plans)


def vae_plans(cfg: VAEConfig, dtype=jnp.float32):
    return encoder_plans(cfg, dtype) + decoder_plans(cfg, dtype)


# ---------------------------------------------------------------------------
# params: every conv weight superpacked, dense heads for the latent
# ---------------------------------------------------------------------------

def vae_init(key, cfg: VAEConfig, dtype=jnp.float32, dist=None):
    """Superpacked params + logical specs; pass a ``DistContext`` to get
    the tree placed on its mesh (out-channels sharded by default)."""
    enc, dec = encoder_plans(cfg, dtype), decoder_plans(cfg, dtype)
    n_keys = len(enc) + len(dec) + 4
    ks = iter(jax.random.split(key, n_keys))
    p, s = {}, {}
    for i, (l, plan) in enumerate(zip(cfg.encoder_layers, enc)):
        fan_in = l.kernel * l.kernel * l.in_c
        kernel = jax.random.normal(
            next(ks), (l.kernel, l.kernel, l.in_c, l.out_c),
            dtype) * (2.0 / fan_in) ** 0.5
        p[f"enc{i}"] = plan.pack(kernel)
        p[f"encb{i}"] = jnp.zeros((l.out_c,), dtype)
        s[f"enc{i}"] = cm.spec("conv_taps", "conv_out")
        s[f"encb{i}"] = cm.spec("conv_out")
    fdim = cfg.feat_hw * cfg.feat_hw * cfg.feat_c
    for head in ("mu", "lv"):
        p[f"{head}_w"] = jax.random.normal(
            next(ks), (fdim, cfg.latent_dim), dtype) * fdim ** -0.5
        p[f"{head}_b"] = jnp.zeros((cfg.latent_dim,), dtype)
        s[f"{head}_w"] = cm.spec(None, None)
        s[f"{head}_b"] = cm.spec(None)
    p["proj"] = jax.random.normal(
        next(ks), (cfg.latent_dim, fdim), dtype) * cfg.latent_dim ** -0.5
    p["projb"] = jnp.zeros((fdim,), dtype)
    s["proj"] = cm.spec(None, "conv_out")
    s["projb"] = cm.spec("conv_out")
    for i, (l, plan) in enumerate(zip(cfg.decoder_layers, dec)):
        kernel = jax.random.normal(
            next(ks), (l.kernel, l.kernel, l.in_c, l.out_c), dtype) * 0.02
        p[f"dec{i}"] = plan.pack(kernel)
        p[f"decb{i}"] = jnp.zeros((l.out_c,), dtype)
        s[f"dec{i}"] = cm.spec("conv_taps", "conv_out")
        s[f"decb{i}"] = cm.spec("conv_out")
    if dist is not None:
        p = dist.shard_params(p, s)
    return p, s


# ---------------------------------------------------------------------------
# apply: planned execution on the superpacks, end to end
# ---------------------------------------------------------------------------

def encode(p, x, cfg: VAEConfig):
    """x (B, H, W, C) -> (mu, logvar), each (B, latent_dim)."""
    plans = encoder_plans(cfg, x.dtype)        # cache hits after model load
    for i, plan in enumerate(plans):
        x = jax.nn.relu(plan.apply(x, p[f"enc{i}"]) + p[f"encb{i}"])
    h = x.reshape(x.shape[0], -1)
    return h @ p["mu_w"] + p["mu_b"], h @ p["lv_w"] + p["lv_b"]


def decode(p, z, cfg: VAEConfig):
    """z (B, latent_dim) -> recon (B, H, W, C) — the Fig. 1 decoder, every
    transposed conv one planned launch on its superpack."""
    plans = decoder_plans(cfg, z.dtype)
    h = jax.nn.relu(z @ p["proj"] + p["projb"])
    x = h.reshape(z.shape[0], cfg.feat_hw, cfg.feat_hw, cfg.feat_c)
    for i, plan in enumerate(plans):
        x = plan.apply(x, p[f"dec{i}"]) + p[f"decb{i}"]
        x = jnp.tanh(x) if i == len(plans) - 1 else jax.nn.relu(x)
    return x


def reparameterize(key, mu, logvar):
    return mu + jnp.exp(0.5 * logvar) * jax.random.normal(
        key, mu.shape, mu.dtype)


def vae_apply(p, x, key, cfg: VAEConfig):
    mu, logvar = encode(p, x, cfg)
    z = reparameterize(key, mu, logvar)
    return decode(p, z, cfg), mu, logvar


def elbo_loss(p, x, key, cfg: VAEConfig, beta: float = 1.0):
    """Negative ELBO: Gaussian reconstruction (MSE, unit variance) + KL to
    the unit prior, both per-image sums averaged over the batch.  Every
    gradient flows through the packed custom VJPs of both halves."""
    recon, mu, logvar = vae_apply(p, x, key, cfg)
    se = jnp.square(recon - x).sum(axis=(1, 2, 3))
    kl = -0.5 * (1.0 + logvar - jnp.square(mu)
                 - jnp.exp(logvar)).sum(axis=-1)
    return (se + beta * kl).mean()


def sample(p, key, cfg: VAEConfig, n: int = 16):
    """Decode n draws from the prior (generation path == serving path)."""
    z = jax.random.normal(key, (n, cfg.latent_dim))
    return decode(p, z, cfg)
