"""Latent-diffusion-style U-Net on the HUGE² plan/executor engine.

The ROADMAP's last open model-zoo item and *the* upsampling-heavy
production workload: a strided 'conv' encoder, a dilated bottleneck, a
transposed decoder, and skip concatenations — every convolution kind the
engine plans, in one forward pass.  Each site gets a ``ConvPlan`` built
once at model load (``unet_plans``) and every conv weight is stored
**superpacked** (``wdtype='int8'`` flips all of them to quantized
superpacks), with logical sharding axes ``(conv_taps, conv_out)`` like the
rest of the zoo.  Training differentiates **through the packed custom
VJPs** on all three kinds, and the skip concatenations split their
cotangents into the decoder and encoder halves through those same VJPs.

The decoder's transposed sites use ``up_kernel % stride == 0`` ('SAME'
``deconv_padding``) geometry on purpose: every phase shares its tap
footprint and pad, so the sites are eligible for the engine's
'pixel_shuffle' (sub-pixel convolution) route — one dense stride-1 conv +
depth-to-space per upsample instead of a phase-interleaved launch (the
geometry-dependent transposed-vs-sub-pixel tradeoff of arXiv:2107.07647,
decided per (site, bucket) by the route heuristic or the autotuner).

Denoising: ``unet_apply(p, x_t, t, cfg)`` predicts the noise ``eps`` given
the corrupted image and a timestep in ``[0, 1]`` (sinusoidal embedding +
one per-level projection).  ``unet_loss`` is the standard denoising score
matching MSE under a cosine ``alpha_bar``; ``denoise_loop`` runs the
sequential Euler refinement the serving bench drives through the control
plane (many decoder calls per request).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.autotune import AutotunePolicy
from repro.core.plan import ConvPlan, ConvSpec, plan_conv
from repro.layers import common as cm
from repro.models.gan import deconv_padding
from repro.models.segnet import atrous_padding


@dataclasses.dataclass(frozen=True)
class UNetConfig:
    name: str
    image_hw: int = 32
    in_c: int = 3
    base: int = 32                  # encoder widths: base · 2^level
    depth: int = 2                  # stride-2 down/up stages
    mid_dilations: tuple[int, ...] = (1, 2)   # bottleneck 'dilated' sites
    kernel: int = 3                 # stem / down / fuse / head kernel
    up_kernel: int = 4              # transposed up kernel; % stride == 0
    time_dim: int = 64              # sinusoidal timestep embedding width
    backend: str = "xla"            # plan policy: 'xla' | 'pallas' | 'auto'
    autotune: Optional[AutotunePolicy] = None
    spatial: tuple[int, int] = (1, 1)
    wdtype: str = "float32"         # 'float32' | 'int8' superpacks

    def width(self, level: int) -> int:
        return self.base * (2 ** level)

    def hw(self, level: int) -> int:
        return self.image_hw // (2 ** level)


UNET = UNetConfig("unet")                                    # 32px latents
UNET_TINY = UNetConfig("unet-tiny", image_hw=16, base=8, time_dim=16)


# ---------------------------------------------------------------------------
# sites: every conv in forward order, as (name, ConvSpec)
# ---------------------------------------------------------------------------

def unet_sites(cfg: UNetConfig,
               dtype="float32") -> tuple[tuple[str, ConvSpec], ...]:
    """(name, ConvSpec) for every conv site, forward order.  One list
    drives planning, init, apply, the golden route table, and the route
    property tests — the site set cannot drift between them."""
    k = cfg.kernel
    same = ((k // 2, (k - 1) // 2), (k // 2, (k - 1) // 2))

    def spec(kind, hw, c_in, c_out, kernel, stride=1, dilation=1,
             padding=None):
        return ConvSpec(
            kind=kind, in_hw=(hw, hw), in_c=c_in, out_c=c_out,
            kernel_hw=(kernel, kernel), strides=(stride, stride),
            padding=padding if padding is not None else same,
            dilation=(dilation, dilation), dtype=str(jnp.dtype(dtype)),
            backend=cfg.backend, spatial=cfg.spatial, wdtype=cfg.wdtype)

    sites = [("stem", spec("conv", cfg.image_hw, cfg.in_c, cfg.base, k))]
    for i in range(cfg.depth):
        sites.append((f"down{i}", spec(
            "conv", cfg.hw(i), cfg.width(i), cfg.width(i + 1), k, stride=2)))
    for j, d in enumerate(cfg.mid_dilations):
        sites.append((f"mid{j}", spec(
            "dilated", cfg.hw(cfg.depth), cfg.width(cfg.depth),
            cfg.width(cfg.depth), k, dilation=d,
            padding=atrous_padding(k, d))))
    for i in reversed(range(cfg.depth)):
        sites.append((f"up{i}", spec(
            "transposed", cfg.hw(i + 1), cfg.width(i + 1), cfg.width(i),
            cfg.up_kernel, stride=2,
            padding=deconv_padding(cfg.up_kernel, 2))))
        sites.append((f"fuse{i}", spec(
            "conv", cfg.hw(i), 2 * cfg.width(i), cfg.width(i), k)))
    sites.append(("head", spec("conv", cfg.image_hw, cfg.base, cfg.in_c, k)))
    return tuple(sites)


def unet_plans(cfg: UNetConfig, dtype=jnp.float32) -> dict[str, ConvPlan]:
    return {name: plan_conv(s, autotune=cfg.autotune)
            for name, s in unet_sites(cfg, str(jnp.dtype(dtype)))}


def unet_route_summary(cfg: UNetConfig, batch: int = 1,
                       dtype=jnp.float32) -> dict[str, tuple[str, str]]:
    """{site: (conv kind, route path at ``batch``)} — plan inspection for
    the 'one pass runs every kind' assertion and the bench's route
    report."""
    return {name: (plan.spec.kind, plan.route_for_batch(batch).path)
            for name, plan in unet_plans(cfg, dtype).items()}


# ---------------------------------------------------------------------------
# params: superpacked conv weights + timestep-embedding projections
# ---------------------------------------------------------------------------

def unet_init(key, cfg: UNetConfig, dtype=jnp.float32, dist=None):
    """Superpacked params + logical specs; He init for the correlation
    sites, the zoo's 0.02 normal for the transposed ups.  Pass a
    ``DistContext`` to get the tree placed on its mesh."""
    plans = unet_plans(cfg, dtype)
    sites = unet_sites(cfg, str(jnp.dtype(dtype)))
    ks = iter(jax.random.split(key, len(sites) + cfg.depth + 2))
    p, s = {}, {}
    for name, spec in sites:
        r, c, n = spec.kernel_hw[0], spec.in_c, spec.out_c
        scale = 0.02 if spec.kind == "transposed" \
            else (2.0 / (r * r * c)) ** 0.5
        kernel = jax.random.normal(next(ks), (r, r, c, n), dtype) * scale
        p[name] = plans[name].pack(kernel)
        p[f"{name}_b"] = jnp.zeros((n,), dtype)
        s[name] = cm.spec("conv_taps", "conv_out")
        s[f"{name}_b"] = cm.spec("conv_out")
    # timestep MLP + one projection per encoder level (applied after each
    # down, and after the first bottleneck site at the deepest level)
    p["temb_w"] = jax.random.normal(
        next(ks), (cfg.time_dim, cfg.time_dim), dtype) * cfg.time_dim ** -0.5
    p["temb_b"] = jnp.zeros((cfg.time_dim,), dtype)
    s["temb_w"] = cm.spec(None, None)
    s["temb_b"] = cm.spec(None)
    for i in range(cfg.depth + 1):
        # tproj{i} is added right after down{i} (channels width(i+1)); the
        # last one conditions the bottleneck entry at width(depth)
        n = cfg.width(min(i + 1, cfg.depth))
        p[f"tproj{i}"] = jax.random.normal(
            next(ks), (cfg.time_dim, n), dtype) * cfg.time_dim ** -0.5
        s[f"tproj{i}"] = cm.spec(None, "conv_out")
    if dist is not None:
        p = dist.shard_params(p, s)
    return p, s


# ---------------------------------------------------------------------------
# apply: planned execution on the superpacks, end to end
# ---------------------------------------------------------------------------

def time_embedding(t, dim: int):
    """Sinusoidal embedding of ``t`` in [0, 1] -> (B, dim)."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10000.0)
                    * jnp.arange(half, dtype=t.dtype) / max(1, half - 1))
    ang = (t * 1000.0)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def unet_apply(p, x, t, cfg: UNetConfig):
    """(x_t (B,H,W,C), t (B,) in [0,1]) -> predicted noise eps (B,H,W,C).

    Encoder activations are kept as skips and concatenated after each
    transposed up; the fuse conv contracts the doubled channels, so the
    concat's cotangent splits into both halves through the packed VJPs."""
    plans = unet_plans(cfg, x.dtype)           # cache hits after model load

    def conv(name, h):
        return plans[name].apply(h, p[name]) + p[f"{name}_b"]

    emb = jax.nn.silu(
        time_embedding(t.astype(x.dtype), cfg.time_dim)
        @ p["temb_w"] + p["temb_b"])

    h = jax.nn.relu(conv("stem", x))
    skips = []
    for i in range(cfg.depth):
        skips.append(h)
        h = conv(f"down{i}", h) + (emb @ p[f"tproj{i}"])[:, None, None, :]
        h = jax.nn.relu(h)
    h = h + (emb @ p[f"tproj{cfg.depth}"])[:, None, None, :]
    for j in range(len(cfg.mid_dilations)):
        h = jax.nn.relu(conv(f"mid{j}", h))
    for i in reversed(range(cfg.depth)):
        h = jax.nn.relu(conv(f"up{i}", h))
        h = jnp.concatenate([h, skips[i]], axis=-1)
        h = jax.nn.relu(conv(f"fuse{i}", h))
    return conv("head", h)


# ---------------------------------------------------------------------------
# denoising: cosine schedule, DSM loss, sequential refinement loop
# ---------------------------------------------------------------------------

def alpha_bar(t):
    """Cosine noise schedule (Nichol & Dhariwal): abar(t), t in [0, 1]."""
    return jnp.cos((t + 0.008) / 1.008 * jnp.pi / 2) ** 2


def unet_loss(p, x0, key, cfg: UNetConfig):
    """Denoising score matching: corrupt x0 at a uniform timestep, predict
    the noise, MSE.  Every gradient flows through the packed VJPs of all
    three conv kinds and both sides of every skip concat."""
    kt, kn = jax.random.split(key)
    b = x0.shape[0]
    t = jax.random.uniform(kt, (b,), x0.dtype)
    ab = alpha_bar(t)[:, None, None, None]
    noise = jax.random.normal(kn, x0.shape, x0.dtype)
    x_t = jnp.sqrt(ab) * x0 + jnp.sqrt(1.0 - ab) * noise
    eps = unet_apply(p, x_t, t, cfg)
    return jnp.mean(jnp.square(eps - noise))


def denoise_step(p, x_t, t_frac, cfg: UNetConfig, dt: float):
    """One refinement step: predict eps at ``t_frac`` (B,) and take an
    Euler step of size ``dt`` toward t=0.  The serving bench wraps this as
    its backend fn — each step is its own request, so one step == one
    bucket-batched pass through every planned site."""
    eps = unet_apply(p, x_t, t_frac, cfg)
    return x_t - eps * dt


def denoise_loop(p, x_t, cfg: UNetConfig, steps: int):
    """Sequential Euler refinement, ``steps`` planned decoder calls."""
    for s in reversed(range(steps)):
        tf = jnp.full((x_t.shape[0],), (s + 1) / steps, x_t.dtype)
        eps = unet_apply(p, x_t, tf, cfg)
        x_t = x_t - eps / steps
    return x_t


def sample(p, key, cfg: UNetConfig, n: int = 4, steps: int = 8):
    """Draw from the prior and refine — the serving path's closed form."""
    x_t = jax.random.normal(
        key, (n, cfg.image_hw, cfg.image_hw, cfg.in_c), jnp.float32)
    return denoise_loop(p, x_t, cfg, steps)
