"""Composable decoder (and encoder-decoder) assembly over stage-scanned blocks.

Layer kinds: attn|local|global|moe|mla|mla_moe|ssd|rec|enc|dec — see
configs.base.  Parameters of a stage are stacked (leading repeat dim) and the
stage executes as ``lax.scan`` with per-block remat.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.layers import attention as attn
from repro.layers import common as cm
from repro.layers import mlp as mlp_lib
from repro.layers import moe as moe_lib
from repro.layers import rglru as rglru_lib
from repro.layers import ssm as ssm_lib
from repro.sharding import stack_specs

# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------


def _norm_init(cfg):
    return cm.rmsnorm_init(cfg.d_model)


def init_layer(key, kind: str, cfg):
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["ln1"], s["ln1"] = _norm_init(cfg)
    if kind in ("attn", "local", "global", "moe", "enc", "dec"):
        p["attn"], s["attn"] = attn.gqa_init(ks[0], cfg)
    elif kind in ("mla", "mla_moe"):
        p["attn"], s["attn"] = attn.mla_init(ks[0], cfg)
    elif kind == "ssd":
        p["ssd"], s["ssd"] = ssm_lib.ssd_init(ks[0], cfg)
        if cfg.sandwich_norm:
            p["pn1"], s["pn1"] = _norm_init(cfg)
        return p, s                                    # mixer-only block
    elif kind == "rec":
        p["rec"], s["rec"] = rglru_lib.rglru_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    if kind == "dec":
        p["lnx"], s["lnx"] = _norm_init(cfg)
        p["cross"], s["cross"] = attn.cross_init(ks[1], cfg)
    p["ln2"], s["ln2"] = _norm_init(cfg)
    if kind in ("moe", "mla_moe"):
        p["moe"], s["moe"] = moe_lib.moe_init(ks[2], cfg)
        if cfg.n_shared:
            p["shared"], s["shared"] = mlp_lib.glu_init(
                ks[3], cfg.d_model, cfg.d_expert * cfg.n_shared)
    else:
        p["mlp"], s["mlp"] = mlp_lib.glu_init(ks[2], cfg.d_model, cfg.d_ff)
    if cfg.sandwich_norm:
        p["pn1"], s["pn1"] = _norm_init(cfg)
        p["pn2"], s["pn2"] = _norm_init(cfg)
    return p, s


def init_block(key, kinds, cfg):
    p, s = {}, {}
    for i, kind in enumerate(kinds):
        key, sub = jax.random.split(key)
        p[f"l{i}"], s[f"l{i}"] = init_layer(sub, kind, cfg)
    return p, s


# ---------------------------------------------------------------------------
# per-layer apply (train / prefill)
# ---------------------------------------------------------------------------


def _rms(p, x, cfg):
    return cm.rmsnorm_apply(p, x, cfg.norm_eps, gemma_style=cfg.gemma_norm)


def apply_layer(p, x, kind, cfg, dist, *, positions, memory=None,
                kv_chunk=1024):
    if kind == "ssd":
        h = ssm_lib.ssd_apply(p["ssd"], _rms(p["ln1"], x, cfg), cfg)
        if cfg.sandwich_norm:
            h = _rms(p["pn1"], h, cfg)
        return x + h
    # mixer sublayer
    h = _rms(p["ln1"], x, cfg)
    if kind in ("mla", "mla_moe"):
        h = attn.mla_apply(p["attn"], h, cfg, positions=positions,
                           kv_chunk=kv_chunk)
    elif kind == "rec":
        h = rglru_lib.rglru_apply(p["rec"], h, cfg)
    elif kind == "enc":
        h = attn.gqa_apply(p["attn"], h, cfg, positions=positions,
                           layer_kind="global", kv_chunk=kv_chunk,
                           causal=False)
    else:
        lk = "local" if kind == "local" else "global"
        h = attn.gqa_apply(p["attn"], h, cfg, positions=positions,
                           layer_kind=lk, kv_chunk=kv_chunk)
    if cfg.sandwich_norm:
        h = _rms(p["pn1"], h, cfg)
    x = x + h
    if kind == "dec":
        h = attn.cross_apply(p["cross"], _rms(p["lnx"], x, cfg), memory, cfg,
                             kv_chunk=kv_chunk)
        x = x + h
    # ffn sublayer
    h = _rms(p["ln2"], x, cfg)
    if kind in ("moe", "mla_moe"):
        y = moe_lib.moe_apply(p["moe"], h, cfg, dist)
        if cfg.n_shared:
            y = y + mlp_lib.glu_apply(p["shared"], h, cfg.act)
        h = y
    else:
        h = mlp_lib.glu_apply(p["mlp"], h, cfg.act)
    if cfg.sandwich_norm:
        h = _rms(p["pn2"], h, cfg)
    return x + h


def _enc_causal_fix(kind):
    return kind  # placeholder for readability


def apply_block(bp, x, kinds, cfg, dist, *, positions, memory=None,
                kv_chunk=1024):
    for i, kind in enumerate(kinds):
        x = apply_layer(bp[f"l{i}"], x, kind, cfg, dist, positions=positions,
                        memory=memory, kv_chunk=kv_chunk)
        if dist is not None:
            x = dist.constrain(x)
    return x


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------


def block_specs(kinds, cfg):
    """Specs for one block, computed abstractly (no arrays allocated)."""
    cell = {}

    def f(k):
        p, s = init_block(k, kinds, cfg)
        cell["s"] = s
        return p

    jax.eval_shape(f, jax.random.PRNGKey(0))
    return cell["s"]


def init(key, cfg, dtype=jnp.bfloat16):
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {}
    specs: dict[str, Any] = {}
    params["embed"], specs["embed"] = cm.embed_init(keys[0], cfg.padded_vocab,
                                                    cfg.d_model, dtype)
    stages_p, stages_s = [], []
    for si, (kinds, reps) in enumerate(cfg.stages):
        skey = jax.random.fold_in(keys[1], si)
        bp = jax.vmap(lambda k: init_block(k, kinds, cfg)[0])(
            jax.random.split(skey, reps))
        stages_p.append(bp)
        stages_s.append(stack_specs(block_specs(kinds, cfg)))
    params["stages"], specs["stages"] = stages_p, stages_s
    if cfg.is_encoder_decoder:
        enc_p, enc_s = [], []
        for si, (kinds, reps) in enumerate(cfg.encoder_stages):
            skey = jax.random.fold_in(keys[2], si)
            bp = jax.vmap(lambda k: init_block(k, kinds, cfg)[0])(
                jax.random.split(skey, reps))
            enc_p.append(bp)
            enc_s.append(stack_specs(block_specs(kinds, cfg)))
        params["enc_stages"], specs["enc_stages"] = enc_p, enc_s
        params["enc_norm"], specs["enc_norm"] = _norm_init(cfg)
    params["final_norm"], specs["final_norm"] = _norm_init(cfg)
    if not cfg.tie_embeddings:
        params["head"], specs["head"] = cm.dense_init(
            keys[3], cfg.d_model, cfg.padded_vocab, None, "vocab", dtype)
    return params, specs


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def _embed_in(params, batch, cfg, dist):
    if cfg.frontend != "none" and "embeds" in batch:
        x = batch["embeds"]
    else:
        x = cm.embed_apply(params["embed"], batch["inputs"])
    if cfg.gemma_norm:
        x = (x.astype(jnp.float32) * (cfg.d_model ** 0.5)).astype(x.dtype)
    if dist is not None:
        x = dist.constrain(x)
    return x


def _positions_for(cfg, b, s):
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.mrope_sections:
        return jnp.broadcast_to(pos[None], (3, b, s))
    return pos


def _run_stages(params_stages, stage_defs, x, cfg, dist, *, positions,
                memory=None, kv_chunk=1024, remat=True):
    for sp, (kinds, reps) in zip(params_stages, stage_defs):
        def body(carry, bp, kinds=kinds):
            y = apply_block(bp, carry, kinds, cfg, dist, positions=positions,
                            memory=memory, kv_chunk=kv_chunk)
            return y, None
        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, sp)
    return x


def encode(params, src_embeds, cfg, dist, kv_chunk=1024):
    x = src_embeds
    if cfg.gemma_norm:
        x = (x.astype(jnp.float32) * (cfg.d_model ** 0.5)).astype(x.dtype)
    pos = _positions_for(cfg, x.shape[0], x.shape[1])
    x = _run_stages(params["enc_stages"], cfg.encoder_stages, x, cfg, dist,
                    positions=pos, kv_chunk=kv_chunk)
    return cm.rmsnorm_apply(params["enc_norm"], x, cfg.norm_eps)


def forward(params, batch, cfg, dist=None, *, kv_chunk=1024, remat=True):
    """Teacher-forced logits: (B, S, V) float32."""
    x = _embed_in(params, batch, cfg, dist)
    b, s = x.shape[0], x.shape[1]
    positions = _positions_for(cfg, b, s)
    memory = None
    if cfg.is_encoder_decoder:
        memory = encode(params, batch["src_embeds"], cfg, dist, kv_chunk)
    x = _run_stages(params["stages"], cfg.stages, x, cfg, dist,
                    positions=positions, memory=memory, kv_chunk=kv_chunk,
                    remat=remat)
    x = cm.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps,
                         gemma_style=cfg.gemma_norm)
    logits = _readout(params, x, cfg)
    if dist is not None:
        logits = dist.constrain(logits, P(dist.rules["batch"], None, "vocab"))
    return logits


def _readout(params, x, cfg):
    """LM head over the padded vocab; padding columns masked to -inf."""
    if cfg.tie_embeddings:
        logits = cm.embed_logits(params["embed"], x)
    else:
        logits = cm.dense_apply(params["head"], x).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    return logits


def loss_fn(params, batch, cfg, dist=None, *, kv_chunk=1024, remat=True):
    logits = forward(params, batch, cfg, dist, kv_chunk=kv_chunk, remat=remat)
    tgt = batch["targets"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    mask = (tgt >= 0).astype(jnp.float32)
    loss = ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------


def init_cache_layer(kind, cfg, batch, max_len, dtype=jnp.bfloat16):
    kh, dh = cfg.num_kv_heads, cfg.head_dim
    if kind in ("attn", "local", "global", "moe", "dec"):
        c = {"k": jnp.zeros((batch, max_len, kh, dh), dtype),
             "v": jnp.zeros((batch, max_len, kh, dh), dtype)}
        s = {"k": cm.spec("batch", "kv_seq", "kv_heads", None),
             "v": cm.spec("batch", "kv_seq", "kv_heads", None)}
        return c, s
    if kind in ("mla", "mla_moe"):
        c = {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
             "kr": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype)}
        s = {"ckv": cm.spec("batch", "kv_seq", None),
             "kr": cm.spec("batch", "kv_seq", None)}
        return c, s
    if kind == "ssd":
        di, h, n = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state
        conv_dim = di + 2 * cfg.ssm_groups * n
        c = {"h": jnp.zeros((batch, h, n, di // h), jnp.float32),
             "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype)}
        s = {"h": cm.spec("batch", "heads", None, None),
             "conv": cm.spec("batch", None, "heads")}
        return c, s
    if kind == "rec":
        c = {"h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
             "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width),
                               dtype)}
        s = {"h": cm.spec("batch", "heads"),
             "conv": cm.spec("batch", None, "heads")}
        return c, s
    raise ValueError(kind)


def init_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    stages_c, stages_s = [], []
    for kinds, reps in cfg.stages:
        bc, bs = {}, {}
        for i, kind in enumerate(kinds):
            c, s = init_cache_layer(kind, cfg, batch, max_len, dtype)
            bc[f"l{i}"], bs[f"l{i}"] = c, s
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (reps,) + a.shape), bc)
        stages_c.append(stacked)
        stages_s.append(stack_specs(bs))
    return stages_c, stages_s


def cache_specs_only(cfg):
    """Logical sharding specs for the decode cache (no arrays built)."""
    stages_s = []
    for kinds, reps in cfg.stages:
        bs = {}
        for i, kind in enumerate(kinds):
            cell = {}

            def f(kind=kind, cell=cell):
                c, s = init_cache_layer(kind, cfg, 1, 1)
                cell["s"] = s
                return c

            jax.eval_shape(f)
            bs[f"l{i}"] = cell["s"]
        stages_s.append(stack_specs(bs))
    return stages_s


def decode_layer(p, x, kind, cfg, cache, idx, memory=None, dist=None):
    if kind == "ssd":
        h, nc = ssm_lib.ssd_decode(p["ssd"], _rms(p["ln1"], x, cfg), cache, cfg)
        if cfg.sandwich_norm:
            h = _rms(p["pn1"], h, cfg)
        return x + h, nc
    h = _rms(p["ln1"], x, cfg)
    if kind in ("mla", "mla_moe"):
        h, nc = attn.mla_decode(p["attn"], h, cache, idx, cfg)
    elif kind == "rec":
        h, nc = rglru_lib.rglru_decode(p["rec"], h, cache, cfg)
    else:
        lk = "local" if kind == "local" else "global"
        h, nc = attn.gqa_decode(p["attn"], h, cache, idx, cfg, layer_kind=lk)
    if cfg.sandwich_norm:
        h = _rms(p["pn1"], h, cfg)
    x = x + h
    if kind == "dec":
        h = attn.cross_apply(p["cross"], _rms(p["lnx"], x, cfg), memory, cfg)
        x = x + h
    h = _rms(p["ln2"], x, cfg)
    if kind in ("moe", "mla_moe"):
        y = moe_lib.moe_apply(p["moe"], h, cfg, dist)
        if cfg.n_shared:
            y = y + mlp_lib.glu_apply(p["shared"], h, cfg.act)
        h = y
    else:
        h = mlp_lib.glu_apply(p["mlp"], h, cfg.act)
    if cfg.sandwich_norm:
        h = _rms(p["pn2"], h, cfg)
    return x + h, nc


def decode_step(params, cache_stages, tokens, idx, cfg, dist=None,
                memory=None):
    """One decode step. tokens: (B, 1) int32 (or embeds for stub frontends).

    Returns (logits (B, 1, V), new_cache_stages).
    """
    if cfg.frontend != "none" and tokens.ndim == 3:
        x = tokens
    else:
        x = cm.embed_apply(params["embed"], tokens)
    if cfg.gemma_norm:
        x = (x.astype(jnp.float32) * (cfg.d_model ** 0.5)).astype(x.dtype)
    if dist is not None:
        x = dist.constrain(x)
    new_stages = []
    for sp, sc, (kinds, reps) in zip(params["stages"], cache_stages,
                                     cfg.stages):
        def body(carry, xs, kinds=kinds):
            bp, bc = xs
            y = carry
            ncs = {}
            for i, kind in enumerate(kinds):
                y, nc = decode_layer(bp[f"l{i}"], y, kind, cfg, bc[f"l{i}"],
                                     idx, memory=memory, dist=dist)
                ncs[f"l{i}"] = nc
            return y, ncs
        x, new_cache = jax.lax.scan(body, x, (sp, sc))
        new_stages.append(new_cache)
    x = cm.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps,
                         gemma_style=cfg.gemma_norm)
    return _readout(params, x, cfg), new_stages
