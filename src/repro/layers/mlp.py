"""Dense FFNs: gated (SwiGLU/GeGLU) and plain, TP-sharded on the hidden dim."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers import common as cm


def glu_init(key, d_model, d_ff, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["wi"], s["wi"] = cm.dense_init(ks[0], d_model, d_ff, None, "ffn", dtype)
    p["wg"], s["wg"] = cm.dense_init(ks[1], d_model, d_ff, None, "ffn", dtype)
    p["wo"], s["wo"] = cm.dense_init(ks[2], d_ff, d_model, "ffn", None, dtype)
    return p, s


def glu_apply(p, x, act="silu"):
    a = cm.ACTS[act](cm.dense_apply(p["wg"], x).astype(jnp.float32))
    h = a * cm.dense_apply(p["wi"], x).astype(jnp.float32)
    return cm.dense_apply(p["wo"], h.astype(x.dtype))


def mlp_init(key, d_model, d_ff, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 2)
    p, s = {}, {}
    p["wi"], s["wi"] = cm.dense_init(ks[0], d_model, d_ff, None, "ffn", dtype)
    p["wo"], s["wo"] = cm.dense_init(ks[1], d_ff, d_model, "ffn", None, dtype)
    return p, s


def mlp_apply(p, x, act="gelu"):
    h = cm.ACTS[act](cm.dense_apply(p["wi"], x).astype(jnp.float32))
    return cm.dense_apply(p["wo"], h.astype(x.dtype))
