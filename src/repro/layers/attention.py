"""Attention family: MHA/GQA (+sliding window, qk-norm, bias), cross-attn,
and DeepSeek MLA (compressed-KV) — all with a KV-chunked flash path so the
full-scale configs lower without materializing (S x S) logits.

Layout: activations (B, S, D); q/k/v (B, S, H, Dh).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.layers import common as cm
from repro.layers import rope as rp

NEG_INF = -2.0 ** 30


# ---------------------------------------------------------------------------
# flash-style attention core (KV-chunk scan, online softmax)
# ---------------------------------------------------------------------------

def _chunk_attend(q, k, v, qpos, kpos, causal, window, scale):
    """One KV chunk. q:(B,Sq,H,D) k/v:(B,Sk,Kh,D) -> partial (acc, m, l).

    bf16 operands with f32 MXU accumulation (flash-standard): halves the
    score/PV dot traffic vs upcasting inputs (§Perf P1 iteration 3)."""
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qr = q.reshape(b, sq, kh, g, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qr, k,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window and window > 0:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                   # (b,kh,g,q)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return acc, m, l


def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    kv_chunk=1024, scale=None):
    """Online-softmax attention, scanning KV in chunks.

    q: (B, Sq, H, D); k, v: (B, Sk, Kh, D).  ``q_offset`` is the absolute
    position of q[0] (for decode/cross-chunk causality).
    Memory: O(Sq * kv_chunk) per step instead of O(Sq * Sk).
    """
    b, sq, h, d = q.shape
    sk, kh = k.shape[1], k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    qpos = q_offset + jnp.arange(sq)
    nchunk = -(-sk // kv_chunk)
    if nchunk <= 1:
        acc, m, l = _chunk_attend(q, k, v, qpos, jnp.arange(sk), causal,
                                  window, scale)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)

    pad = nchunk * kv_chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nchunk, kv_chunk, kh, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunk, kv_chunk, kh, d).transpose(1, 0, 2, 3, 4)

    g = h // kh
    init = (jnp.zeros((b, kh, g, sq, d), jnp.float32),
            jnp.full((b, kh, g, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, kh, g, sq), jnp.float32))

    @jax.checkpoint
    def body(carry, inp):
        ci, kci, vci = inp
        acc, m, l = carry
        kpos = ci * kv_chunk + jnp.arange(kv_chunk)
        kpos_valid = kpos < sk
        a2, m2, l2 = _chunk_attend(q, kci, vci, qpos,
                                   jnp.where(kpos_valid, kpos, 2 ** 30),
                                   causal, window, scale)
        m_new = jnp.maximum(m, m2)
        r1 = jnp.exp(m - m_new)
        r2 = jnp.exp(m2 - m_new)
        acc = acc * r1[..., None] + a2 * r2[..., None]
        l = l * r1 + l2 * r2
        return (acc, m_new, l), None

    (acc, m, l), _ = jax.lax.scan(body, init,
                                  (jnp.arange(nchunk), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, dtype=jnp.bfloat16):
    d, h, kh, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["q"], s["q"] = cm.dense_init(ks[0], d, h * dh, None, "heads", dtype,
                                   bias=cfg.qkv_bias)
    p["k"], s["k"] = cm.dense_init(ks[1], d, kh * dh, None, "heads", dtype,
                                   bias=cfg.qkv_bias)
    p["v"], s["v"] = cm.dense_init(ks[2], d, kh * dh, None, "heads", dtype,
                                   bias=cfg.qkv_bias)
    p["o"], s["o"] = cm.dense_init(ks[3], h * dh, d, "heads", None, dtype)
    if getattr(cfg, "qk_norm", False):
        p["qn"], s["qn"] = cm.rmsnorm_init(dh)
        p["kn"], s["kn"] = cm.rmsnorm_init(dh)
    return p, s


def gqa_apply(p, x, cfg, *, positions, layer_kind="global", kv_chunk=1024,
              causal=True):
    """Training / prefill self-attention. x: (B, S, D)."""
    b, sq, d = x.shape
    h, kh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = cm.dense_apply(p["q"], x).reshape(b, sq, h, dh)
    k = cm.dense_apply(p["k"], x).reshape(b, sq, kh, dh)
    v = cm.dense_apply(p["v"], x).reshape(b, sq, kh, dh)
    if "qn" in p:
        q = cm.rmsnorm_apply(p["qn"], q, cfg.norm_eps)
        k = cm.rmsnorm_apply(p["kn"], k, cfg.norm_eps)
    theta = cfg.rope_theta_local if (layer_kind == "local" and
                                     getattr(cfg, "rope_theta_local", 0)) \
        else cfg.rope_theta
    if getattr(cfg, "mrope_sections", None):
        q = rp.apply_mrope(q, positions, cfg.mrope_sections, theta)
        k = rp.apply_mrope(k, positions, cfg.mrope_sections, theta)
    else:
        pos2d = positions if positions.ndim == 2 else positions[0]
        q = rp.apply_rope(q, pos2d, theta)
        k = rp.apply_rope(k, pos2d, theta)
    window = cfg.window if layer_kind == "local" else 0
    o = flash_attention(q, k, v, causal=causal, window=window,
                        kv_chunk=kv_chunk)
    return cm.dense_apply(p["o"], o.reshape(b, sq, h * dh))


def gqa_decode(p, x, cache, cache_index, cfg, *, layer_kind="global"):
    """Single-token decode. cache: {"k","v"}: (B, Smax, Kh, Dh)."""
    b, sq, d = x.shape
    h, kh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = cm.dense_apply(p["q"], x).reshape(b, sq, h, dh)
    k = cm.dense_apply(p["k"], x).reshape(b, sq, kh, dh)
    v = cm.dense_apply(p["v"], x).reshape(b, sq, kh, dh)
    if "qn" in p:
        q = cm.rmsnorm_apply(p["qn"], q, cfg.norm_eps)
        k = cm.rmsnorm_apply(p["kn"], k, cfg.norm_eps)
    pos = jnp.full((b, sq), cache_index, jnp.int32)
    theta = cfg.rope_theta_local if (layer_kind == "local" and
                                     getattr(cfg, "rope_theta_local", 0)) \
        else cfg.rope_theta
    if getattr(cfg, "mrope_sections", None):
        q = rp.apply_mrope(q, jnp.broadcast_to(pos, (3, b, sq)),
                           cfg.mrope_sections, theta)
        k = rp.apply_mrope(k, jnp.broadcast_to(pos, (3, b, sq)),
                           cfg.mrope_sections, theta)
    else:
        q = rp.apply_rope(q, pos, theta)
        k = rp.apply_rope(k, pos, theta)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, cache_index, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, cache_index, 0, 0))
    smax = ck.shape[1]
    kpos = jnp.arange(smax)
    window = cfg.window if layer_kind == "local" else 0
    g = h // kh
    qr = q.reshape(b, sq, kh, g, dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qr.astype(jnp.float32),
                   ck.astype(jnp.float32)) * (dh ** -0.5)
    mask = kpos <= cache_index
    if window:
        mask &= kpos > cache_index - window
    s = jnp.where(mask[None, None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, cv.astype(jnp.float32))
    o = o.reshape(b, sq, h * dh).astype(x.dtype)
    return cm.dense_apply(p["o"], o), {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# cross attention (enc-dec)
# ---------------------------------------------------------------------------

def cross_init(key, cfg, dtype=jnp.bfloat16):
    d, h, kh, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["q"], s["q"] = cm.dense_init(ks[0], d, h * dh, None, "heads", dtype)
    p["k"], s["k"] = cm.dense_init(ks[1], d, kh * dh, None, "heads", dtype)
    p["v"], s["v"] = cm.dense_init(ks[2], d, kh * dh, None, "heads", dtype)
    p["o"], s["o"] = cm.dense_init(ks[3], h * dh, d, "heads", None, dtype)
    return p, s


def cross_apply(p, x, memory, cfg, kv_chunk=1024):
    """x: (B, Sq, D) decoder states; memory: (B, Sk, D) encoder output."""
    b, sq, _ = x.shape
    sk = memory.shape[1]
    h, kh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = cm.dense_apply(p["q"], x).reshape(b, sq, h, dh)
    k = cm.dense_apply(p["k"], memory).reshape(b, sk, kh, dh)
    v = cm.dense_apply(p["v"], memory).reshape(b, sk, kh, dh)
    o = flash_attention(q, k, v, causal=False, kv_chunk=kv_chunk)
    return cm.dense_apply(p["o"], o.reshape(b, sq, h * dh))


# ---------------------------------------------------------------------------
# DeepSeek MLA (multi-head latent attention, compressed KV cache)
# ---------------------------------------------------------------------------

def mla_init(key, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    h = cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p, s = {}, {}
    p["dq"], s["dq"] = cm.dense_init(ks[0], d, qr, None, None, dtype)
    p["dq_n"], s["dq_n"] = cm.rmsnorm_init(qr)
    p["uq"], s["uq"] = cm.dense_init(ks[1], qr, h * (dn + dr), None, "heads", dtype)
    p["dkv"], s["dkv"] = cm.dense_init(ks[2], d, kvr + dr, None, None, dtype)
    p["dkv_n"], s["dkv_n"] = cm.rmsnorm_init(kvr)
    p["uk"], s["uk"] = cm.dense_init(ks[3], kvr, h * dn, None, "heads", dtype)
    p["uv"], s["uv"] = cm.dense_init(ks[4], kvr, h * dv, None, "heads", dtype)
    p["o"], s["o"] = cm.dense_init(ks[5], h * dv, d, "heads", None, dtype)
    return p, s


def mla_apply(p, x, cfg, *, positions, kv_chunk=1024):
    """Training / prefill MLA (decompressed form)."""
    b, sq, d = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    cq = cm.rmsnorm_apply(p["dq_n"], cm.dense_apply(p["dq"], x), cfg.norm_eps)
    q = cm.dense_apply(p["uq"], cq).reshape(b, sq, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    ckv_full = cm.dense_apply(p["dkv"], x)
    ckv = cm.rmsnorm_apply(p["dkv_n"], ckv_full[..., :cfg.kv_lora_rank],
                           cfg.norm_eps)
    k_rope = ckv_full[..., cfg.kv_lora_rank:].reshape(b, sq, 1, dr)
    pos2d = positions if positions.ndim == 2 else positions[0]
    q_rope = rp.apply_rope(q_rope, pos2d, cfg.rope_theta)
    k_rope = rp.apply_rope(k_rope, pos2d, cfg.rope_theta)
    k_nope = cm.dense_apply(p["uk"], ckv).reshape(b, sq, h, dn)
    v = cm.dense_apply(p["uv"], ckv).reshape(b, sq, h, dv)
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    k_full = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (b, sq, h, dr))], -1)
    scale = (dn + dr) ** -0.5
    # pad v to qk dim for the shared flash core, then slice back
    if dv < dn + dr:
        v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
    else:
        v_p = v
    o = flash_attention(q_full, k_full, v_p, causal=True, kv_chunk=kv_chunk,
                        scale=scale)[..., :dv]
    return cm.dense_apply(p["o"], o.reshape(b, sq, h * dv))


def mla_decode(p, x, cache, cache_index, cfg):
    """Absorbed-form MLA decode: attention runs in the compressed space;
    the cache holds (c_kv, k_rope) only — the MLA memory win."""
    b, sq, d = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    cq = cm.rmsnorm_apply(p["dq_n"], cm.dense_apply(p["dq"], x), cfg.norm_eps)
    q = cm.dense_apply(p["uq"], cq).reshape(b, sq, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    pos = jnp.full((b, sq), cache_index, jnp.int32)
    q_rope = rp.apply_rope(q_rope, pos, cfg.rope_theta)
    ckv_full = cm.dense_apply(p["dkv"], x)
    ckv = cm.rmsnorm_apply(p["dkv_n"], ckv_full[..., :kvr], cfg.norm_eps)
    k_rope = rp.apply_rope(ckv_full[..., kvr:].reshape(b, sq, 1, dr), pos,
                           cfg.rope_theta)
    cc = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, cache_index, 0))
    cr = jax.lax.dynamic_update_slice(
        cache["kr"], k_rope[:, :, 0].astype(cache["kr"].dtype),
        (0, cache_index, 0))
    # absorb W_uk into q: q_c (B,1,H,kvr) = q_nope @ W_uk(per head)^T
    wuk = p["uk"]["w"].reshape(kvr, h, dn)
    q_c = jnp.einsum("bqhd,khd->bqhk", q_nope.astype(jnp.float32),
                     wuk.astype(jnp.float32))
    s = (jnp.einsum("bqhk,bsk->bhqs", q_c, cc.astype(jnp.float32)) +
         jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                    cr.astype(jnp.float32))) * ((dn + dr) ** -0.5)
    kpos = jnp.arange(cc.shape[1])
    s = jnp.where((kpos <= cache_index)[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhqs,bsk->bqhk", w, cc.astype(jnp.float32))
    wuv = p["uv"]["w"].reshape(kvr, h, dv)
    o = jnp.einsum("bqhk,khd->bqhd", o_c, wuv.astype(jnp.float32))
    o = o.reshape(b, sq, h * dv).astype(x.dtype)
    return cm.dense_apply(p["o"], o), {"ckv": cc, "kr": cr}
