"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 1e4):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4):
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, Dh/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, sections, theta: float = 1e4):
    """Qwen2-VL multimodal RoPE.

    positions: (3, B, S) — temporal / height / width position ids (the stub
    frontend emits t==h==w==arange for text tokens, per the paper).
    ``sections``: per-axis frequency budget, e.g. (16, 24, 24) summing Dh/2.
    """
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    ang_per_axis = positions[..., None].astype(jnp.float32) * freqs  # (3,B,S,Dh/2)
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        parts.append(ang_per_axis[i, :, :, start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, -1)                    # (B, S, Dh/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)
