"""Mamba-2 SSD mixer (state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: intra-chunk attention-like
matmuls + a scan over chunk boundary states.  Decode is the O(1) recurrent
state update.  The short causal depthwise conv in front of (x, B, C) runs
through the paper-engine's *untangled depthwise* formulation (HUGE2 §3.2.3,
C=1 outer-product case).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers import common as cm
from repro.core.untangle import untangled_depthwise_conv1d


def ssd_init(key, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    di = cfg.d_inner                      # e.g. 2*d
    h = cfg.ssm_heads                     # di / headdim
    n = cfg.ssm_state
    g = cfg.ssm_groups
    ks = jax.random.split(key, 6)
    conv_dim = di + 2 * g * n
    p = {
        # fused in-proj: [z (di), x (di), B (g*n), C (g*n), dt (h)]
        "in": jax.random.normal(ks[0], (d, 2 * di + 2 * g * n + h), dtype)
              * d ** -0.5,
        "conv": jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), dtype)
                * 0.2,
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((di,), jnp.float32),
        "out": jax.random.normal(ks[2], (di, d), dtype) * di ** -0.5,
    }
    s = {
        "in": cm.spec(None, "heads"),
        "conv": cm.spec(None, "heads"),
        "A_log": cm.spec(None), "D": cm.spec(None), "dt_bias": cm.spec(None),
        "norm": cm.spec("heads"),
        "out": cm.spec("heads", None),
    }
    return p, s


def _split_in(y, cfg):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = y[..., :di]
    x = y[..., di:2 * di]
    bmat = y[..., 2 * di:2 * di + g * n]
    cmat = y[..., 2 * di + g * n:2 * di + 2 * g * n]
    dt = y[..., 2 * di + 2 * g * n:]
    return z, x, bmat, cmat, dt


def ssd_chunked(x, dt, a_log, b, c, d_skip, chunk: int = 128):
    """Chunked SSD. x:(B,S,H,P) dt:(B,S,H) b,c:(B,S,G,N) -> (B,S,H,P).

    Within-chunk: Y += (C B^T * decay-masked) dtX.  Across chunks: state
    h:(B,H,P,N) carried by lax.scan with per-chunk decay.
    """
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    nchunk = -(-s // chunk)
    pad = nchunk * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = nchunk * chunk
    a = -jnp.exp(a_log)                                     # (H,) negative
    xf = x.astype(jnp.float32).reshape(bsz, nchunk, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(bsz, nchunk, chunk, h)
    bf = b.astype(jnp.float32).reshape(bsz, nchunk, chunk, g, n)
    cf = c.astype(jnp.float32).reshape(bsz, nchunk, chunk, g, n)
    # heads per group
    hg = h // g
    bf = jnp.repeat(bf, hg, axis=3)                         # (B,Nc,Q,H,N)
    cf = jnp.repeat(cf, hg, axis=3)

    da = dtf * a                                            # (B,Nc,Q,H)
    cum = jnp.cumsum(da, axis=2)                            # within-chunk
    # decay from position j to i (i>=j): exp(cum[i] - cum[j]).  Mask the
    # *exponent* (not the product) so masked entries are exactly 0 and the
    # VJP never sees exp(+large)*0 = NaN.
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (B,Nc,Qi,Qj,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    seg = jnp.where(tri[None, None, :, :, None], seg, -1e30)
    l_mask = jnp.exp(seg)
    # NOTE (§Perf P3): every multi-operand einsum here is pre-merged into a
    # single pairwise contraction — XLA otherwise materializes per-position
    # rank-1 outer products f32[B,Nc,H,Q,N*P] (measured 6 x 25.8 GB/chip).
    xdt = xf * dtf[..., None]                               # (B,Nc,Q,H,P)
    # intra-chunk: scores (B,Nc,H,Qi,Qj)
    scores = jnp.einsum("bnqhN,bnkhN->bnhqk", cf, bf)
    scores = scores * l_mask.transpose(0, 1, 4, 2, 3)
    y_intra = jnp.einsum("bnhqk,bnkhp->bnqhp", scores, xdt)

    # chunk-boundary states
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,Nc,Q,H)
    state_c = jnp.einsum("bnkhN,bnkhp->bnhNp",
                         bf, xdt * decay_to_end[..., None])  # per-chunk inject
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # (B,Nc,H)

    def scanner(hprev, inp):
        inj, dec = inp                                      # (B,H,N,P),(B,H)
        hnew = hprev * dec[:, :, None, None] + inj
        return hnew, hprev

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    _, h_in = jax.lax.scan(
        scanner, h0,
        (state_c.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                    # (B,Nc,H,N,P)
    decay_from_start = jnp.exp(cum)                         # (B,Nc,Q,H)
    y_inter = jnp.einsum("bnqhN,bnhNp->bnqhp",
                         cf * decay_from_start[..., None], h_in)
    y = (y_intra + y_inter).reshape(bsz, sp, h, p)[:, :s]
    y = y + d_skip[None, None, :, None] * x.astype(jnp.float32)[
        :, :sp].reshape(bsz, sp, h, p)[:, :s]
    return y


def ssd_apply(p, xin, cfg, conv_state=None):
    """Full mixer: in-proj -> conv -> SSD -> gated norm -> out-proj."""
    bsz, s, _ = xin.shape
    di, h, n, g = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    ph = di // h
    y = cm.dense_apply({"w": p["in"]}, xin)
    z, x, bmat, cmat, dt = _split_in(y, cfg)
    xbc = jnp.concatenate([x, bmat, cmat], -1)
    xbc = untangled_depthwise_conv1d(xbc, p["conv"], causal=True)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(xin.dtype)
    x = xbc[..., :di].reshape(bsz, s, h, ph)
    bmat = xbc[..., di:di + g * n].reshape(bsz, s, g, n)
    cmat = xbc[..., di + g * n:].reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    yss = ssd_chunked(x, dt, p["A_log"], bmat, cmat, p["D"],
                      chunk=cfg.ssm_chunk)
    yss = yss.reshape(bsz, s, di)
    # gated RMSNorm (mamba2)
    zf = jax.nn.silu(z.astype(jnp.float32))
    yn = yss * zf
    var = jnp.mean(yn * yn, -1, keepdims=True)
    yn = yn * jax.lax.rsqrt(var + 1e-6) * p["norm"]
    return cm.dense_apply({"w": p["out"]}, yn.astype(xin.dtype))


def ssd_decode(p, xin, state, cfg):
    """O(1) decode. state: {"h": (B,H,N,P) f32, "conv": (B,K-1,conv_dim)}."""
    bsz, s, _ = xin.shape
    assert s == 1
    di, h, n, g = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    ph = di // h
    y = cm.dense_apply({"w": p["in"]}, xin)
    z, x, bmat, cmat, dt = _split_in(y, cfg)
    xbc = jnp.concatenate([x, bmat, cmat], -1)               # (B,1,convdim)
    window = jnp.concatenate([state["conv"], xbc], 1)        # (B,K,convdim)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv"].astype(jnp.float32))[:, None]
    xbc = jax.nn.silu(conv_out).astype(xin.dtype)
    new_conv = window[:, 1:]
    x = xbc[..., :di].reshape(bsz, h, ph)
    bmat = xbc[..., di:di + g * n].reshape(bsz, g, n)
    cmat = xbc[..., di + g * n:].reshape(bsz, g, n)
    hg = h // g
    bmat = jnp.repeat(bmat, hg, axis=1)                      # (B,H,N)
    cmat = jnp.repeat(cmat, hg, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    a = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt * a)                                    # (B,H)
    inj = jnp.einsum("bh,bhN,bhp->bhNp", dt, bmat, x.astype(jnp.float32))
    hnew = state["h"] * dec[:, :, None, None] + inj
    yss = jnp.einsum("bhN,bhNp->bhp", cmat, hnew)
    yss = yss + p["D"][None, :, None] * x.astype(jnp.float32)
    yss = yss.reshape(bsz, 1, di)
    zf = jax.nn.silu(z.astype(jnp.float32))
    yn = yss * zf
    var = jnp.mean(yn * yn, -1, keepdims=True)
    yn = yn * jax.lax.rsqrt(var + 1e-6) * p["norm"]
    out = cm.dense_apply({"w": p["out"]}, yn.astype(xin.dtype))
    return out, {"h": hnew, "conv": new_conv}
