"""Shared layer primitives: params are plain pytrees; every creator returns
``(params, specs)`` where ``specs`` mirrors the params with *logical*
PartitionSpecs (resolved to mesh axes by ``repro.sharding``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Pytree = Any


def spec(*axes) -> P:
    """Logical partition spec (axis names resolved later)."""
    return P(*axes)


def dense_init(key, in_dim, out_dim, in_axis, out_axis, dtype=jnp.bfloat16,
               bias=False, scale=None):
    scale = scale if scale is not None else in_dim ** -0.5
    w = jax.random.normal(key, (in_dim, out_dim), dtype) * scale
    params = {"w": w}
    specs = {"w": spec(in_axis, out_axis)}
    if bias:
        params["b"] = jnp.zeros((out_dim,), dtype)
        specs["b"] = spec(out_axis)
    return params, specs


# XLA:CPU's thunk runtime lacks some fused BF16xBF16->F32 dot kernels; upcast
# on CPU only (trace-time constant — no effect on the TPU target).  The
# dry-run (compile-only, REPRO_DRYRUN=1) keeps bf16 so cost_analysis reports
# the TPU-faithful byte counts.
import os as _os

_CPU_BACKEND = (jax.default_backend() == "cpu"
                and _os.environ.get("REPRO_DRYRUN") != "1")


def _dot_operands(x, w):
    if _CPU_BACKEND and x.dtype == jnp.bfloat16:
        return x.astype(jnp.float32), w.astype(jnp.float32)
    return x, w


def dense_apply(p, x):
    xx, ww = _dot_operands(x, p["w"])
    y = jax.lax.dot_general(xx, ww, (((x.ndim - 1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(x.dtype)


def rmsnorm_init(dim, dtype=jnp.float32):
    return {"g": jnp.ones((dim,), dtype)}, {"g": spec(None)}


def rmsnorm_apply(p, x, eps=1e-6, gemma_style=False):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    g = p["g"].astype(jnp.float32)
    y = y * (1.0 + g) if gemma_style else y * g
    return y.astype(x.dtype)


def layernorm_init(dim, dtype=jnp.float32):
    return ({"g": jnp.ones((dim,), dtype), "b": jnp.zeros((dim,), dtype)},
            {"g": spec(None), "b": spec(None)})


def layernorm_apply(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]
    return y.astype(x.dtype)


def embed_init(key, vocab, dim, dtype=jnp.bfloat16):
    w = jax.random.normal(key, (vocab, dim), dtype) * (dim ** -0.5)
    return {"w": w}, {"w": spec("vocab", None)}


def embed_apply(p, ids):
    return jnp.take(p["w"], ids, axis=0)


def embed_logits(p, x):
    """Tied readout: (B, S, D) @ (V, D)^T."""
    xx, ww = _dot_operands(x, p["w"])
    return jax.lax.dot_general(
        xx, ww, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


ACTS: dict[str, Callable] = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "gelu_exact": lambda x: jax.nn.gelu(x, approximate=False),
    "relu": jax.nn.relu,
}
