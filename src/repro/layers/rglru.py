"""Griffin / RecurrentGemma RG-LRU recurrent block (arXiv:2402.19427).

Block: in-proj to two branches -> (conv1d -> RG-LRU) * gelu(gate) -> out-proj.
The temporal conv1d runs through the HUGE2 untangled depthwise path.
Prefill uses an associative scan over the diagonal linear recurrence;
decode is the O(1) update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers import common as cm
from repro.core.untangle import untangled_depthwise_conv1d

_C = 8.0  # RG-LRU exponent constant


def rglru_init(key, cfg, dtype=jnp.bfloat16):
    d, dr = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 6)
    p = {
        "in_x": jax.random.normal(ks[0], (d, dr), dtype) * d ** -0.5,
        "in_g": jax.random.normal(ks[1], (d, dr), dtype) * d ** -0.5,
        "conv": jax.random.normal(ks[2], (cfg.conv_width, dr), dtype) * 0.2,
        "wa": jax.random.normal(ks[3], (dr, dr), dtype) * dr ** -0.5,
        "wx": jax.random.normal(ks[4], (dr, dr), dtype) * dr ** -0.5,
        "lam": jnp.full((dr,), 2.0, jnp.float32),   # sigmoid(lam)^c ~ decay
        "out": jax.random.normal(ks[5], (dr, d), dtype) * dr ** -0.5,
    }
    s = {
        "in_x": cm.spec(None, "heads"), "in_g": cm.spec(None, "heads"),
        "conv": cm.spec(None, "heads"),
        "wa": cm.spec(None, "heads"), "wx": cm.spec(None, "heads"),
        "lam": cm.spec("heads"), "out": cm.spec("heads", None),
    }
    return p, s


def _rglru_gates(p, x):
    """x: (..., dr) post-conv branch -> (a, gated_x) in f32."""
    rg = jax.nn.sigmoid(cm.dense_apply({"w": p["wa"]}, x).astype(jnp.float32))
    ig = jax.nn.sigmoid(cm.dense_apply({"w": p["wx"]}, x).astype(jnp.float32))
    log_a = -_C * rg * jax.nn.softplus(p["lam"])        # log a_t  (<=0)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bx = mult * ig * x.astype(jnp.float32)
    return a, bx


def rglru_apply(p, xin, cfg, h0=None):
    """Prefill/train. xin: (B, S, D) -> (B, S, D)."""
    b, s, d = xin.shape
    x = cm.dense_apply({"w": p["in_x"]}, xin)
    g = cm.dense_apply({"w": p["in_g"]}, xin)
    x = untangled_depthwise_conv1d(x, p["conv"], causal=True)
    a, bx = _rglru_gates(p, x)
    if h0 is not None:
        bx = bx.at[:, 0].add(a[:, 0] * h0)

    def comb(l, r):
        return (l[0] * r[0], l[1] * r[0] + r[1])

    _, h = jax.lax.associative_scan(comb, (a, bx), axis=1)
    y = h * jax.nn.gelu(g.astype(jnp.float32), approximate=True)
    return cm.dense_apply({"w": p["out"]}, y.astype(xin.dtype))


def rglru_decode(p, xin, state, cfg):
    """O(1) decode. state: {"h": (B, dr) f32, "conv": (B, K-1, dr)}."""
    b, s, d = xin.shape
    assert s == 1
    x = cm.dense_apply({"w": p["in_x"]}, xin)
    g = cm.dense_apply({"w": p["in_g"]}, xin)
    window = jnp.concatenate([state["conv"], x], 1)
    xc = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                    p["conv"].astype(jnp.float32))[:, None].astype(xin.dtype)
    a, bx = _rglru_gates(p, xc)
    hnew = a[:, 0] * state["h"] + bx[:, 0]
    y = hnew[:, None] * jax.nn.gelu(g.astype(jnp.float32), approximate=True)
    out = cm.dense_apply({"w": p["out"]}, y.astype(xin.dtype))
    return out, {"h": hnew, "conv": window[:, 1:]}
