"""Mixture-of-Experts with expert parallelism.

Two interchangeable implementations:

* ``dense``  — exact: every expert runs on every token, combined by the top-k
  gate mask.  Used by reduced smoke configs (small E) and as the oracle in
  tests.
* ``ep``     — production path: experts sharded over the ``model`` mesh axis
  via ``jax.shard_map``.  Each model-rank serves its E_l local experts for all
  locally-resident tokens with capacity-bounded gather -> FFN -> scatter-add,
  then a ``psum`` over the model axis combines disjoint expert outputs.  This
  keeps routing/token movement *local to each shard* (no SPMD surprise
  all-gathers) and reproduces real MoE FLOPs (cap = T*k*cf/E per expert).

Routers: 'softmax' (DBRX: top-k softmax renormalized) and 'sigmoid_bias'
(DeepSeek-V3 aux-loss-free: sigmoid affinity + selection-only bias).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.layers import common as cm
from repro.sharding import shard_map_compat


def moe_init(key, cfg, dtype=jnp.bfloat16):
    d, de, e = cfg.d_model, cfg.d_expert, cfg.n_experts
    ks = jax.random.split(key, 5)
    scale = d ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * scale,
        "bias": jnp.zeros((e,), jnp.float32),   # aux-free balance bias
        "wi": jax.random.normal(ks[1], (e, d, de), dtype) * scale,
        "wg": jax.random.normal(ks[2], (e, d, de), dtype) * scale,
        "wo": jax.random.normal(ks[3], (e, de, d), dtype) * (de ** -0.5),
    }
    s = {
        "router": cm.spec(None, None),
        "bias": cm.spec(None),
        "wi": cm.spec("expert", None, "expert_ffn"),
        "wg": cm.spec("expert", None, "expert_ffn"),
        "wo": cm.spec("expert", "expert_ffn", None),
    }
    return p, s


def _route(x2d, p, cfg):
    """x2d: (T, D) -> (weights (T,k), idx (T,k))."""
    logits = (x2d.astype(jnp.float32) @ p["router"])
    if cfg.router_type == "sigmoid_bias":
        scores = jax.nn.sigmoid(logits)
        sel = scores + p["bias"]
        _, idx = jax.lax.top_k(sel, cfg.top_k)
        w = jnp.take_along_axis(scores, idx, axis=-1)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        w = w * cfg.routed_scaling
    else:
        scores = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(scores, cfg.top_k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, idx


def _expert_ffn(wi, wg, wo, x, act):
    h = cm.ACTS[act]((x @ wg).astype(jnp.float32)) * (x @ wi).astype(jnp.float32)
    return h.astype(x.dtype) @ wo


def moe_apply_dense(p, x, cfg):
    """Exact all-experts-all-tokens combine (oracle / smoke path)."""
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    w, idx = _route(x2, p, cfg)
    gates = jnp.zeros((b * s, cfg.n_experts), jnp.float32).at[
        jnp.arange(b * s)[:, None], idx].add(w)
    # (T, E) x per-expert FFN, contracted over E
    h_g = jnp.einsum("td,edf->tef", x2.astype(jnp.float32),
                     p["wg"].astype(jnp.float32))
    h_i = jnp.einsum("td,edf->tef", x2.astype(jnp.float32),
                     p["wi"].astype(jnp.float32))
    h = cm.ACTS[cfg.act](h_g) * h_i
    y = jnp.einsum("tef,efd->ted", h, p["wo"].astype(jnp.float32))
    out = jnp.einsum("ted,te->td", y, gates)
    return out.astype(x.dtype).reshape(b, s, d)


def _ep_local_body(x2, router, bias, wi, wg, wo, *, cfg, model_axis,
                   n_model: int):
    """Per-shard body under shard_map. x2: (T_l, D); wi/wg/wo: (E_l, ...)."""
    t_l, d = x2.shape
    e_l = wi.shape[0]
    rank = jax.lax.axis_index(model_axis)
    w, idx = _route(x2, {"router": router, "bias": bias}, cfg)     # (T_l, k)
    cap = min(t_l, max(1, int(t_l * cfg.top_k * cfg.capacity_factor)
                       // cfg.n_experts))
    out = jnp.zeros((t_l, d), jnp.float32)
    for e in range(e_l):
        gid = rank * e_l + e
        gate_e = jnp.where(idx == gid, w, 0.0).sum(-1)             # (T_l,)
        gv, tok = jax.lax.top_k(gate_e, cap)                       # capacity
        xe = jnp.take(x2, tok, axis=0)                             # (cap, D)
        ye = _expert_ffn(wi[e], wg[e], wo[e], xe, cfg.act)
        ye = ye.astype(jnp.float32) * gv[:, None]
        out = out.at[tok].add(jnp.where((gv > 0)[:, None], ye, 0.0))
    out = jax.lax.psum(out, model_axis)
    return out.astype(x2.dtype)


def moe_apply_ep(p, x, cfg, dist):
    """Expert-parallel MoE via shard_map (see module docstring)."""
    b, s, d = x.shape
    mesh = dist.mesh
    ba, ma = dist.batch_axes, dist.model_axis
    n_model = mesh.shape[ma]
    body = partial(_ep_local_body, cfg=cfg, model_axis=ma, n_model=n_model)
    f = shard_map_compat(
        body, mesh,
        in_specs=(P(ba, None), P(None, None), P(None),
                  P(ma), P(ma), P(ma)),
        out_specs=P(ba, None))
    y = f(x.reshape(b * s, d), p["router"], p["bias"], p["wi"], p["wg"],
          p["wo"])
    return y.reshape(b, s, d)


# ---------------------------------------------------------------------------
# all-to-all expert parallelism (experts sharded over data*model, 1/chip)
# ---------------------------------------------------------------------------
#
# §Perf P2: with 256 experts stored ZeRO-3-sharded over data*model, the
# psum-EP path needs each layer's experts all-gathered over 'data' — XLA
# hoists that gather out of the layer scan, materializing six 54 GB f32
# buffers (measured; see EXPERIMENTS.md).  Production EP instead routes
# *tokens* to resident experts with all_to_all (DeepSeek's own deployment
# shape).  Weights never move; expert grads stay fully sharded.

def _ep_a2a_body(x2, valid, router, bias, wi, wg, wo, *, cfg, axes):
    """Per-shard body. x2: (T_l, D) local tokens; wi/wg/wo: (E_l, ...) the
    experts resident on this chip (usually E_l == 1).  ``valid`` masks
    padding tokens (decode batches are padded up to the EP extent)."""
    t_l, d = x2.shape
    e_l = wi.shape[0]
    e = cfg.n_experts
    n_dev = e // e_l
    w, idx = _route(x2, {"router": router, "bias": bias}, cfg)    # (T_l, k)
    w = w * valid[:, None].astype(w.dtype)
    cap = min(t_l, max(1, int(t_l * cfg.top_k * cfg.capacity_factor) // e))
    # dense gate matrix, then per-expert top-cap (expert-capacity dropping)
    gates = jnp.zeros((t_l, e), jnp.float32).at[
        jnp.arange(t_l)[:, None], idx].add(w)                      # (T_l, E)
    gv, tok = jax.lax.top_k(gates.T, cap)                          # (E, cap)
    buf = jnp.take(x2, tok.reshape(-1), axis=0).reshape(e, cap, d)
    buf = jnp.where((gv > 0)[..., None], buf, 0)
    # route token blocks to their expert's chip
    recv = jax.lax.all_to_all(buf, axes, split_axis=0, concat_axis=0,
                              tiled=True)                          # (E, cap, D)
    recv = recv.reshape(n_dev, e_l, cap, d)
    outs = []
    for el in range(e_l):                                          # static
        h = _expert_ffn(wi[el], wg[el], wo[el],
                        recv[:, el].reshape(n_dev * cap, d), cfg.act)
        outs.append(h.reshape(n_dev, cap, d))
    back = jnp.stack(outs, 1).reshape(e, cap, d)
    ret = jax.lax.all_to_all(back, axes, split_axis=0, concat_axis=0,
                             tiled=True)                           # (E, cap, D)
    y = jnp.zeros((t_l, d), jnp.float32)
    flat_tok = tok.reshape(-1)
    flat_val = (ret.astype(jnp.float32)
                * gv[..., None]).reshape(-1, d)
    y = y.at[flat_tok].add(jnp.where((gv > 0).reshape(-1, 1), flat_val, 0))
    return y.astype(x2.dtype)


def moe_apply_ep_a2a(p, x, cfg, dist):
    b, s, d = x.shape
    mesh = dist.mesh
    ep_axes = dist.rules["expert"]          # e.g. ('data', 'model')
    ba = dist.batch_axes
    n_ep = 1
    for a in ep_axes:
        n_ep *= mesh.shape[a]
    # tokens split over every EP axis (batch axes may overlap with them)
    ba_t = tuple(ba) if isinstance(ba, tuple) else ((ba,) if ba else ())
    tok_axes = tuple(dict.fromkeys(ba_t + tuple(ep_axes)))
    tokens = b * s
    padded = -(-tokens // n_ep) * n_ep
    x2 = x.reshape(tokens, d)
    valid = jnp.ones((tokens,), jnp.bool_)
    if padded != tokens:
        # decode batches smaller than the EP extent: pad with masked tokens
        # (zero gate weight -> dropped at dispatch), §Perf P2 iteration 3
        x2 = jnp.pad(x2, ((0, padded - tokens), (0, 0)))
        valid = jnp.pad(valid, (0, padded - tokens))
    body = partial(_ep_a2a_body, cfg=cfg, axes=ep_axes)
    f = shard_map_compat(
        body, mesh,
        in_specs=(P(tok_axes, None), P(tok_axes), P(None, None), P(None),
                  P(ep_axes), P(ep_axes), P(ep_axes)),
        out_specs=P(tok_axes, None))
    y = f(x2, valid, p["router"], p["bias"], p["wi"], p["wg"], p["wo"])
    return y[:tokens].reshape(b, s, d)


def update_balance_bias(bias, expert_load, gamma: float = 1e-3):
    """DeepSeek-V3 aux-loss-free balancing (arXiv:2408.15664): between steps,
    nudge each expert's selection bias against its load error.  Not part of
    the gradient — the driver applies it to params['...']['moe']['bias'].

    expert_load: (E,) fraction of routed tokens per expert this step.
    """
    target = 1.0 / bias.shape[-1]
    return bias - gamma * jnp.sign(expert_load - target)


def expert_load_from_idx(idx, n_experts: int):
    """(T, k) routing indices -> (E,) load fractions."""
    one = jnp.zeros((n_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    return one / idx.size


def moe_apply(p, x, cfg, dist=None):
    if dist is not None and getattr(cfg, "moe_impl", "dense") == "ep":
        if isinstance(dist.rules.get("expert"), tuple):
            return moe_apply_ep_a2a(p, x, cfg, dist)
        return moe_apply_ep(p, x, cfg, dist)
    return moe_apply_dense(p, x, cfg)
