"""Continuous batching for LM serving (vLLM-style slot scheduler, scoped to
the static-shape JAX world).

The server keeps a fixed pool of B cache *slots* sharing one jitted
``decode_step``.  Requests join mid-flight whenever a slot frees: the
prompt is prefilled token-by-token into the slot's cache region while other
slots keep decoding (all slots advance together each step — the classic
static-batch continuous scheduler).  Per-slot position counters live in a
vector so one jit covers every occupancy mix.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.serving.metrics import latency_stats


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                      # (P,) int32
    max_new: int
    t_arrival: float = dataclasses.field(default_factory=time.perf_counter)
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    out: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                            # next cache index to write
    prompt_left: int = 0


class ContinuousBatcher:
    """Fixed-slot continuous batching over ``decode_step``.

    All slots step together; empty slots process a pad token into a scratch
    position (their logits are discarded).  Per-step cost is one jitted
    decode regardless of occupancy — the production trade for static shapes.
    """

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 128,
                 memory=None):
        self.cfg, self.params = cfg, params
        self.n = slots
        self.max_len = max_len
        self.memory = memory
        self.slots = [_Slot() for _ in range(slots)]
        self.queue: deque[Request] = deque()
        self.done: list[Request] = []
        # decode_step takes ONE cache index per call, but slots sit at
        # different positions — so each slot owns a B=1 cache and shares a
        # single jitted B=1 step (same shapes => one compilation).  A fused
        # per-slot-position kernel is the TPU follow-up; this keeps the
        # scheduler exact and portable.
        self.cache1, _ = tfm.init_cache(cfg, 1, max_len)
        self._step1 = jax.jit(
            lambda p, c, t, i: tfm.decode_step(p, c, t, i, cfg,
                                               memory=memory))
        self.slot_caches = [jax.tree.map(jnp.copy, self.cache1)
                            for _ in range(slots)]

    # -- client API ----------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in self.slots:
            if s.req is None and self.queue:
                s.req = self.queue.popleft()
                s.pos = 0
                s.prompt_left = len(s.req.prompt)

    def step(self):
        """Advance every occupied slot by one token (prefill or decode)."""
        self._admit()
        for si, s in enumerate(self.slots):
            if s.req is None:
                continue
            r = s.req
            if s.prompt_left > 0:
                tok = np.array([[r.prompt[len(r.prompt) - s.prompt_left]]],
                               np.int32)
            else:
                tok = np.array([[r.out[-1]]], np.int32)
            logits, self.slot_caches[si] = self._step1(
                self.params, self.slot_caches[si], jnp.asarray(tok), s.pos)
            s.pos += 1
            if s.prompt_left > 0:
                s.prompt_left -= 1
                if s.prompt_left == 0:      # prompt consumed: first token
                    nxt = int(np.argmax(np.asarray(logits[0, -1])))
                    r.out.append(nxt)
                    r.t_first = time.perf_counter()
            else:
                nxt = int(np.argmax(np.asarray(logits[0, -1])))
                r.out.append(nxt)
            if (len(r.out) >= r.max_new or s.pos >= self.max_len - 1):
                r.t_done = time.perf_counter()
                self.done.append(r)
                s.req = None
                # recycle the slot cache (zeros) for the next request
                self.slot_caches[si] = jax.tree.map(jnp.copy, self.cache1)

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(s.req for s in self.slots)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return steps

    def stats(self):
        """Serving report via the shared ``serving.metrics`` implementation
        (same percentile math as the image batcher and the benches), plus
        the legacy second-unit keys."""
        lat = [r.t_done - r.t_arrival for r in self.done if r.t_done]
        ttft = [r.t_first - r.t_arrival for r in self.done if r.t_first]
        st = latency_stats(lat)
        ttft_st = latency_stats(ttft)
        st["completed"] = len(self.done)
        st["p50_latency_s"] = st["p50_ms"] / 1e3
        st["p50_ttft_s"] = ttft_st["p50_ms"] / 1e3
        st["ttft_p95_ms"] = ttft_st["p95_ms"]
        return st
