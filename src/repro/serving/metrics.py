"""Latency/throughput statistics shared by every serving surface.

One implementation of percentile reporting for the LM slot scheduler
(``serving/batcher.py``), the image batcher (``serving/image_batcher.py``),
the serve examples, and the benchmark harness (``benchmarks/util.py``
re-exports ``latency_stats`` so the JSON emitters use the same math) —
replacing the per-example ``np.percentile`` calls that had drifted apart.

Percentiles use numpy's default linear interpolation over the *completed*
requests only; throughput is completions over the measured wall-clock
window, not the sum of latencies (batched serving overlaps requests, so
the two differ by design).
"""
from __future__ import annotations

import numpy as np

PERCENTILES = (50, 95, 99)


def latency_stats(latencies_s, *, window_s: float | None = None) -> dict:
    """Summarize per-request latencies (seconds) into the serving report.

    Returns ``completed``, ``mean_ms`` and ``p50_ms``/``p95_ms``/``p99_ms``;
    when ``window_s`` (the measured serving window) is given, also
    ``throughput_rps`` = completed / window.
    """
    lat = np.asarray([float(v) for v in latencies_s], np.float64)
    out = {"completed": int(lat.size)}
    if lat.size:
        out["mean_ms"] = float(lat.mean() * 1e3)
        for p in PERCENTILES:
            out[f"p{p}_ms"] = float(np.percentile(lat, p) * 1e3)
    else:
        out["mean_ms"] = 0.0
        out.update({f"p{p}_ms": 0.0 for p in PERCENTILES})
    if window_s is not None:
        out["throughput_rps"] = (lat.size / window_s) if window_s > 0 else 0.0
    return out


def format_stats(st: dict, unit: str = "req") -> str:
    """One-line human rendering of a ``latency_stats`` dict."""
    parts = []
    if "throughput_rps" in st:
        parts.append(f"throughput {st['throughput_rps']:8.1f} {unit}/s")
    parts.append(f"latency p50 {st['p50_ms']:6.1f} ms")
    parts.append(f"p95 {st['p95_ms']:6.1f} ms")
    parts.append(f"p99 {st['p99_ms']:6.1f} ms")
    return "  ".join(parts)
