"""SLO-aware serving control plane: one admission + scheduling layer in
front of every model backend (the bucket-coalescing image batchers and the
LM slot scheduler), with fault-injected replay wired into the launch path.

The two schedulers that grew out of PRs 1-4 — ``serving/batcher.py``
(continuous LM batching) and ``serving/image_batcher.py`` (bucket
coalescing) — were peer entry points with separate queues, no deadlines,
and no survival story when a device disappears mid-batch.  Here they become
*backends* of one control plane:

admission → schedule → launch → replay

- **Admission** (``submit``): a request carries an SLO (``slo_ms``) and a
  priority class (``interactive`` > ``batch``).  When the backend has
  measured launch costs, the control plane estimates wait + service for
  the backlog ahead of the request; if the estimate already blows the
  deadline the request is **rejected at admission** (cheapest possible
  failure: no queue space, no compute, an immediate answer to the client).
  Without measured costs admission is permissive — estimates, never
  guesses.
- **Schedule** (``pump``): per-model, per-class FIFO queues.  Interactive
  requests launch first, but starvation is bounded: a batch request older
  than ``starvation_ms`` is scheduled ahead of fresher interactive work.
  Across models the head-of-line request with the earliest deadline wins
  (EDF).  A launch takes from the chosen class, then *backfills* the
  remaining bucket slots with the other class's requests — padding with
  real work instead of zeros.  Requests whose deadline has already passed
  are **shed before launch** (never compute something the client stopped
  waiting for) and counted separately from served ones.
- **Launch**: image models go through the backend's bucket executables
  (``DynamicImageBatcher.execute`` — plans pre-built at model load, bucket
  costs shared via the ``RouteCache``); LM models advance one
  ``ContinuousBatcher`` decode step per pump.  Every launch wall-time
  feeds a per-(model, bucket) ``StragglerMonitor``; flagged buckets
  surface as the slow-bucket alert in ``stats()``.
- **Replay** (the fault ladder): a ``FailureInjector`` (or a real
  ``NodeFailure``) firing at a launch boundary kills that launch's
  results.  The control plane re-queues the affected live requests at the
  *front* of their class queues in arrival order and replays them on the
  next pump — zero requests dropped, zero answered twice, and (the
  relaunches hit the same bucket executables on the same payloads)
  responses bit-equal to a fault-free run — asserted in
  ``tests/test_control_plane.py``.  When the failure means a lost replica,
  ``degrade`` shrinks the mesh via ``runtime.elastic.shrink_mesh`` and
  re-jits every image backend under the surviving data-parallel extent;
  with ``spatial_tiles=`` it instead re-tiles the survivors as a spatial
  mesh and re-plans plane-parallel ``dev_tiles`` (``core.spatial``).

Multi-model hosting: ``register_image_model`` / ``register_lm_model`` put
a GAN, a segnet, and a VAE (or anything with a ``serve_fn``) behind one
process; each backend pre-builds its plans at registration (model load)
and the batchers share one ``RouteCache`` for measured bucket costs.

``stats()`` reports per-class p50/p95/p99, **goodput under SLO** (served
within deadline / submitted — rejected, shed, and served-but-late all
count against it), fault/replay records, and the straggler alert; the
open-loop tail-latency harness in ``benchmarks/serve_bench.py`` turns the
same report into ``BENCH_slo.json``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import BATCH_BUCKETS
from repro.runtime.fault import NodeFailure, StragglerMonitor
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.image_batcher import DynamicImageBatcher
from repro.serving.metrics import latency_stats

PRIORITIES = ("interactive", "batch")


@dataclasses.dataclass
class ServeRequest:
    """One request under the control plane.

    Status lifecycle: ``queued`` -> ``served`` | ``rejected`` | ``shed``
    (a fault replay moves a request back to ``queued`` transiently and
    bumps ``replays``).  ``slo_ms=None`` means no deadline: never rejected
    or shed, excluded from the goodput denominator's miss accounting.
    """

    rid: int
    model: str
    payload: np.ndarray                     # image/latent, or (P,) int32 LM prompt
    priority: str = "interactive"
    slo_ms: Optional[float] = None
    max_new: int = 16                       # LM backends only
    # None = stamped by the control plane's injected clock at submit
    # (deadline tests / open-loop drivers stamp explicitly, same domain)
    t_arrival: Optional[float] = None
    t_done: Optional[float] = None
    out: Optional[np.ndarray] = None
    status: str = "queued"
    replays: int = 0
    reason: str = ""                        # why rejected / shed

    def __post_init__(self):
        if self.priority not in PRIORITIES:
            raise ValueError(f"priority must be one of {PRIORITIES}, "
                             f"got {self.priority!r}")

    @property
    def deadline(self) -> Optional[float]:
        if self.slo_ms is None or self.t_arrival is None:
            return None
        return self.t_arrival + self.slo_ms / 1e3

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done is None or self.t_arrival is None:
            return None
        return self.t_done - self.t_arrival

    @property
    def in_slo(self) -> Optional[bool]:
        """Served within deadline; ``None`` when no SLO was attached."""
        if self.slo_ms is None:
            return None
        return self.t_done is not None and self.t_done <= self.deadline


class ImageBackend:
    """Image/latent launch engine: wraps a ``DynamicImageBatcher`` for its
    per-bucket executables, measured bucket costs, and cover planning; the
    control plane owns admission and ordering (the batcher's internal
    queue stays empty in this mode)."""

    kind = "image"

    def __init__(self, name: str, serve_fn: Callable, proto: np.ndarray, *,
                 buckets: Sequence[int] = BATCH_BUCKETS,
                 max_wait_ms: float = 2.0, dist=None,
                 cache=None, cache_key: Optional[str] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.name = name
        self.proto = np.asarray(proto)
        self.batcher = DynamicImageBatcher(
            serve_fn, buckets=buckets, max_wait_ms=max_wait_ms, dist=dist,
            cache=cache, cache_key=cache_key or name, clock=clock)

    @property
    def max_wait_s(self) -> float:
        return self.batcher.max_wait_s

    @property
    def largest_bucket(self) -> int:
        return self.batcher.buckets[-1]

    def warmup(self, **kw):
        return self.batcher.warmup(self.proto, **kw)

    def next_launch_size(self, n: int) -> int:
        return self.batcher._first_launch_size(n)

    def estimate_s(self, ahead: list, req: ServeRequest) -> Optional[float]:
        """Admission estimate: measured cost of covering the ``ahead``
        backlog plus this request (``None`` until costs are measured)."""
        if not self.batcher.bucket_cost_s:
            return None
        n = len(ahead) + 1
        self.batcher._plan_cover(n)
        return self.batcher._sched_memo[n][0]

    def launch(self, payloads: Sequence[np.ndarray],
               bucket: int) -> np.ndarray:
        return self.batcher.execute(payloads, bucket)

    def rebind(self, dist, serve_fn: Optional[Callable] = None):
        self.batcher.rebind_dist(dist, serve_fn)


class LMBackend:
    """LM slot-scheduler backend: the control plane feeds admitted prompts
    into a ``ContinuousBatcher`` as slots free up (priority order held at
    the control-plane queue, not inside the batcher) and advances it one
    decode step per pump.  On device loss every in-flight slot is evicted
    — caches reset, partial output discarded — and the requests go back
    to the control plane for replay (greedy decode is deterministic, so a
    replayed request's tokens are bit-equal to a fault-free run)."""

    kind = "lm"
    max_wait_s = 0.0                        # LM decodes continuously

    def __init__(self, name: str, cfg, params, *, slots: int = 4,
                 max_len: int = 128, memory=None):
        self.name = name
        self.cb = ContinuousBatcher(cfg, params, slots=slots,
                                    max_len=max_len, memory=memory)
        self._wrapped: dict[int, ServeRequest] = {}
        self._consumed = 0                  # cb.done prefix already reported
        self.steps = 0
        self.step_cost_s: Optional[float] = None

    def free_slots(self) -> int:
        return sum(1 for s in self.cb.slots if s.req is None)

    def active(self) -> bool:
        return bool(self.cb.queue) or any(s.req for s in self.cb.slots)

    def feed(self, sreq: ServeRequest):
        self._wrapped[sreq.rid] = sreq
        self.cb.submit(Request(rid=sreq.rid,
                               prompt=np.asarray(sreq.payload, np.int32),
                               max_new=sreq.max_new))

    def estimate_s(self, ahead: list, req: ServeRequest) -> Optional[float]:
        """Admission estimate: backlog tokens spread over the slots, plus
        this request's own prefill + decode, at the EWMA step cost."""
        if self.step_cost_s is None:
            return None
        backlog = sum(len(r.payload) + r.max_new for r in ahead)
        own = len(req.payload) + req.max_new
        return (backlog / max(1, self.cb.n) + own) * self.step_cost_s

    def step(self) -> list[ServeRequest]:
        """One decode step; returns the requests that finished on it."""
        t0 = time.perf_counter()
        self.cb.step()
        dt = time.perf_counter() - t0
        self.steps += 1
        self.step_cost_s = (dt if self.step_cost_s is None
                            else 0.8 * self.step_cost_s + 0.2 * dt)
        finished = []
        for r in self.cb.done[self._consumed:]:
            sreq = self._wrapped.pop(r.rid)
            sreq.out = np.asarray(r.out, np.int32)
            sreq.t_done = r.t_done
            finished.append(sreq)
        self._consumed = len(self.cb.done)
        return finished

    def evict_live(self) -> list[ServeRequest]:
        """Device loss mid-step: evict every in-flight slot and queued
        request, reset the slot caches, and hand the ``ServeRequest``s
        back for control-plane re-queue + replay."""
        live = []
        for si, s in enumerate(self.cb.slots):
            if s.req is not None:
                live.append(self._wrapped.pop(s.req.rid))
                s.req, s.pos, s.prompt_left = None, 0, 0
                self.cb.slot_caches[si] = jax.tree.map(jnp.copy,
                                                       self.cb.cache1)
        while self.cb.queue:
            live.append(self._wrapped.pop(self.cb.queue.popleft().rid))
        return live


class ControlPlane:
    """Admission + scheduling + fault replay over registered backends.

    ``injector`` is a ``runtime.fault.FailureInjector`` keyed by *launch
    sequence number* (every image bucket launch and every LM decode step
    increments it) — ``FailureInjector((3,))`` kills the third launch
    mid-batch, exercising the re-queue/replay path on purpose.
    """

    def __init__(self, *, starvation_ms: float = 50.0, injector=None,
                 admission: bool = True, straggler_k: float = 3.0,
                 straggler_warmup: int = 3,
                 on_fault: Optional[Callable] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.backends: dict[str, object] = {}
        self.queues: dict[str, dict[str, deque]] = {}
        self.starvation_s = starvation_ms / 1e3
        # ONE monotonic clock for every scheduling timestamp: arrivals,
        # admission ('now + est > deadline'), shedding ('now > deadline'),
        # max-wait expiry — and it is handed down to every image backend's
        # batcher, so admission and the batcher's coalescing deadline can
        # never disagree about 'now'.  Compute-cost durations (_observe
        # timing) stay on time.perf_counter: they measure the device.
        self.clock = clock
        self.injector = injector
        self.admission = admission
        self.on_fault = on_fault
        self.done: list[ServeRequest] = []
        self.rejected: list[ServeRequest] = []
        self.shed: list[ServeRequest] = []
        self.submitted = 0
        self._submitted_by_class = {c: 0 for c in PRIORITIES}
        self.launch_seq = 0
        self.fault_events: list[dict] = []
        self.degraded: Optional[dict] = None
        self._served_rids: set = set()      # zero-duplicate guard
        self.monitors: dict[tuple, StragglerMonitor] = {}
        self._straggler_kw = dict(k=straggler_k, warmup=straggler_warmup)
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None

    # -- model registration (model load: plans pre-built here) ---------------
    def register_image_model(self, name: str, serve_fn: Callable,
                             proto: np.ndarray, *, warmup: bool = False,
                             **kw) -> ImageBackend:
        kw.setdefault("clock", self.clock)   # one clock, both layers
        be = ImageBackend(name, serve_fn, proto, **kw)
        self._register(name, be)
        if warmup:
            be.warmup()
        return be

    def register_lm_model(self, name: str, cfg, params,
                          **kw) -> LMBackend:
        be = LMBackend(name, cfg, params, **kw)
        self._register(name, be)
        return be

    def _register(self, name, be):
        if name in self.backends:
            raise ValueError(f"model {name!r} already registered")
        self.backends[name] = be
        self.queues[name] = {c: deque() for c in PRIORITIES}

    def warmup(self):
        """Compile every image backend's bucket executables up front."""
        for be in self.backends.values():
            if isinstance(be, ImageBackend):
                be.warmup()

    # -- admission ------------------------------------------------------------
    def submit(self, req: ServeRequest) -> bool:
        """Admit or reject (``False``) a request.  Rejection happens only
        when the measured-cost estimate for the backlog ahead of the
        request already exceeds its deadline."""
        if req.model not in self.backends:
            raise ValueError(f"unknown model {req.model!r} "
                             f"(registered: {sorted(self.backends)})")
        self.submitted += 1
        self._submitted_by_class[req.priority] += 1
        if req.t_arrival is None:
            req.t_arrival = self.clock()
        if self._t_first is None:
            self._t_first = self.clock()
        ddl = req.deadline
        if ddl is not None and self.admission:
            ahead = self._ahead_of(req)
            est = self.backends[req.model].estimate_s(ahead, req)
            if est is not None and self.clock() + est > ddl:
                req.status = "rejected"
                req.reason = (f"admission: backlog estimate {est * 1e3:.2f} "
                              f"ms blows slo {req.slo_ms:.2f} ms")
                self.rejected.append(req)
                return False
        self.queues[req.model][req.priority].append(req)
        return True

    def _ahead_of(self, req: ServeRequest) -> list:
        """Queued requests that will be scheduled before ``req``:
        same-class backlog, plus the interactive queue for a batch
        request (interactive preempts batch up to the starvation bound)."""
        q = self.queues[req.model]
        ahead = list(q[req.priority])
        if req.priority == "batch":
            ahead += list(q["interactive"])
        return ahead

    # -- scheduling -----------------------------------------------------------
    def _pick_class(self, q: dict, now: float) -> str:
        """Interactive first; a batch head past the starvation bound (or an
        empty interactive queue) flips the choice."""
        inter, batch = q["interactive"], q["batch"]
        if batch and (not inter
                      or now - batch[0].t_arrival >= self.starvation_s):
            return "batch"
        return "interactive" if inter else "batch"

    def _launch_due(self, name: str, now: float, drain: bool) -> bool:
        be, q = self.backends[name], self.queues[name]
        n = len(q["interactive"]) + len(q["batch"])
        if n == 0:
            return False
        if drain or n >= be.largest_bucket:
            return True
        heads = [c[0] for c in q.values() if c]
        oldest = min(h.t_arrival for h in heads)
        if now - oldest >= be.max_wait_s:
            return True
        # deadline urgency: coalescing any longer would blow the head SLO
        ddls = [h.deadline for h in heads if h.deadline is not None]
        return bool(ddls) and min(ddls) - now <= be.max_wait_s

    def pump(self, *, drain: bool = False) -> list[ServeRequest]:
        """One scheduling round: advance every LM backend a step, launch at
        most one image bucket; returns the requests completed."""
        now = self.clock()
        finished = self._pump_lm(now)
        due = [n for n, b in self.backends.items()
               if isinstance(b, ImageBackend) and self._launch_due(n, now,
                                                                   drain)]
        if due:
            # EDF across models: earliest head-of-line deadline wins
            def urgency(name):
                heads = [c[0] for c in self.queues[name].values() if c]
                ddl = min((h.deadline for h in heads
                           if h.deadline is not None), default=float("inf"))
                return (ddl, min(h.t_arrival for h in heads))
            name = min(due, key=urgency)
            finished += self._launch_image(name, now)
        return finished

    def _take(self, name: str, cls: str, want: int,
              now: float) -> list[ServeRequest]:
        """Pop up to ``want`` launchable requests from one class queue,
        shedding the expired (deadline already passed — never compute what
        the client stopped waiting for)."""
        out, q = [], self.queues[name][cls]
        while q and len(out) < want:
            r = q.popleft()
            ddl = r.deadline
            if ddl is not None and now > ddl:
                r.status = "shed"
                r.reason = f"shed: deadline passed {(now - ddl) * 1e3:.2f} ms ago"
                self.shed.append(r)
            else:
                out.append(r)
        return out

    def _launch_image(self, name: str, now: float) -> list[ServeRequest]:
        be, q = self.backends[name], self.queues[name]
        cls = self._pick_class(q, now)
        n = len(q["interactive"]) + len(q["batch"])
        size = be.next_launch_size(n)
        reqs = self._take(name, cls, size, now)
        other = "batch" if cls == "interactive" else "interactive"
        reqs += self._take(name, other, size - len(reqs), now)  # backfill
        if not reqs:
            return []
        return self._execute(be, reqs, size)

    def _pump_lm(self, now: float) -> list[ServeRequest]:
        finished = []
        for name, be in self.backends.items():
            if not isinstance(be, LMBackend):
                continue
            q = self.queues[name]
            while be.free_slots() and (q["interactive"] or q["batch"]):
                for r in self._take(name, self._pick_class(q, now), 1, now):
                    be.feed(r)
            if not be.active():
                continue
            self.launch_seq += 1
            try:
                if self.injector is not None:
                    self.injector.check(self.launch_seq)
                t0 = time.perf_counter()
                done = be.step()
                self._observe(be.name, "step", time.perf_counter() - t0)
            except NodeFailure as e:
                self._on_failure(be, be.evict_live(), e)
                continue
            for r in done:
                self._commit(r)
            finished += done
        return finished

    # -- launch + replay ------------------------------------------------------
    def _execute(self, be: ImageBackend, reqs: list[ServeRequest],
                 bucket: int) -> list[ServeRequest]:
        self.launch_seq += 1
        t0 = time.perf_counter()
        try:
            if self.injector is not None:
                self.injector.check(self.launch_seq)   # device lost mid-batch
            outs = be.launch([r.payload for r in reqs], bucket)
        except NodeFailure as e:
            self._on_failure(be, reqs, e)
            return []
        self._observe(be.name, bucket, time.perf_counter() - t0)
        now = self.clock()
        for r, out in zip(reqs, outs):
            r.out = out
            r.t_done = now
            self._commit(r)
        return reqs

    def _commit(self, r: ServeRequest):
        if r.rid in self._served_rids:
            raise AssertionError(f"request {r.rid} answered twice")
        self._served_rids.add(r.rid)
        r.status = "served"
        self.done.append(r)
        self._t_last = self.clock()

    def _on_failure(self, be, live: list[ServeRequest], err: Exception):
        """The fault ladder, rung one: discard the dead launch, re-queue
        its live requests at the front of their class queues in arrival
        order, and replay on the next pump.  Rung two (replica actually
        lost) is ``degrade``, reachable via the ``on_fault`` hook."""
        self.fault_events.append({
            "launch": self.launch_seq, "model": be.name,
            "live": len(live), "error": str(err)})
        for r in sorted(live, key=lambda r: (r.t_arrival, r.rid),
                        reverse=True):
            r.replays += 1
            r.status = "queued"
            r.out = None
            r.t_done = None
            self.queues[be.name][r.priority].appendleft(r)
        if self.on_fault is not None:
            self.on_fault(self, err)

    def degrade(self, devices_left: int, *, model_parallel: int = 1,
                pod: int = 0, serve_fns: Optional[dict] = None,
                spatial_tiles: Optional[tuple] = None):
        """Degraded serving after replica loss: shrink the mesh to the
        surviving chips and re-jit every image backend under the new
        extent.  ``serve_fns`` optionally maps model name -> a rebuilt
        closure over re-placed params (the ``elastic.restore_on_mesh``
        path); without it the existing closures re-jit under the shrunk
        mesh.

        Data-parallel (default): ``runtime.elastic.shrink_mesh`` — TP
        preserved, whole DP replicas dropped.

        Plane-parallel (``spatial_tiles=(D_h, D_w)``): the survivors are
        re-tiled as a spatial mesh (``launch.mesh.make_spatial_mesh``,
        leftover extent on the leading 'data' axis) and installed as the
        active spatial mesh, so plans re-built against the new tiling emit
        matching ``dev_tiles`` verdicts.  ``serve_fns`` should close over
        model configs whose ``spatial=`` matches ``spatial_tiles`` — that
        is the re-plan: the new closures trace through the shard_map
        executor on the shrunk mesh."""
        from repro.sharding import DistContext
        if spatial_tiles is not None:
            from repro.core import spatial as spatialmod
            from repro.launch.mesh import make_spatial_mesh
            sp_h, sp_w = (int(v) for v in spatial_tiles)
            if devices_left % (sp_h * sp_w):
                raise ValueError(
                    f"degrade: spatial_tiles {sp_h}x{sp_w} does not divide "
                    f"{devices_left} surviving devices")
            mesh = make_spatial_mesh(
                sp_h, sp_w, data=devices_left // (sp_h * sp_w))
            spatialmod.set_spatial_mesh(mesh)
        else:
            from repro.runtime.elastic import shrink_mesh
            mesh = shrink_mesh(devices_left, model_parallel, pod)
        dist = DistContext(mesh=mesh)
        for name, be in self.backends.items():
            if isinstance(be, ImageBackend):
                be.rebind(dist, (serve_fns or {}).get(name))
        self.degraded = {"devices_left": devices_left,
                         "mesh_shape": dict(mesh.shape),
                         "at_launch": self.launch_seq}
        if spatial_tiles is not None:
            self.degraded["spatial_tiles"] = (sp_h, sp_w)
        return mesh

    def _observe(self, model: str, bucket, dt: float):
        key = (model, bucket)
        if key not in self.monitors:
            self.monitors[key] = StragglerMonitor(**self._straggler_kw)
        self.monitors[key].record(self.launch_seq, dt)

    # -- drivers --------------------------------------------------------------
    def run(self, reqs: Optional[Sequence[ServeRequest]] = None,
            *, max_pumps: int = 100_000) -> list[ServeRequest]:
        """Submit ``reqs`` and pump to empty (drain mode)."""
        for r in reqs or ():
            self.submit(r)
        pumps = 0
        while self.pending() and pumps < max_pumps:
            self.pump(drain=True)
            pumps += 1
        return self.done

    def pending(self) -> int:
        n = sum(len(c) for q in self.queues.values() for c in q.values())
        n += sum(1 for be in self.backends.values()
                 if isinstance(be, LMBackend) and be.active())
        return n

    def results(self) -> dict[int, np.ndarray]:
        return {r.rid: r.out for r in self.done}

    # -- reporting ------------------------------------------------------------
    def stats(self) -> dict:
        window = None
        if self._t_first is not None and self._t_last is not None:
            window = self._t_last - self._t_first
        per_class = {}
        for cls in PRIORITIES:
            rs = [r for r in self.done if r.priority == cls]
            st = latency_stats([r.latency_s for r in rs], window_s=window)
            good = sum(1 for r in rs if r.in_slo is not False)
            n_sub = self._submitted_by_class[cls]
            st["slo_miss"] = sum(1 for r in rs if r.in_slo is False)
            st["rejected"] = sum(1 for r in self.rejected
                                 if r.priority == cls)
            st["shed"] = sum(1 for r in self.shed if r.priority == cls)
            st["goodput_rps"] = (good / window if window else 0.0)
            st["goodput_under_slo"] = (good / n_sub) if n_sub else 1.0
            per_class[cls] = st
        per_model = {}
        for name, be in self.backends.items():
            served = sum(1 for r in self.done if r.model == name)
            m = {"kind": be.kind, "served": served}
            if isinstance(be, ImageBackend):
                launches = be.batcher.launches
                m["launches"] = len(launches)
                m["pad_fraction"] = (
                    1.0 - (sum(live for _, live in launches)
                           / max(1, sum(b for b, _ in launches))))
            else:
                m["steps"] = be.steps
                m["step_cost_ms"] = (None if be.step_cost_s is None
                                     else be.step_cost_s * 1e3)
            per_model[name] = m
        slow = sorted(f"{m}/b{b}" for (m, b), mon in self.monitors.items()
                      if mon.events)
        good = sum(1 for r in self.done if r.in_slo is not False)
        return {
            "submitted": self.submitted,
            "served": len(self.done),
            "rejected": len(self.rejected),
            "shed": len(self.shed),
            "queued": self.pending(),
            "replayed_requests": sum(1 for r in self.done if r.replays),
            "goodput_rps": (good / window if window else 0.0),
            "goodput_under_slo": ((good / self.submitted)
                                  if self.submitted else 1.0),
            "per_class": per_class,
            "per_model": per_model,
            "faults": {"events": len(self.fault_events),
                       "records": list(self.fault_events),
                       "degraded": self.degraded},
            "stragglers": {
                "events": sum(len(m.events) for m in self.monitors.values()),
                "slow_buckets": slow},
        }
