"""Dynamic batching for image/latent serving (the bucket-aware sibling of
the LM slot scheduler in ``serving/batcher.py``).

Generative-image requests are single tensors (a latent vector for a GAN /
VAE decoder, an image for segmentation) with no autoregressive state, so
the scheduling problem is pure *coalescing*: gather whatever is queued,
pad it up to the nearest plan batch bucket (``core.plan.BATCH_BUCKETS`` —
the sizes every ``ConvPlan`` routed at build time), and launch one jitted
call.  The bucket set keeps the number of compiled executables bounded
(one jit per bucket, compiled on first use or eagerly via ``warmup``) and
keeps execution on plan-time routes — ``route_for_batch`` never has to
size a route for an arbitrary traced batch.

Scheduling policy (classic dynamic batching, cf. TF-Serving / Triton):

- launch immediately when a full largest bucket is queued;
- otherwise wait for more arrivals, but never longer than
  ``max_wait_ms`` past the oldest request's arrival — then serve the queue
  on bucket-sized launches, padding the tail;
- ``drain=True`` (offline / shutdown) flushes without waiting.

**Cost-aware launch planning.**  Buckets quantize compile count, but the
mapping queue-length -> launch sizes is a policy choice: padding 5 requests
up to bucket 16 can cost 2x a bucket-4 launch plus a single.  ``warmup``
therefore *measures* each bucket's launch wall-time (the serving analog of
the engine's plan-time route choice), and the scheduler covers the queue
with the bucket multiset minimizing total measured cost (a tiny
coin-change DP, memoized per queue length).  Until costs are measured the
policy degrades to round-up-to-nearest-bucket.

The measured costs can be **persisted**: pass a ``repro.core.autotune``
``RouteCache`` (plus a ``cache_key`` naming the served model) and the
batcher preloads ``bucket_cost_s`` from the cache at construction and
writes back any buckets ``warmup`` measures — a restarted server with a
warm cache compiles its buckets but re-measures none of them.

Data-parallel serving: pass a ``DistContext`` and the batcher constrains
the batched input over the mesh's data axes inside the jitted call, so the
padded bucket shards across devices under ``NamedSharding`` (weights are
sharded at init by the model's ``dist``-aware ``*_init``).

Under the SLO-aware control plane (``serving/control_plane.py``) this
batcher is no longer a peer entry point but the *launch engine* of an
``ImageBackend``: the control plane owns admission/priorities/deadlines
and calls ``execute`` directly; ``rebind_dist`` is its elastic-degrade
hook after replica loss.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional, Sequence

import jax
import numpy as np

from repro.core.plan import BATCH_BUCKETS
from repro.serving.metrics import latency_stats


@dataclasses.dataclass
class ImageRequest:
    rid: int
    payload: np.ndarray                    # (z_dim,) latent or (H, W, C) image
    # None = stamped by the batcher's injected clock at submit (open-loop
    # drivers stamp scheduled arrivals explicitly, in the same clock domain)
    t_arrival: Optional[float] = None
    t_done: Optional[float] = None
    out: Optional[np.ndarray] = None

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done is None or self.t_arrival is None:
            return None
        return self.t_done - self.t_arrival


class DynamicImageBatcher:
    """Coalesce image requests into plan batch buckets, one jit per bucket.

    ``serve_fn(batch) -> batch`` is the model forward with parameters
    already bound (e.g. ``lambda z: generator_apply(params, z, cfg)``); the
    batcher jits it once and relies on shape specialization for the
    per-bucket executables.
    """

    def __init__(self, serve_fn: Callable, *,
                 buckets: Sequence[int] = BATCH_BUCKETS,
                 max_wait_ms: float = 2.0, dist=None,
                 cache=None, cache_key: Optional[str] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.buckets = tuple(sorted(int(b) for b in buckets))
        if not self.buckets or self.buckets[0] < 1:
            raise ValueError(f"bad buckets {buckets}")
        self.max_wait_s = max_wait_ms / 1e3
        # ONE monotonic clock for every scheduling timestamp (arrival,
        # max-wait expiry, completion).  The control plane injects its own
        # clock here so a request can't be admitted under one clock and
        # deadline-expired under another; compute-cost *durations*
        # (``warmup`` timing loops) stay on ``time.perf_counter`` — they
        # measure the device, not the schedule.
        self.clock = clock
        # bucket-cost persistence: a repro.core.autotune.RouteCache plus a
        # key naming the served model (costs are per model + per host)
        self.cache = cache
        self.cache_key = cache_key
        self.rebind_dist(dist, serve_fn)
        self.queue: deque[ImageRequest] = deque()
        self.done: list[ImageRequest] = []
        self.launches: list[tuple[int, int]] = []   # (bucket, live) per call
        self.bucket_cost_s: dict[int, float] = {}   # measured by warmup
        if cache is not None and cache_key is not None:
            self.bucket_cost_s = {
                b: c for b, c in cache.get_bucket_costs(cache_key).items()
                if b in self.buckets}
        self._sched_memo: dict[int, tuple[float, int]] = {0: (0.0, 0)}
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None

    def rebind_dist(self, dist, serve_fn: Optional[Callable] = None):
        """(Re-)jit the serve closure under ``dist`` — the elastic-degrade
        path: after replica loss the control plane shrinks the mesh and
        rebinds every backend to the surviving data-parallel extent.
        Bucket executables recompile lazily on the next launch; measured
        costs are kept (same kernels, fewer replicas — ``warmup(force=
        True)`` re-measures).  ``serve_fn`` defaults to the current one
        (pass a rebuilt closure when params were re-placed via
        ``elastic.restore_on_mesh``)."""
        self.dist = dist
        if serve_fn is not None:
            self._serve_fn = serve_fn
        fn = self._serve_fn

        def batched(x):
            if dist is not None:
                x = dist.constrain(x, dist.image_spec())
            return fn(x)

        if dist is not None and dist.spatial_tiles() != (1, 1):
            # plane-parallel serving: bind the mesh as the active spatial
            # mesh while tracing, so conv plans whose routes carry matching
            # ``dev_tiles`` dispatch through the shard_map executor.  The
            # binding only matters at trace time — compiled bucket
            # executables keep the sharded program afterwards.
            from repro.core import spatial as _spatial
            inner = batched

            def batched(x, _inner=inner):
                with _spatial.use_spatial_mesh(dist.mesh):
                    return _inner(x)

        self._serve = jax.jit(batched)

    # -- client API ----------------------------------------------------------
    def submit(self, req: ImageRequest):
        if req.t_arrival is None:
            req.t_arrival = self.clock()
        if self._t_first is None:
            self._t_first = self.clock()
        self.queue.append(req)

    def bucket_for(self, n: int) -> int:
        """Smallest bucket that fits ``n`` (the largest bucket caps a launch)."""
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def warmup(self, proto: Optional[np.ndarray] = None, *, iters: int = 2,
               force: bool = False) -> tuple[int, ...]:
        """Eagerly compile every bucket (zeros payload) so serving latency
        never includes a compile, and *measure* each bucket's launch cost
        (min of ``iters``) for the cost-aware scheduler.  Buckets whose cost
        was preloaded from the route cache are compiled but NOT re-timed
        unless ``force=True`` — a restarted server with a warm cache pays
        zero measurement loops.  Newly measured costs are written back to
        the cache (when one is attached).  ``proto`` is one request payload
        (shape/dtype template); defaults to the oldest queued request's.
        Returns the buckets that were actually timed."""
        if proto is None:
            if not self.queue:
                raise ValueError("warmup needs a proto payload or a queued "
                                 "request for the shape")
            proto = self.queue[0].payload
        timed = []
        for b in self.buckets:
            x = jax.numpy.asarray(np.zeros((b,) + proto.shape, proto.dtype))
            jax.block_until_ready(self._serve(x))       # compile
            if b in self.bucket_cost_s and not force:
                continue                                # cache hit: no timing
            ts = []
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(self._serve(x))
                ts.append(time.perf_counter() - t0)
            self.bucket_cost_s[b] = min(ts)
            timed.append(b)
        self._sched_memo = {0: (0.0, 0)}                # rebuild on new costs
        if timed and self.cache is not None and self.cache_key is not None:
            self.cache.put_bucket_costs(self.cache_key, self.bucket_cost_s)
            self.cache.save()
        return tuple(timed)

    def _first_launch_size(self, n: int) -> int:
        """Bucket of the next launch for a queue of ``n``: head of the
        cheapest bucket cover under the measured costs (largest-first so
        the most waiters complete earliest), else round-up-to-bucket."""
        if not self.bucket_cost_s:
            return self.bucket_for(n)
        best = max(self._plan_cover(n))
        return best

    def _plan_cover(self, n: int) -> tuple[int, ...]:
        """Bucket multiset covering ``n`` requests at minimum measured cost
        (classic coin-change DP over launch sizes; overshoot = tail pad)."""
        memo = self._sched_memo
        for i in range(1, n + 1):                        # bottom-up, O(n·|B|)
            if i not in memo:
                memo[i] = min(
                    (self.bucket_cost_s[b] + memo[max(0, i - b)][0], b)
                    for b in self.buckets)
        cover, k = [], n
        while k > 0:
            b = memo[k][1]
            cover.append(b)
            k = max(0, k - b)
        return tuple(cover)

    # -- scheduler -----------------------------------------------------------
    def pump(self, *, drain: bool = False) -> list[ImageRequest]:
        """Launch at most one batch if the policy says go; returns the
        requests completed by that launch (empty when still coalescing)."""
        if not self.queue:
            return []
        now = self.clock()
        full = len(self.queue) >= self.buckets[-1]
        expired = now - self.queue[0].t_arrival >= self.max_wait_s
        if not (full or expired or drain):
            return []
        size = self._first_launch_size(len(self.queue))
        take = min(len(self.queue), size)
        reqs = [self.queue.popleft() for _ in range(take)]
        return self._launch(reqs, bucket=size)

    def run(self, reqs=None, *, drain: bool = True) -> list[ImageRequest]:
        """Submit ``reqs`` (optional) and pump until the queue is empty.
        With ``drain=False`` the loop sleeps out the oldest request's
        max-wait deadline instead of spinning on empty pumps."""
        for r in reqs or ():
            self.submit(r)
        while self.queue:
            if not self.pump(drain=drain) and not drain and self.queue:
                wait = self.max_wait_s - (self.clock()
                                          - self.queue[0].t_arrival)
                if wait > 0:
                    time.sleep(min(wait, 1e-3))
        return self.done

    def execute(self, rows: Sequence[np.ndarray],
                bucket: Optional[int] = None) -> np.ndarray:
        """Pad ``rows`` up to ``bucket`` and run ONE jitted launch,
        returning the live output rows with no request bookkeeping — the
        control plane's entry point (``serving.control_plane`` owns its
        own queues and uses this batcher purely as the launch engine).
        The launch is still recorded in ``launches`` so pad-fraction
        stats cover both callers."""
        bucket = self.bucket_for(len(rows)) if bucket is None else bucket
        batch = np.stack([np.asarray(r) for r in rows])
        if len(rows) < bucket:                       # pad the tail
            pad = np.zeros((bucket - len(rows),) + batch.shape[1:],
                           batch.dtype)
            batch = np.concatenate([batch, pad])
        out = jax.block_until_ready(self._serve(jax.numpy.asarray(batch)))
        self.launches.append((bucket, len(rows)))
        return np.asarray(out)[:len(rows)]

    def _launch(self, reqs: list[ImageRequest],
                bucket: Optional[int] = None) -> list[ImageRequest]:
        out = self.execute([r.payload for r in reqs], bucket)
        now = self.clock()
        for i, r in enumerate(reqs):
            r.out = out[i]
            r.t_done = now
        self.done.extend(reqs)
        self._t_last = now
        return reqs

    def reset_stats(self):
        """Drop request/launch history for a fresh measurement window; the
        compiled bucket executables and measured costs are kept (benchmark
        repeats must not pay recompilation)."""
        self.queue.clear()
        self.done = []
        self.launches = []
        self._t_first = self._t_last = None

    # -- open-loop driver (shared by the serve examples / benches) -----------
    def drive_open_loop(self, make_payload: Callable[[int], np.ndarray],
                        requests: int, rate: float = 0.0
                        ) -> list[ImageRequest]:
        """Submit ``requests`` payloads at ``rate`` req/s (0 = one burst),
        pumping as arrivals trickle in, then drain the tail."""
        gap = 1.0 / rate if rate > 0 else 0.0
        for i in range(requests):
            if gap:
                time.sleep(gap)
            self.submit(ImageRequest(rid=i, payload=make_payload(i)))
            self.pump()
        return self.run()

    # -- reporting -----------------------------------------------------------
    def stats(self) -> dict:
        window = None
        if self._t_first is not None and self._t_last is not None:
            window = self._t_last - self._t_first
        st = latency_stats([r.latency_s for r in self.done], window_s=window)
        st["launches"] = len(self.launches)
        st["bucket_histogram"] = {
            b: sum(1 for bb, _ in self.launches if bb == b)
            for b in self.buckets}
        st["pad_fraction"] = (
            1.0 - (sum(live for _, live in self.launches)
                   / max(1, sum(b for b, _ in self.launches))))
        return st
