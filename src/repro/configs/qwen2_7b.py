"""qwen2-7b [dense] — GQA with QKV bias, arXiv:2407.10671.
28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064."""
from repro.configs.base import ModelConfig, uniform_stages


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b", family="dense", num_layers=28, d_model=3584,
        num_heads=28, num_kv_heads=4, head_dim=128, d_ff=18944,
        vocab_size=152064, stages=uniform_stages("attn", 28),
        qkv_bias=True, rope_theta=1e6, norm_eps=1e-6,
    )


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
        stages=uniform_stages("attn", 2))
