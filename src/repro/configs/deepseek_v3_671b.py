"""deepseek-v3-671b [moe] — MLA + 256-expert top-8 aux-free MoE,
arXiv:2412.19437.  61L d_model=7168 128H, vocab=129280; first 3 layers dense
(d_ff 18432), 58 MoE layers with 1 shared + 256 routed (d_expert 2048).
MTP head omitted (noted in DESIGN.md)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe", num_layers=61, d_model=7168,
        num_heads=128, num_kv_heads=128, head_dim=192, d_ff=18432,
        vocab_size=129280,
        stages=((("mla",), 3), (("mla_moe",), 58)),
        use_mla=True, q_lora_rank=1536, kv_lora_rank=512, qk_rope_dim=64,
        qk_nope_dim=128, v_head_dim=128,
        n_experts=256, n_shared=1, top_k=8, d_expert=2048,
        router_type="sigmoid_bias", routed_scaling=2.5, moe_impl="ep",
        rope_theta=1e4, norm_eps=1e-6,
    )


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, q_lora_rank=32, kv_lora_rank=16,
        qk_rope_dim=8, qk_nope_dim=16, v_head_dim=16, n_experts=8, top_k=2,
        d_expert=32, moe_impl="dense",
        stages=((("mla",), 1), (("mla_moe",), 2)))
