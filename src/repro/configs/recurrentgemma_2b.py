"""recurrentgemma-2b [hybrid] — RG-LRU + local attention 2:1 (Griffin),
arXiv:2402.19427.  26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000,
lru_width=2560, local window 2048."""
from repro.configs.base import ModelConfig, patterned_stages


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid", num_layers=26,
        d_model=2560, num_heads=10, num_kv_heads=1, head_dim=256, d_ff=7680,
        vocab_size=256000,
        stages=patterned_stages(["rec", "rec", "local"], 26),
        window=2048, lru_width=2560, conv_width=4,
        gemma_norm=True, tie_embeddings=True, subquadratic=True,
        rope_theta=1e4, norm_eps=1e-6, act="gelu",
    )


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=3, d_model=64, num_heads=2, num_kv_heads=1,
        head_dim=32, d_ff=128, vocab_size=512, window=8, lru_width=64,
        stages=patterned_stages(["rec", "rec", "local"], 3))
