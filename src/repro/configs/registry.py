"""Architecture registry: ``--arch <id>`` resolution for all launchers."""
from __future__ import annotations

import importlib

_MODULES = {
    "mamba2-130m": "repro.configs.mamba2_130m",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "glm4-9b": "repro.configs.glm4_9b",
    "llama3.2-1b": "repro.configs.llama32_1b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
}

ARCH_IDS = tuple(_MODULES)


def get_config(name: str):
    return importlib.import_module(_MODULES[name]).config()


def get_reduced(name: str):
    return importlib.import_module(_MODULES[name]).reduced()


def shape_applicable(cfg, shape) -> tuple[bool, str]:
    """Which (arch x shape) cells run — skips recorded in EXPERIMENTS.md."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 512k dense-KV decode skipped per brief"
    return True, ""
