"""qwen2-vl-2b [vlm] — M-RoPE + dynamic resolution, arXiv:2409.12191.
28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.

The vision frontend is a STUB per the brief: ``input_specs()`` supplies
precomputed patch embeddings (B, S, d_model); the backbone applies M-RoPE
over (temporal, height, width) position ids.
"""
from repro.configs.base import ModelConfig, uniform_stages


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", family="vlm", num_layers=28, d_model=1536,
        num_heads=12, num_kv_heads=2, head_dim=128, d_ff=8960,
        vocab_size=151936, stages=uniform_stages("attn", 28),
        qkv_bias=True, rope_theta=1e6, mrope_sections=(16, 24, 24),
        frontend="vlm_stub", norm_eps=1e-6,
    )


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, mrope_sections=(2, 3, 3),
        stages=uniform_stages("attn", 2))
