"""gemma3-1b [dense] — 5:1 local:global attention, 128k-capable,
hf:google/gemma-3-1b-pt.  26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144; sliding window 512; qk-norm; sandwich norms; tied embeddings.

long_500k note: local layers are window-capped (512); the 1-in-6 global
layers attend over the full cache — decode stays O(S) per token, memory is
dominated by the 4 global-layer caches (sharded over 'data').
"""
from repro.configs.base import ModelConfig, patterned_stages


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", family="dense", num_layers=26, d_model=1152,
        num_heads=4, num_kv_heads=1, head_dim=256, d_ff=6912,
        vocab_size=262144,
        stages=patterned_stages(["local"] * 5 + ["global"], 26),
        window=512, rope_theta=1e6, rope_theta_local=1e4,
        qk_norm=True, gemma_norm=True, sandwich_norm=True,
        tie_embeddings=True, subquadratic=True, norm_eps=1e-6,
        act="gelu",
    )


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=3, d_model=64, num_heads=2, num_kv_heads=1,
        head_dim=32, d_ff=128, vocab_size=512, window=8,
        stages=patterned_stages(["local", "local", "global"], 3))
