"""glm4-9b [dense] — RoPE + GQA, hf:THUDM/glm-4-9b.
40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552."""
from repro.configs.base import ModelConfig, uniform_stages


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", family="dense", num_layers=40, d_model=4096,
        num_heads=32, num_kv_heads=2, head_dim=128, d_ff=13696,
        vocab_size=151552, stages=uniform_stages("attn", 40),
        rope_theta=1e4, norm_eps=1.5625e-7,
    )


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
        stages=uniform_stages("attn", 2))
