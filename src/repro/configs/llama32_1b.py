"""llama3.2-1b [dense] — small llama3, hf:meta-llama/Llama-3.2-1B.
16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256; tied embeddings."""
from repro.configs.base import ModelConfig, uniform_stages


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b", family="dense", num_layers=16, d_model=2048,
        num_heads=32, num_kv_heads=8, head_dim=64, d_ff=8192,
        vocab_size=128256, stages=uniform_stages("attn", 16),
        rope_theta=5e5, tie_embeddings=True, norm_eps=1e-5,
    )


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
        stages=uniform_stages("attn", 2))
