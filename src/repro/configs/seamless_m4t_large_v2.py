"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal,
arXiv:2308.11596.  24L enc + 24L dec, d_model=1024 16H (kv=16) d_ff=8192
vocab=256206.

The speech/text frontend is a STUB: ``input_specs()`` supplies precomputed
source frame embeddings (B, S_src, d_model); the transformer backbone
(self-attn encoder, causal decoder with cross-attention) is implemented in
full.  Decode shapes exercise the decoder with a 3072-frame source memory.
"""
from repro.configs.base import ModelConfig, uniform_stages


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="audio", num_layers=48,
        d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64, d_ff=8192,
        vocab_size=256206,
        stages=uniform_stages("dec", 24),
        encoder_stages=uniform_stages("enc", 24),
        is_encoder_decoder=True, frontend="audio_stub",
        rope_theta=1e4, norm_eps=1e-5, act="gelu",
    )


SRC_FRAMES = 3072            # stub source length for decode/prefill shapes


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=512,
        stages=uniform_stages("dec", 2),
        encoder_stages=uniform_stages("enc", 2))
