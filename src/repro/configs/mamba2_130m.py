"""mamba2-130m [ssm] — SSD (state-space duality), arXiv:2405.21060.
24L d_model=768, attn-free, vocab=50280, ssm_state=128."""
from repro.configs.base import ModelConfig, uniform_stages


def config() -> ModelConfig:
    d = 768
    return ModelConfig(
        name="mamba2-130m", family="ssm", num_layers=24, d_model=d,
        num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=50280,
        stages=uniform_stages("ssd", 24),
        d_inner=2 * d, ssm_state=128, ssm_heads=(2 * d) // 64, ssm_groups=1,
        ssm_conv=4, ssm_chunk=128, tie_embeddings=True,
        subquadratic=True, norm_eps=1e-5,
    )


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, d_inner=128, ssm_heads=2,
        ssm_state=16, ssm_chunk=16, vocab_size=512,
        stages=uniform_stages("ssd", 2))
