"""dbrx-132b [moe] — 16 experts top-4 fine-grained MoE,
hf:databricks/dbrx-base.  40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352."""
from repro.configs.base import ModelConfig, uniform_stages


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe", num_layers=40, d_model=6144,
        num_heads=48, num_kv_heads=8, head_dim=128, d_ff=10752,
        vocab_size=100352,
        stages=uniform_stages("moe", 40),
        n_experts=16, n_shared=0, top_k=4, d_expert=10752,
        router_type="softmax", moe_impl="ep",
        rope_theta=5e5, norm_eps=1e-5,
    )


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, n_experts=4, top_k=2,
        d_expert=64, moe_impl="dense", stages=uniform_stages("moe", 2))
