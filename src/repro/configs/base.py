"""Model/config schema shared by all assigned architectures.

A model is a sequence of *stages*; a stage is a repeated *block* (tuple of
layer kinds) whose parameters are stacked along a leading repeat dim and
executed with ``lax.scan`` — heterogeneous stacks (gemma3 5:1 local:global,
griffin 2:1 recurrent:attention, deepseek 3 dense + 58 MoE) stay scan-able
and compile-time stays O(block), not O(depth).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

Stage = tuple[tuple[str, ...], int]          # (block layer kinds, repeats)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                              # dense|moe|ssm|hybrid|vlm|audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    stages: tuple[Stage, ...]
    head_dim: int = 128

    # attention
    window: int = 0
    rope_theta: float = 1e4
    rope_theta_local: float = 0.0
    qkv_bias: bool = False
    qk_norm: bool = False
    mrope_sections: Optional[tuple[int, ...]] = None
    sandwich_norm: bool = False
    gemma_norm: bool = False                 # (1+g) rmsnorm + sqrt(d) embed scale
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"

    # MLA (deepseek)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    n_shared: int = 0
    top_k: int = 0
    d_expert: int = 0
    router_type: str = "softmax"             # softmax | sigmoid_bias
    routed_scaling: float = 1.0
    capacity_factor: float = 1.25
    moe_impl: str = "dense"                  # dense | ep

    # SSM (mamba2)
    d_inner: int = 0
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # RG-LRU (recurrentgemma)
    lru_width: int = 0
    conv_width: int = 4

    # encoder-decoder
    encoder_layers: int = 0
    encoder_stages: tuple[Stage, ...] = ()
    is_encoder_decoder: bool = False

    # modality frontend: 'none' means token ids; otherwise the stub supplies
    # precomputed (B, S, d_model) embeddings (vlm patches / audio frames).
    frontend: str = "none"

    # long-context capability (decides long_500k applicability)
    subquadratic: bool = False

    def total_layers(self):
        n = sum(len(b) * r for b, r in self.stages)
        if self.is_encoder_decoder:
            n += sum(len(b) * r for b, r in self.encoder_stages)
        return n

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a 256 multiple so the embedding/LM head shard
        evenly on the model axis (Megatron-style); padded logits are masked
        to -inf and never win argmax / contribute to the loss."""
        return -(-self.vocab_size // 256) * 256


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                                # train_4k | prefill_32k | ...
    kind: str                                # train | prefill | decode
    seq_len: int
    global_batch: int
    grad_accum: int = 1                      # microbatch = batch/accum


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def uniform_stages(kind: str, n: int) -> tuple[Stage, ...]:
    return (((kind,), n),)


def patterned_stages(pattern: Sequence[str], n_layers: int) -> tuple[Stage, ...]:
    """Repeat ``pattern`` to cover n_layers; leftover becomes a second stage."""
    p = len(pattern)
    reps, rem = divmod(n_layers, p)
    stages: list[Stage] = []
    if reps:
        stages.append((tuple(pattern), reps))
    if rem:
        stages.append((tuple(pattern[:rem]), 1))
    return tuple(stages)
