"""Plane-parallel execution: one conv plane sharded spatially across a
device mesh, halo exchange at tile boundaries.

Every route the engine owned before this module — whole-plane Pallas, the
spatially-tiled grid, the fused GEMMs — executes one plane on one device,
so throughput on big segmentation/decoder planes is capped at
one-plane-per-device.  This module is the jump to *plane-parallel*: the
plan's per-bucket ``Route`` may carry a device-tiling verdict
(``Route.dev_tiles``, sitting next to ``sp_tiles``), and ``ConvPlan.apply``
then runs the conv as a ``shard_map`` over a spatial mesh — each device
executes the *existing* superpack executors on its own halo'd slab, with
``jax.lax.ppermute`` (collective-permute, never an all-gather of the
plane) moving exactly the halo rows/cols between neighbours.

The construction (per sharded dim, both kinds):

- **Alignment.**  Device ``d`` owns input rows ``[d·Hl, (d+1)·Hl)`` and
  output rows ``[d·T, (d+1)·T)``.  The halo widths are uniform across
  devices iff ``T·s == Hl`` — so the plane is zero-padded up front to
  ``H' = OH'·s`` rows with ``OH' = D·ceil(OH/D)`` (appended zeros
  reproduce the conv's own zero padding, and the extra output rows are
  sliced off after the launch).  For the transposed kind the same
  condition reads ``U == H`` per dim (phase-output extent equals input
  extent — true for every 'SAME'-style ``deconv_padding`` site), and the
  pad-to extent is ``H' = D·ceil(H/D)``.
- **Halo widths** come from the existing kernel algebra.  Single
  correlation: the halo'd slab is ``tin = halo_extent(T, r, s, d)`` rows,
  entered at ``halo_lo = pl`` (the spec's low padding) — so
  ``halo_hi = tin - Hl - pl``.  Transposed: the slab is
  ``tin = xh_max + T_u`` rows (the live-phase tap-origin span of
  ``deconv_tap_span``), ``halo_lo = gl`` (the global pad), ``halo_hi =
  xh_max - gl``.  One-hop feasibility requires each halo ≤ the block
  extent.
- **Edge zeros for free.**  ``ppermute`` delivers zeros to devices with no
  sending peer, which is exactly the zero padding the global conv applies
  at the plane boundary — no special-casing of edge devices anywhere.
- **Local plans are just plans.**  Each shard runs ``plan_conv`` of a
  *local spec*: same kernel/strides/dilation, ``in = tin`` rows, and
  padding ``(0, 0)`` (single kinds) or ``(pl - gl·s, ·)`` (transposed) on
  the sharded dim.  For the transposed kind the phase residue classes
  ``m ≡ (pl' - q) (mod s)`` are invariant under the local pad shift
  (``gl·s ≡ 0 mod s``), so the local plan's superpack layout is
  bit-identical to the parent's — the replicated packed buffer is shared,
  and the local plan's own custom VJP differentiates the shard.  The
  ``shard_map`` transpose scatters halo cotangents back through the
  reversed ``ppermute`` and psums the weight cotangent across devices.
- **2D tiling** is a two-stage exchange: rows first, then columns of the
  row-extended slab — the column strips then carry the corner halos from
  the diagonal neighbours without any extra collective.

``spatial_plan`` is the pure-arithmetic feasibility/geometry record the
route builders consult at plan time (it never builds a plan or touches
devices); ``spatial_apply`` is the executor; ``set_spatial_mesh`` /
``use_spatial_mesh`` bind the process's active spatial mesh that
``ConvPlan.apply`` dispatches through when a route carries ``dev_tiles``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
import warnings

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import decompose as dec
from repro.core.plan import ConvSpec, Route, plan_conv
from repro.sharding import shard_map_compat

Pair = tuple[int, int]

# default physical mesh axis names for the plane dims (see
# ``sharding.DEFAULT_RULES['plane_h'/'plane_w']`` / ``make_spatial_mesh``)
SPATIAL_AXES = ("sp_h", "sp_w")


# ---------------------------------------------------------------------------
# geometry: the per-dim tiling record and its feasibility arithmetic
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DimTiling:
    """One spatial dim's device tiling, all plan-time constants."""

    dev: int        # devices along this dim (1 = unsharded)
    size: int       # parent input extent H
    pad_to: int     # padded input extent H' (zeros appended; H' >= H)
    block: int      # per-device input rows Hl = H'/dev
    out_pad: int    # padded output extent OH' (sliced back to OH after)
    tin: int        # halo'd slab extent each device assembles
    halo_lo: int    # rows received from the previous device
    halo_hi: int    # rows received from the next device
    lpad: Pair      # the local spec's padding along this dim


@dataclasses.dataclass(frozen=True)
class SpatialPlan:
    """Device-tiling geometry for one spec: per-dim records + the local
    (per-shard) spec whose ``plan_conv`` runs on every device."""

    spec: ConvSpec
    dims: tuple[DimTiling, DimTiling]
    local_spec: ConvSpec
    out_hw: Pair          # parent output extent (the slice target)

    @property
    def dev_tiles(self) -> Pair:
        return (self.dims[0].dev, self.dims[1].dev)


def _single_dim(d: int, h: int, r: int, s: int, dil: int, pad: Pair,
                oh: int) -> tuple[DimTiling | None, str | None]:
    """Tiling of one dim of a 'conv'/'dilated' site over ``d`` devices:
    ``(tiling, None)`` when feasible, ``(None, reason)`` when not."""
    pl, _ = pad
    if d == 1:
        return DimTiling(1, h, h, h, oh, h, 0, 0, pad), None
    if pl < 0:                       # crop-style padding: not worth tiling
        return None, f"crop-style padding (pad lo {pl} < 0)"
    # pad the output to a device multiple; the input pads to OH'·s so that
    # T·s == Hl holds (and to at least H so no real rows are dropped)
    out_pad = d * max(-(-oh // d), -(-(-(-h // s)) // d))
    hp = out_pad * s
    if hp < h:
        return None, f"padded extent {hp} would drop input rows (H={h})"
    block, t = hp // d, out_pad // d
    tin = (t - 1) * s + (r - 1) * dil + 1
    halo_lo = pl
    halo_hi = max(0, tin - block - halo_lo)
    if halo_lo > block or halo_hi > block:
        return None, (f"halo ({halo_lo}, {halo_hi}) exceeds the {block}-row "
                      f"device block (needs multi-hop exchange)")
    return (DimTiling(d, h, hp, block, out_pad, tin, halo_lo, halo_hi,
                      (0, 0)), None)


def _transposed_dim(d: int, h: int, r: int, s: int, pad: Pair
                    ) -> tuple[DimTiling | None, str | None]:
    """Tiling of one dim of a transposed site over ``d`` devices:
    ``(tiling, None)`` when feasible, ``(None, reason)`` when not.  Needs
    per-dim uniform phases with ``U == H`` (the 'SAME'-style zoo padding);
    ``gl``/``xh_max`` are H-invariant, so the parent's phase algebra
    transfers to the padded extent unchanged."""
    if d == 1:
        oh = dec.transposed_out_size(h, r, s, pad)
        return DimTiling(1, h, h, h, oh, h, 0, 0, pad), None
    plans = dec.plan_phases_1d(h, r, s, pad)
    if any(p.out_size != h for p in plans):
        sizes = sorted({p.out_size for p in plans})
        return None, (f"transposed phases are non-uniform or U != H "
                      f"(phase outputs {sizes}, H={h})")
    gl = max(0, max(p.pad[0] for p in plans))
    live = [p for p in plans if p.taps > 0]
    if not live:
        return None, "no live phases"
    xh_max = max(gl - p.pad[0] + p.taps - 1 for p in live)
    hp = d * (-(-h // d))
    block = hp // d                  # == T_u (phase-output rows per device)
    tin = xh_max + block
    halo_lo, halo_hi = gl, max(0, xh_max - gl)
    if halo_lo > block or halo_hi > block:
        return None, (f"halo ({halo_lo}, {halo_hi}) exceeds the {block}-row "
                      f"device block (needs multi-hop exchange)")
    pl, _ = pad
    lpad_lo = pl - gl * s
    lpad_hi = s * block + r - 2 - (tin - 1) * s - lpad_lo
    return (DimTiling(d, h, hp, block, s * hp, tin, halo_lo, halo_hi,
                      (lpad_lo, lpad_hi)), None)


# specs whose infeasible-tiling warning already fired (mirrors
# ``sharding._REPLICATION_WARNED``): once per process, surviving
# ``reset()``, so plan-cache clears don't re-warn
_INFEASIBLE_WARNED: set = set()


def _warn_infeasible(spec: ConvSpec, reason: str) -> None:
    """A spec that *requests* device tiling but cannot be tiled would
    otherwise silently plan single-device (the ``dev_tiles`` verdict just
    vanishes) — name the spec and the reason, once."""
    if spec in _INFEASIBLE_WARNED:
        return
    _INFEASIBLE_WARNED.add(spec)
    warnings.warn(
        f"spatial_plan: {spec.kind} site {spec.in_hw}x{spec.in_c}->"
        f"{spec.out_c} k={spec.kernel_hw} s={spec.strides} "
        f"p={spec.padding} requests device tiling spatial={spec.spatial} "
        f"but admits no one-hop halo exchange ({reason}) — planning "
        f"single-device", RuntimeWarning, stacklevel=3)


@functools.lru_cache(maxsize=4096)
def spatial_plan(spec: ConvSpec) -> SpatialPlan | None:
    """The device-tiling geometry for ``spec``, or None when ``spec``
    requests no tiling (``spatial == (1, 1)``) or the geometry cannot be
    tiled with one-hop halo exchange (warned once per spec).  Pure
    arithmetic over the spec constants — identical on every host, never
    touches a device (this is what makes ``dev_tiles`` a
    golden-fixture-stable verdict)."""
    d_h, d_w = spec.spatial
    if (d_h, d_w) == (1, 1):
        return None
    (h, w), (r, s) = spec.in_hw, spec.kernel_hw
    (sh, sw) = spec.strides
    (ph, pw) = spec.padding
    if spec.kind == "transposed":
        th, why_h = _transposed_dim(d_h, h, r, sh, ph)
        tw, why_w = _transposed_dim(d_w, w, s, sw, pw)
    else:
        (dh, dw) = spec.dilation if spec.kind == "dilated" else (1, 1)
        oh = dec.single_out_size(h, r, sh, dh, ph)
        ow = dec.single_out_size(w, s, sw, dw, pw)
        th, why_h = _single_dim(d_h, h, r, sh, dh, ph, oh)
        tw, why_w = _single_dim(d_w, w, s, sw, dw, pw, ow)
    if th is None or tw is None:
        _warn_infeasible(spec, "; ".join(
            f"dim {nm}: {why}" for nm, why in (("H", why_h), ("W", why_w))
            if why))
        return None
    if spec.kind == "transposed":
        out_hw = (dec.transposed_out_size(h, r, sh, ph),
                  dec.transposed_out_size(w, s, sw, pw))
    else:
        out_hw = (oh, ow)
    local_spec = dataclasses.replace(
        spec, in_hw=(th.tin, tw.tin), padding=(th.lpad, tw.lpad),
        spatial=(1, 1))
    return SpatialPlan(spec=spec, dims=(th, tw), local_spec=local_spec,
                       out_hw=out_hw)


def plane_parallel_bytes(spec: ConvSpec, out_hw: Pair, batch: int,
                         itemsize: int) -> int:
    """The single-device working set the dev-tiling verdict is gated on:
    resident input plane + output plane at this batch bucket."""
    h, w = spec.in_hw
    oh, ow = out_hw
    return itemsize * batch * (h * w * spec.in_c + oh * ow * spec.out_c)


# ---------------------------------------------------------------------------
# active spatial mesh: what ``ConvPlan.apply`` dispatches through
# ---------------------------------------------------------------------------

_ACTIVE: list = [None]      # (mesh, (axis_h, axis_w)) or None


def set_spatial_mesh(mesh, axes: Pair = SPATIAL_AXES):
    """Bind (or, with ``mesh=None``, clear) the process's active spatial
    mesh.  Serving binds it at model load / ``degrade`` time; tests and
    benches prefer the scoped ``use_spatial_mesh``."""
    _ACTIVE[0] = None if mesh is None else (mesh, tuple(axes))


def active_spatial_mesh():
    """The bound (mesh, axes) or None."""
    return _ACTIVE[0]


@contextlib.contextmanager
def use_spatial_mesh(mesh, axes: Pair = SPATIAL_AXES):
    prev = _ACTIVE[0]
    set_spatial_mesh(mesh, axes)
    try:
        yield
    finally:
        _ACTIVE[0] = prev


def mesh_matches(mesh, axes, dev_tiles: Pair) -> bool:
    """Does the bound mesh offer exactly ``dev_tiles`` devices along the
    spatial axes?  (An axis may be absent when its tile extent is 1.)"""
    for ax, want in zip(axes, dev_tiles):
        have = int(mesh.shape[ax]) if ax in mesh.shape else 1
        if have != want:
            return False
    return True


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------

def _exchange(xb, axis: int, mesh_axis: str, dim: DimTiling):
    """One dim's halo exchange: send my bottom ``halo_lo`` rows forward and
    my top ``halo_hi`` rows backward along ``mesh_axis``, concat, slice to
    the exact slab extent.  Devices at the mesh edge receive zeros — the
    global conv's own boundary padding."""
    if dim.dev == 1:
        return xb
    fwd = [(i, i + 1) for i in range(dim.dev - 1)]
    bwd = [(i + 1, i) for i in range(dim.dev - 1)]
    parts = []
    if dim.halo_lo:
        src = jax.lax.slice_in_dim(xb, dim.block - dim.halo_lo, dim.block,
                                   axis=axis)
        parts.append(jax.lax.ppermute(src, mesh_axis, fwd))
    parts.append(xb)
    if dim.halo_hi:
        src = jax.lax.slice_in_dim(xb, 0, dim.halo_hi, axis=axis)
        parts.append(jax.lax.ppermute(src, mesh_axis, bwd))
    out = jnp.concatenate(parts, axis=axis) if len(parts) > 1 else xb
    if out.shape[axis] != dim.tin:
        out = jax.lax.slice_in_dim(out, 0, dim.tin, axis=axis)
    return out


def spatial_apply(sp: SpatialPlan, x4: jax.Array, packed: jax.Array,
                  mesh, axes: Pair = SPATIAL_AXES) -> jax.Array:
    """Run the planned conv plane-parallel over ``mesh``: pad the plane to
    the device-aligned extent, shard rows/cols over the spatial axes,
    exchange halos (rows, then columns of the row-extended slab), run the
    local plan's single-device executor per shard, reassemble, slice.

    Differentiable end to end: the local plan's custom VJP runs per shard
    inside the ``shard_map``, whose transpose reverses the ``ppermute``
    halo flows and psums the replicated superpack's cotangent."""
    th, tw = sp.dims
    ax_h, ax_w = axes
    lplan = plan_conv(sp.local_spec)
    zh, zw = th.pad_to - th.size, tw.pad_to - tw.size
    if zh or zw:
        x4 = jnp.pad(x4, ((0, 0), (0, zh), (0, zw), (0, 0)))

    def body(xb, pk):
        xl = _exchange(xb, 1, ax_h, th)
        xl = _exchange(xl, 2, ax_w, tw)
        return lplan.apply(xl, pk)

    spec_h = ax_h if th.dev > 1 else None
    spec_w = ax_w if tw.dev > 1 else None
    f = shard_map_compat(
        body, mesh,
        in_specs=(P(None, spec_h, spec_w, None), P(None, None)),
        out_specs=P(None, spec_h, spec_w, None))
    y = f(x4, packed)
    oh, ow = sp.out_hw
    if y.shape[1] != oh or y.shape[2] != ow:
        y = y[:, :oh, :ow, :]
    return y


def try_spatial(plan, x: jax.Array, packed: jax.Array):
    """``ConvPlan.apply``'s dispatch hook: execute plane-parallel when a
    spatial mesh is bound and its extents match the route's ``dev_tiles``
    verdict; return None to fall back to the single-device route (the
    route's path/tiles fields are the single-device verdict, so the
    fallback is always well-defined)."""
    active = active_spatial_mesh()
    if active is None:
        return None
    lead = x.shape[:-3]
    batch = int(math.prod(lead)) if lead else 1
    route: Route = plan.route_for_batch(batch)
    if route.dev_tiles is None:
        return None
    mesh, axes = active
    if not mesh_matches(mesh, axes, route.dev_tiles):
        return None
    sp = spatial_plan(plan.spec)
    if sp is None:                   # spec mutated outside plan_conv
        return None
    x4 = x.reshape((-1,) + x.shape[-3:])
    y = spatial_apply(sp, x4, plan.as_superpack(packed), mesh, axes)
    return y.reshape(lead + y.shape[1:])


def reset():
    """Drop the memoized geometry (tests patch plan-route constants and
    clear every plan-derived cache together)."""
    spatial_plan.cache_clear()
