"""Plan/executor engine: every HUGE² conv is *planned once* at model-load.

The paper's central claim is that transposed / strided / dilated convolutions
should be decomposed **offline** and executed as zero-free GEMMs with maximal
data reuse.  This module is that offline step made explicit:

- ``ConvSpec``   — a hashable description of one convolution site (op kind,
  spatial/channel shapes, strides, padding, dilation, dtype, backend policy).
- ``plan_conv``  — compiles a spec into a ``ConvPlan`` exactly once (keyed
  LRU cache); everything the old engine recomputed inside every jitted call
  is captured here: per-phase ``PhasePlan1D`` geometry, the execution path
  per phase (Pallas whole-plane / XLA fused-taps / XLA per-tap GEMMs, with
  VMEM tile sizes chosen at plan time), and the mirrored backward schedules.
- ``ConvPlan.pack``    — slices the HWIO kernel into GEMM-ready per-phase
  sub-kernels, flattened tap-major to ``(T_h*T_w*C, N)``.  Done once at
  model load; the packed buffers *are* the model's parameters from then on.
- ``ConvPlan.apply``   — executes the planned convolution on packed weights.
  For the transposed and strided kinds this is a ``jax.custom_vjp`` whose
  backward also runs on the packed layout:

  * dx of a transposed conv — the §3.2.3 *strided-conv* form: per-tap GEMMs
    of the padded derivative maps against panels fetched straight out of the
    packed phase buffers (no kernel reassembly, no zeros).
  * dK of a transposed conv — the §3.2.3 *dilated-kernel* form, emitted
    directly in the packed per-phase layout.

No other module slices kernels at execution time; ``repro.core.engine`` and
``repro.kernels.ops`` are thin dispatchers over this cache.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import decompose as dec
from repro.core.untangle import pad_or_crop

Pair = tuple[int, int]

# leave headroom below the 16 MiB/core VMEM of v5e (moved from kernels.ops)
_VMEM_BUDGET = 12 * 1024 * 1024

# plan-time fuse heuristic: concatenate tap views + one wide GEMM when the
# GEMM has too few rows to amortize per-tap dispatch (paper Fig. 7 DC1).
_FUSE_MAX_ROWS = 128


def norm_padding(padding, k_hw) -> tuple[Pair, Pair]:
    """Normalize 'SAME'/'VALID'/int-pair/nested paddings to ((lo,hi),(lo,hi))."""
    if isinstance(padding, str):
        r, s = k_hw
        if padding.upper() == "SAME":
            return ((r // 2, (r - 1) // 2), (s // 2, (s - 1) // 2))
        if padding.upper() == "VALID":
            return ((0, 0), (0, 0))
        raise ValueError(padding)
    (a, b) = padding
    if isinstance(a, int):
        return ((a, a), (b, b))
    return (tuple(a), tuple(b))


def flip_swap(kernel):
    """(R,S,C,N) -> spatially flipped, channels swapped (R,S,N,C)."""
    return jnp.transpose(jnp.flip(kernel, (0, 1)), (0, 1, 3, 2))


def pick_vmem_tiles(hp, wp, c, n, r, s, oh, ow, itemsize):
    """Largest MXU-aligned (C_t, N_t) whose working set fits VMEM.

    Plan-time replacement for the old per-call ``kernels.ops._pick_tiles``.
    """
    from repro.kernels.untangled_conv import vmem_bytes_estimate
    for n_t in (256, 128, 64, 32, 16, 8):
        for c_t in (256, 128, 64, 32, 16, 8):
            if c_t > max(c, 8) * 2 or n_t > max(n, 8) * 2:
                continue
            if vmem_bytes_estimate(hp, wp, min(c_t, c), r, s, min(n_t, n),
                                   oh, ow, itemsize) <= _VMEM_BUDGET:
                return min(c_t, c), min(n_t, n)
    return None


# ---------------------------------------------------------------------------
# spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Hashable description of one convolution site — the plan-cache key."""

    kind: str                     # 'transposed' | 'conv' | 'dilated'
    in_hw: Pair                   # input spatial (H, W)
    in_c: int
    out_c: int
    kernel_hw: Pair               # (R, S)
    strides: Pair = (1, 1)
    padding: tuple[Pair, Pair] = ((0, 0), (0, 0))
    dilation: Pair = (1, 1)
    dtype: str = "float32"
    backend: str = "auto"         # 'auto' | 'xla' | 'pallas'


def conv_spec(kind: str, x_shape: Sequence[int], kernel_shape: Sequence[int],
              *, strides=(1, 1), padding=((0, 0), (0, 0)), dilation=(1, 1),
              dtype=None, backend: str = "auto") -> ConvSpec:
    """Build a normalized (cache-canonical) spec from array shapes."""
    r, s, c, n = kernel_shape
    if x_shape[-1] != c:
        raise ValueError(f"channel mismatch {x_shape[-1]} vs {c}")
    return ConvSpec(
        kind=kind, in_hw=(int(x_shape[-3]), int(x_shape[-2])),
        in_c=int(c), out_c=int(n), kernel_hw=(int(r), int(s)),
        strides=tuple(int(v) for v in strides),
        padding=norm_padding(padding, (r, s)),
        dilation=tuple(int(v) for v in dilation),
        dtype=str(jnp.dtype(dtype)) if dtype is not None else "float32",
        backend=backend)


# ---------------------------------------------------------------------------
# per-phase execution record
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PhaseExec:
    """Plan-time execution record for one output phase (or the whole conv)."""

    key: str                      # packed-weights pytree key
    q: Pair                       # (q_h, q_w) output phase
    rho: Pair                     # first kernel tap per dim
    taps: Pair                    # (T_h, T_w) sub-kernel extent
    pad: tuple[Pair, Pair]        # input pad/crop for this phase's stride-1 conv
    out_hw: Pair                  # (U, V) phase output extent
    path: str                     # 'zeros' | 'fused' | 'taps' | 'pallas'
    tiles: Pair | None            # (C_t, N_t) when path == 'pallas'


def _choose_path(backend: str, hp: int, wp: int, c: int, n: int,
                 taps: Pair, out_hw: Pair, itemsize: int) -> tuple[str, Pair | None]:
    th, tw = taps
    u, v = out_hw
    if th == 0 or tw == 0 or u == 0 or v == 0:
        return "zeros", None
    want_pallas = backend == "pallas" or (
        backend == "auto" and jax.default_backend() == "tpu")
    if want_pallas:
        tiles = pick_vmem_tiles(hp, wp, c, n, th, tw, u, v, itemsize)
        if tiles is not None:
            return "pallas", tiles
    if u * v <= _FUSE_MAX_ROWS and th * tw > 2:
        return "fused", None
    return "taps", None


# ---------------------------------------------------------------------------
# plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class ConvPlan:
    """Compiled execution plan.  Identity-hashable (plans are cache singletons),
    so it can ride through ``jax.custom_vjp`` as a static argument."""

    spec: ConvSpec
    out_hw: Pair
    phases: tuple[PhaseExec, ...]          # len 1 for 'conv'/'dilated'
    bwd_pad: tuple[Pair, Pair] | None      # transposed: dy padding for dx/dK
    dx_taps: tuple[tuple, ...] | None      # transposed: (m, n, key, flat_row)
    conv_bwd: "ConvPlan | None"            # conv: child transposed plan for dx
    build_ms: float = 0.0

    # -- weight layout -----------------------------------------------------
    def pack(self, kernel: jax.Array):
        """Kernel (R,S,C,N) -> packed GEMM-ready weights.

        'transposed': {key: (T_h*T_w*C, N)} tap-major flattened phase
        sub-kernels.  'conv'/'dilated': the kernel itself (identity pack —
        untangling reads taps in place, there is nothing to pre-slice).
        """
        if self.spec.kind != "transposed":
            return kernel
        subs = dec.decompose_kernel(kernel, self.spec.strides,
                                    self.spec.padding)
        packed = {}
        for ex in self.phases:
            sub = subs[ex.q]
            th, tw = ex.taps
            packed[ex.key] = sub.reshape(th * tw * self.spec.in_c,
                                         self.spec.out_c)
        return packed

    def unpack(self, packed):
        """Packed weights -> full (R,S,C,N) kernel (offline use only)."""
        if self.spec.kind != "transposed":
            return packed
        r, s = self.spec.kernel_hw
        c, n = self.spec.in_c, self.spec.out_c
        (sh, sw) = self.spec.strides
        sample = next(iter(packed.values()))
        kernel = jnp.zeros((r, s, c, n), sample.dtype)
        for ex in self.phases:
            th, tw = ex.taps
            if th == 0 or tw == 0:
                continue
            sub = packed[ex.key].reshape(th, tw, c, n)
            kernel = kernel.at[ex.rho[0]::sh, ex.rho[1]::sw].set(sub)
        return kernel

    # -- execution ---------------------------------------------------------
    def apply(self, x: jax.Array, packed) -> jax.Array:
        """Planned execution on packed weights (differentiable)."""
        if (tuple(x.shape[-3:-1]) != self.spec.in_hw
                or x.shape[-1] != self.spec.in_c):
            raise ValueError(
                f"input {x.shape[-3:]} does not match plan spec "
                f"{self.spec.in_hw + (self.spec.in_c,)} — plans bake geometry "
                f"at build time; plan_conv a spec for this shape")
        if self.spec.kind == "transposed":
            return _planned_transposed(self, x, packed)
        if self.spec.kind == "conv":
            return _planned_conv(self, x, packed)
        return _dilated_fwd(self, x, packed)       # autodiff through slices

    __call__ = apply

    def apply_kernel(self, x: jax.Array, kernel: jax.Array) -> jax.Array:
        """Compatibility path: pack per call, then execute.  Under jit this
        re-slices the kernel every invocation — serve from ``pack`` instead."""
        return self.apply(x, self.pack(kernel))


@functools.lru_cache(maxsize=4096)
def plan_conv(spec: ConvSpec) -> ConvPlan:
    """Compile ``spec`` into a ``ConvPlan`` (LRU-cached; one build per live
    site — the bound only matters for workloads cycling through thousands of
    distinct shapes, which evict oldest-first rather than grow unbounded)."""
    t0 = time.perf_counter()
    itemsize = jnp.dtype(spec.dtype).itemsize
    h, w = spec.in_hw
    r, s = spec.kernel_hw
    c, n = spec.in_c, spec.out_c
    (sh, sw) = spec.strides
    (ph, pw) = spec.padding

    if spec.kind == "transposed":
        if spec.dilation != (1, 1):
            raise ValueError("transposed plans do not support rhs dilation")
        plans_h = dec.plan_phases_1d(h, r, sh, ph)
        plans_w = dec.plan_phases_1d(w, s, sw, pw)
        oh = dec.transposed_out_size(h, r, sh, ph)
        ow = dec.transposed_out_size(w, s, sw, pw)
        phases = []
        for p_h in plans_h:
            for p_w in plans_w:
                taps = (p_h.taps, p_w.taps)
                out_hw = (p_h.out_size, p_w.out_size)
                hp = h + p_h.pad[0] + p_h.pad[1]
                wp = w + p_w.pad[0] + p_w.pad[1]
                path, tiles = _choose_path(spec.backend, hp, wp, c, n,
                                           taps, out_hw, itemsize)
                phases.append(PhaseExec(
                    key=f"q{p_h.phase}x{p_w.phase}", q=(p_h.phase, p_w.phase),
                    rho=(p_h.rho, p_w.rho), taps=taps,
                    pad=(p_h.pad, p_w.pad), out_hw=out_hw,
                    path=path, tiles=tiles))
        # dx schedule (strided-conv form): tap (m, n) of the flipped/swapped
        # kernel reads full-kernel tap (r-1-m, s-1-n), which lives in phase
        # ((pl-r') % s) at flat row r'//s within the packed buffer.
        by_q = {ex.q: ex for ex in phases}
        dx_taps = []
        for m in range(r):
            for nn in range(s):
                rp, sp = r - 1 - m, s - 1 - nn
                qh, qw = (ph[0] - rp) % sh, (pw[0] - sp) % sw
                ex = by_q[(qh, qw)]
                row = (rp // sh) * ex.taps[1] + (sp // sw)
                dx_taps.append((m, nn, ex.key, row))
        bwd_pad = ((r - 1 - ph[0], r - 1 - ph[1]),
                   (s - 1 - pw[0], s - 1 - pw[1]))
        plan = ConvPlan(spec=spec, out_hw=(oh, ow), phases=tuple(phases),
                        bwd_pad=bwd_pad, dx_taps=tuple(dx_taps),
                        conv_bwd=None)

    elif spec.kind in ("conv", "dilated"):
        (dh, dw) = spec.dilation if spec.kind == "dilated" else (1, 1)
        hp, wp = h + ph[0] + ph[1], w + pw[0] + pw[1]
        oh = (hp - (r - 1) * dh - 1) // sh + 1
        ow = (wp - (s - 1) * dw - 1) // sw + 1
        if oh <= 0 or ow <= 0:
            raise ValueError(f"non-positive output {oh}x{ow}")
        path, tiles = _choose_path(spec.backend, hp, wp, c, n, (r, s),
                                   (oh, ow), itemsize)
        ex = PhaseExec(key="k", q=(0, 0), rho=(0, 0), taps=(r, s),
                       pad=spec.padding, out_hw=(oh, ow), path=path,
                       tiles=tiles)
        conv_bwd = None
        if spec.kind == "conv":
            # mirrored dx plan: transposed conv of dy with the flipped/swapped
            # kernel.  When the stride does not tile the input exactly, extend
            # the high padding so the transposed conv emits exactly H (resp. W).
            def_h = h - ((oh - 1) * sh + (r - 1 - ph[0]) + (r - 1 - ph[1])
                         - r + 2)
            def_w = w - ((ow - 1) * sw + (s - 1 - pw[0]) + (s - 1 - pw[1])
                         - s + 2)
            conv_bwd = plan_conv(ConvSpec(
                kind="transposed", in_hw=(oh, ow), in_c=n, out_c=c,
                kernel_hw=(r, s), strides=(sh, sw),
                padding=((r - 1 - ph[0], r - 1 - ph[1] + def_h),
                         (s - 1 - pw[0], s - 1 - pw[1] + def_w)),
                dtype=spec.dtype, backend="xla"))
        plan = ConvPlan(spec=spec, out_hw=(oh, ow), phases=(ex,),
                        bwd_pad=None, dx_taps=None, conv_bwd=conv_bwd)
    else:
        raise ValueError(f"unknown conv kind {spec.kind!r}")

    plan.build_ms = (time.perf_counter() - t0) * 1e3
    return plan


def plan_cache_info():
    return plan_conv.cache_info()


def plan_cache_clear():
    plan_conv.cache_clear()


# ---------------------------------------------------------------------------
# executors (all geometry is plan-time constant)
# ---------------------------------------------------------------------------

def _exec_phase(xp: jax.Array, sub4: jax.Array, ex: PhaseExec, strides: Pair,
                dilation: Pair, out_dtype, interpret=None) -> jax.Array:
    """One planned stride/dilation correlation of pre-padded ``xp`` with the
    4-D sub-kernel, along the path chosen at plan time."""
    th, tw = ex.taps
    u, v = ex.out_hw
    (sh, sw), (dh, dw) = strides, dilation
    cc = xp.shape[-1]

    def tap_view(m, nn):
        return jax.lax.slice(
            xp, [0] * (xp.ndim - 3) + [m * dh, nn * dw, 0],
            list(xp.shape[:-3]) + [m * dh + (u - 1) * sh + 1,
                                   nn * dw + (v - 1) * sw + 1, cc],
            [1] * (xp.ndim - 3) + [sh, sw, 1])

    if ex.path == "pallas":
        from repro.kernels.untangled_conv import untangled_conv2d_pallas
        lead = xp.shape[:-3]
        xp4 = xp.reshape((-1,) + xp.shape[-3:])
        y = untangled_conv2d_pallas(xp4, sub4, strides=strides,
                                    rhs_dilation=dilation,
                                    c_tile=ex.tiles[0], n_tile=ex.tiles[1],
                                    out_dtype=out_dtype, interpret=interpret)
        return y.reshape(lead + y.shape[1:])
    if ex.path == "fused":
        buf = jnp.concatenate([tap_view(m, nn) for m in range(th)
                               for nn in range(tw)], axis=-1)
        w2 = sub4.reshape(th * tw * cc, sub4.shape[-1])
        y = jax.lax.dot_general(buf, w2, (((buf.ndim - 1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        return y.astype(out_dtype)
    acc = None
    for m in range(th):
        for nn in range(tw):
            xs = tap_view(m, nn)
            t = jax.lax.dot_general(
                xs, sub4[m, nn], (((xs.ndim - 1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            acc = t if acc is None else acc + t
    return acc.astype(out_dtype)


def _transposed_fwd(plan: ConvPlan, x, packed, interpret=None):
    spec = plan.spec
    c, n = spec.in_c, spec.out_c
    outs = {}
    for ex in plan.phases:
        if ex.path == "zeros":
            outs[ex.q] = jnp.zeros(
                (*x.shape[:-3], ex.out_hw[0], ex.out_hw[1], n), x.dtype)
            continue
        th, tw = ex.taps
        sub4 = packed[ex.key].reshape(th, tw, c, n)
        xp = pad_or_crop(x, ex.pad)
        outs[ex.q] = _exec_phase(xp, sub4, ex, (1, 1), (1, 1), x.dtype,
                                 interpret)
    return dec.interleave_phases(outs, spec.strides, plan.out_hw)


def _conv_fwd(plan: ConvPlan, x, kernel, interpret=None):
    ex = plan.phases[0]
    xp = pad_or_crop(x, ex.pad)
    return _exec_phase(xp, kernel, ex, plan.spec.strides, (1, 1), x.dtype,
                       interpret)


def _dilated_fwd(plan: ConvPlan, x, kernel, interpret=None):
    ex = plan.phases[0]
    xp = pad_or_crop(x, ex.pad)
    return _exec_phase(xp, kernel, ex, plan.spec.strides, plan.spec.dilation,
                       x.dtype, interpret)


# ---------------------------------------------------------------------------
# transposed conv: custom VJP on packed weights (§3.2.3, Fig. 6)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _planned_transposed(plan: ConvPlan, x, packed):
    return _transposed_fwd(plan, x, packed)


def _pt_fwd(plan, x, packed):
    return _transposed_fwd(plan, x, packed), (x, packed)


def _pt_bwd(plan, res, dy):
    x, packed = res
    spec = plan.spec
    h, w = spec.in_hw
    r, s = spec.kernel_hw
    (sh, sw) = spec.strides
    c = spec.in_c
    x4 = x.reshape((-1,) + x.shape[-3:])
    dy4 = dy.reshape((-1,) + dy.shape[-3:])
    dy_p = pad_or_crop(dy4, plan.bwd_pad)

    # dx — strided-conv form, panels fetched from the packed phase buffers.
    acc = None
    for (m, nn, key, row) in plan.dx_taps:
        panel = jax.lax.slice(packed[key], [row * c, 0],
                              [(row + 1) * c, spec.out_c])   # (C, N)
        wnd = jax.lax.slice(
            dy_p, [0, m, nn, 0],
            [dy_p.shape[0], m + sh * (h - 1) + 1, nn + sw * (w - 1) + 1,
             dy_p.shape[3]], [1, sh, sw, 1])
        t = jax.lax.dot_general(wnd, panel, (((wnd.ndim - 1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        acc = t if acc is None else acc + t
    dx = acc.astype(x.dtype).reshape(x.shape)

    # dK — dilated-kernel form, emitted directly in the packed layout.
    dk = {}
    for ex in plan.phases:
        th, tw = ex.taps
        if th == 0 or tw == 0:
            dk[ex.key] = jnp.zeros(packed[ex.key].shape,
                                   packed[ex.key].dtype)
            continue
        rows = []
        for t_h in range(th):
            rr = ex.rho[0] + sh * t_h
            cols = []
            for t_w in range(tw):
                ss = ex.rho[1] + sw * t_w
                wnd = jax.lax.slice(
                    dy_p, [0, r - 1 - rr, s - 1 - ss, 0],
                    [dy_p.shape[0], r - 1 - rr + sh * (h - 1) + 1,
                     s - 1 - ss + sw * (w - 1) + 1, dy_p.shape[3]],
                    [1, sh, sw, 1])
                cols.append(jnp.einsum("buvc,buvn->cn", x4, wnd,
                                       preferred_element_type=jnp.float32))
            rows.append(jnp.stack(cols, 0))
        sub = jnp.stack(rows, 0)                      # (T_h, T_w, C, N)
        dk[ex.key] = sub.reshape(th * tw * c, spec.out_c).astype(
            packed[ex.key].dtype)
    return dx, dk


_planned_transposed.defvjp(_pt_fwd, _pt_bwd)


# ---------------------------------------------------------------------------
# strided conv: custom VJP mirrored through a child transposed plan
# ---------------------------------------------------------------------------

def _grad_kernel_strided(plan: ConvPlan, x4, dy4):
    """dK of a strided conv: correlate the padded input with the s-dilated
    derivative maps (paper Fig. 6 step 3), tap by tap."""
    spec = plan.spec
    r, s = spec.kernel_hw
    (sh, sw) = spec.strides
    oh, ow = plan.out_hw
    x_p = pad_or_crop(x4, spec.padding)
    rows = []
    for rr in range(r):
        cols = []
        for ss in range(s):
            wnd = jax.lax.slice(
                x_p, [0, rr, ss, 0],
                [x_p.shape[0], rr + sh * (oh - 1) + 1,
                 ss + sw * (ow - 1) + 1, x_p.shape[3]],
                [1, sh, sw, 1])
            cols.append(jnp.einsum("bouc,boun->cn", wnd, dy4,
                                   preferred_element_type=jnp.float32))
        rows.append(jnp.stack(cols, 0))
    return jnp.stack(rows, 0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _planned_conv(plan: ConvPlan, x, kernel):
    return _conv_fwd(plan, x, kernel)


def _pc_fwd(plan, x, kernel):
    return _conv_fwd(plan, x, kernel), (x, kernel)


def _pc_bwd(plan, res, dy):
    x, kernel = res
    x4 = x.reshape((-1,) + x.shape[-3:])
    dy4 = dy.reshape((-1,) + dy.shape[-3:])
    dx = plan.conv_bwd.apply_kernel(dy4, flip_swap(kernel)).astype(x.dtype)
    dx = dx.reshape(x.shape)
    dk = _grad_kernel_strided(plan, x4, dy4).astype(kernel.dtype)
    return dx, dk


_planned_conv.defvjp(_pc_fwd, _pc_bwd)
