"""Plan/executor engine: every HUGE² conv is *planned once* at model-load,
and every conv — transposed, strided, or dilated — *executes as one launch*.

The paper's central claim is that transposed / strided / dilated convolutions
should be decomposed **offline** and executed as zero-free GEMMs with maximal
data reuse.  This module is that offline step made explicit:

- ``ConvSpec``   — a hashable description of one convolution site (op kind,
  spatial/channel shapes, strides, padding, dilation, dtype, backend policy).
- ``plan_conv``  — compiles a spec into a ``ConvPlan`` exactly once (keyed
  LRU cache); everything the old engine recomputed inside every jitted call
  is captured here: per-phase ``PhasePlan1D`` geometry, the *whole-conv*
  execution path (one fused Pallas launch / one wide XLA GEMM / per-tap
  GEMM fallback, with VMEM tile sizes chosen at plan time), and the mirrored
  backward schedules.  ``ConvSpec`` carries no batch — instead every plan
  sizes one ``Route`` per batch bucket (``BATCH_BUCKETS`` = 1/4/16/64)
  against the plane-bytes/VMEM caps at build time, and the executors look
  the route up with ``ConvPlan.route_for_batch(B)``; serving pads request
  batches to the nearest bucket so each bucket jits exactly once.
- ``ConvPlan.pack``    — flattens the HWIO kernel into the **superpacked**
  weight layout, one tap-major buffer per site.  For the transposed kind:
  all phase sub-kernels concatenated, ``(Σ_q T_h·T_w·C, N)``, with phase row
  offsets as plan-time constants (``PhaseExec.tap_off``).  For the
  single-correlation kinds ('conv' / 'dilated'): the same tap-major layout
  with one phase, ``(R·S·C, N)`` — tap ``t = m·S + n`` owns rows
  ``[t·C, (t+1)·C)``, and dilation never appears in the layout (a dilated
  kernel packs identically to a dense one — the *geometry* moves into the
  plan, not the weights).  Done once at model load; the superpack *is* the
  model's parameter from then on.
- ``ConvPlan.apply``   — executes the planned convolution on the superpack.

All three kinds execute through the same single-correlation machinery: pad
the input **once**, keep that plane resident, and run shift-and-add tap
GEMMs against superpack rows at plan-time offsets.

Transposed execution (EcoFlow-style fusion of all s_h·s_w phases over one
residency of the input):

* ``pallas``      — one multi-phase Pallas kernel: the globally padded plane
  resident in VMEM once, a static unrolled loop over every phase's taps
  accumulating into per-phase f32 scratch, and a flush that writes the
  *interleaved* output block directly with strided in-kernel stores.  When
  the whole plane does not fit VMEM, the same launch runs the **spatially
  tiled** grid instead (``Route.sp_tiles``): halo'd output tiles with
  double-buffered input DMA — the 'pallas' verdict is a *tile*-fits check,
  so plane size never forces a site off the Pallas route (the XLA
  fallbacks below remain for non-uniform-phase transposed shapes, and
  for the pathological case of a minimum halo tile over the budget).
* ``fused_tap``   — one wide XLA GEMM: all tap-shifted views of the resident
  plane stacked against the superpack reshaped ``(ΣT, C, N)``, per-phase
  tap-segment sums, one reshape-interleave.  Exact FLOPs; wins when the
  plane is small relative to the phase output (DCGAN head layers).
* ``fused_plane`` — one wide XLA GEMM of the whole padded plane against the
  superpack viewed as ``(C, ΣT·N)``; every tap's contribution for every
  position comes out of the single GEMM, then shifted slice-accumulate and
  one reshape-interleave.  Slight FLOP overhead ``Hg·Wg·ΣT / Σ u·v·T``;
  wins when that ratio is small (deep layers, big planes).
* ``taps``        — general fallback (non-uniform phase extents with a large
  plane ratio): still a *single* global pad — per-phase GEMMs read the one
  resident plane through plan-time offsets — but phases are separate GEMMs
  and the output goes through ``interleave_phases``.

Single-correlation execution ('conv' / 'dilated', §3.2.2 — the dilated
kernel is never zero-inserted; taps read the raw plane at ``m·d_h`` /
``n·d_w`` offsets):

* ``pallas``      — ONE launch of the superpack Pallas kernel: the padded
  plane resident in VMEM, a static unrolled tap loop accumulating into f32
  scratch, tiles picked at plan time from the dilation-aware working set
  (the plane grows by the dilated tap reach ``(R-1)·d_h``; the superpack
  tile does not — taps are R·S rows regardless of dilation).  Big planes
  run the spatially tiled grid (``Route.sp_tiles``, halo'd output tiles +
  double-buffered input DMA) under the same single launch.
* ``fused_tap``   — ONE wide XLA GEMM: the R·S tap-shifted (strided,
  dilated) views of the resident plane concatenated along channels against
  the full ``(R·S·C, N)`` superpack.  Exact FLOPs (the buffer is built from
  the raw input — im2col's *layout*, but zero-free and load-time planned).
* ``taps``        — fallback when the tap-stacked buffer would out-grow the
  edge memory budget: per-tap shift-and-add GEMMs reading superpack rows
  ``[t·C, (t+1)·C)`` over the same single resident plane.

``apply`` is a ``jax.custom_vjp`` for **every** kind, running directly on
the superpacked layout:

* dx of a transposed conv — the §3.2.3 *strided-conv* form: per-tap GEMMs
  of the padded derivative maps against ``(C, N)`` panels fetched straight
  out of the superpack at plan-time row offsets (no kernel reassembly).
* dK of a transposed conv — the §3.2.3 *dilated-kernel* form, emitted
  directly in superpack order.
* dx of a strided/dilated conv — the mirrored *transposed-tap* form: one
  GEMM of dy against the superpack viewed ``(ΣT, C, N)``, then per-tap
  strided/dilated shift-and-add into the padded input plane (the exact
  transpose of the forward tap reads; no flipped kernel is ever assembled).
* dK of a strided/dilated conv — tap views of the resident input plane
  contracted with dy in one GEMM, emitted directly in superpack row order.

No other module slices kernels at execution time; ``repro.core.engine`` and
``repro.kernels.ops`` are thin dispatchers over this cache.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import decompose as dec
from repro.core.untangle import pad_or_crop

Pair = tuple[int, int]

# leave headroom below the 16 MiB/core VMEM of v5e (moved from kernels.ops)
_VMEM_BUDGET = 12 * 1024 * 1024

# plan-time fuse heuristic for the per-phase fallback and plain convs:
# concatenate tap views + one wide GEMM when the GEMM has too few rows to
# amortize per-tap dispatch (paper Fig. 7 DC1).
_FUSE_MAX_ROWS = 128

# batch buckets every plan sizes a route for at build time.  Serving pads
# each request batch up to the nearest bucket (``serving/image_batcher``),
# so the executor jits exactly once per bucket and ``route_for_batch`` is a
# plan-time table lookup — no byte-cap arithmetic happens at trace time.
BATCH_BUCKETS = (1, 4, 16, 64)

# whole-conv XLA path heuristic: the plane GEMM computes
# Hg*Wg*ΣT*C*N MACs where Σ u·v·T_q*C*N would be exact; take the plane
# GEMM when the overhead ratio is below this, else the exact tap-stacked
# GEMM (uniform phases) or the per-phase fallback.
_PLANE_RATIO_MAX = 1.6
# cap the (B=1) f32 plane-GEMM intermediate (Hg*Wg*ΣT*N) — beyond this the
# im2col-like blowup stops being an edge-memory win.
_PLANE_BYTES_MAX = 64 * 1024 * 1024

# plane-parallel verdict floor: a spec that *requests* device tiling
# (``ConvSpec.spatial != (1, 1)``) still routes single-device at buckets
# whose resident input+output planes stay under this — splitting a small
# plane buys halo traffic without relieving any memory pressure.
_SPATIAL_MIN_BYTES = 4 * 1024 * 1024


def norm_padding(padding, k_hw) -> tuple[Pair, Pair]:
    """Normalize 'SAME'/'VALID'/int-pair/nested paddings to ((lo,hi),(lo,hi))."""
    if isinstance(padding, str):
        r, s = k_hw
        if padding.upper() == "SAME":
            return ((r // 2, (r - 1) // 2), (s // 2, (s - 1) // 2))
        if padding.upper() == "VALID":
            return ((0, 0), (0, 0))
        raise ValueError(padding)
    (a, b) = padding
    if isinstance(a, int):
        return ((a, a), (b, b))
    return (tuple(a), tuple(b))


def pick_vmem_tiles(hp, wp, c, n, r, s, oh, ow, itemsize, witemsize=None):
    """Largest MXU-aligned (C_t, N_t) whose working set fits VMEM.

    Plan-time replacement for the old per-call ``kernels.ops._pick_tiles``.
    ``witemsize`` is the *weight* itemsize when it differs from the
    activation's (int8 superpacks: 1 byte/elem + the f32 scale rows).
    """
    from repro.kernels.untangled_conv import vmem_bytes_estimate
    for n_t in (256, 128, 64, 32, 16, 8):
        for c_t in (256, 128, 64, 32, 16, 8):
            if c_t > max(c, 8) * 2 or n_t > max(n, 8) * 2:
                continue
            if vmem_bytes_estimate(hp, wp, min(c_t, c), r, s, min(n_t, n),
                                   oh, ow, itemsize,
                                   witemsize=witemsize) <= _VMEM_BUDGET:
                return min(c_t, c), min(n_t, n)
    return None


def pick_fused_tiles(hg, wg, c, n, total_taps, sum_uv, oh, ow, itemsize,
                     witemsize=None):
    """(C_t, N_t) for the multi-phase fused kernel: the working set is the
    whole global plane + the superpack tile + per-phase f32 scratch + the
    full interleaved output block."""
    from repro.kernels.untangled_conv import vmem_bytes_estimate_fused
    for n_t in (256, 128, 64, 32, 16, 8):
        for c_t in (256, 128, 64, 32, 16, 8):
            if c_t > max(c, 8) * 2 or n_t > max(n, 8) * 2:
                continue
            if vmem_bytes_estimate_fused(
                    hg, wg, min(c_t, c), total_taps, min(n_t, n), sum_uv,
                    oh, ow, itemsize, witemsize=witemsize) <= _VMEM_BUDGET:
                return min(c_t, c), min(n_t, n)
    return None


def _spatial_cands(extent: int) -> tuple[int, ...]:
    """Output-tile size candidates along one dim, descending, clipped."""
    return tuple(dict.fromkeys(min(t, extent) for t in (128, 64, 32, 16, 8)))


def pick_tiled_single(c, n, r, s, oh, ow, strides, dilation, itemsize,
                      witemsize=None):
    """(C_t, N_t, (T_oh, T_ow)) for the spatially tiled single-correlation
    kernel, or None.  N tiles are maximized *first*: every N-tile revisit
    re-streams the full halo'd C range of the tile (total halo DMA per
    plane is ∝ N/N_t and independent of C_t), so a big N_t minimizes DMA
    traffic; then the largest C_t (fewer accumulator carries, fatter MXU
    contractions), then the largest output tile whose double-buffered
    working set (``vmem_bytes_estimate_tiled``) fits the budget."""
    from repro.kernels.untangled_conv import (halo_extent,
                                              vmem_bytes_estimate_tiled)
    (sh, sw), (dh, dw) = strides, dilation
    for n_t in (256, 128, 64, 32, 16, 8):
        for c_t in (256, 128, 64, 32, 16, 8):
            if c_t > max(c, 8) * 2 or n_t > max(n, 8) * 2:
                continue
            for toh in _spatial_cands(oh):
                for tow in _spatial_cands(ow):
                    tin_h = halo_extent(toh, r, sh, dh)
                    tin_w = halo_extent(tow, s, sw, dw)
                    if vmem_bytes_estimate_tiled(
                            tin_h, tin_w, min(c_t, c), r * s, min(n_t, n),
                            toh * tow, itemsize,
                            witemsize=witemsize) <= _VMEM_BUDGET:
                        return min(c_t, c), min(n_t, n), (toh, tow)
    return None


def pick_tiled_transposed(c, n, total_taps, phases, itemsize, witemsize=None):
    """(C_t, N_t, (T_u, T_v)) for the spatially tiled multi-phase deconv
    kernel, or None.  Tile sizes are in *phase-output* coordinates (the
    interleaved output tile is (T_u·s_h, T_v·s_w)); the halo covers the
    phase tap-origin span, so it is phase-aware by construction.  Search
    order as in ``pick_tiled_single``: N_t (DMA), then C_t, then space.
    Only uniform-phase plans call this (checked by the route builder)."""
    from repro.kernels.untangled_conv import (deconv_tap_span,
                                              vmem_bytes_estimate_tiled)
    uu, vv = phases[0].out_hw
    ((mh, xh_max), (mw, xw_max)) = deconv_tap_span(phases)
    for n_t in (256, 128, 64, 32, 16, 8):
        for c_t in (256, 128, 64, 32, 16, 8):
            if c_t > max(c, 8) * 2 or n_t > max(n, 8) * 2:
                continue
            for tu in _spatial_cands(uu):
                for tv in _spatial_cands(vv):
                    tin_h = xh_max - mh + tu
                    tin_w = xw_max - mw + tv
                    if vmem_bytes_estimate_tiled(
                            tin_h, tin_w, min(c_t, c), total_taps,
                            min(n_t, n), len(phases) * tu * tv,
                            itemsize, witemsize=witemsize) <= _VMEM_BUDGET:
                        return min(c_t, c), min(n_t, n), (tu, tv)
    return None


# ---------------------------------------------------------------------------
# spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Hashable description of one convolution site — the plan-cache key."""

    kind: str                     # 'transposed' | 'conv' | 'dilated'
    in_hw: Pair                   # input spatial (H, W)
    in_c: int
    out_c: int
    kernel_hw: Pair               # (R, S)
    strides: Pair = (1, 1)
    padding: tuple[Pair, Pair] = ((0, 0), (0, 0))
    dilation: Pair = (1, 1)
    dtype: str = "float32"
    backend: str = "auto"         # 'auto' | 'xla' | 'pallas'
    # requested device tiling (D_h, D_w) of the plane over a spatial mesh
    # (``core.spatial``).  Part of the cache key: a tiled site plans its
    # own routes (``Route.dev_tiles``).  (1, 1) = single-device, always.
    spatial: Pair = (1, 1)
    # weight *storage* dtype: 'float32' (dense superpack) or 'int8' (the
    # quantized superpack — ``pack`` emits a ``QuantizedSuperpack`` with
    # per-tap-row f32 scales, routes account 1 byte/weight-elem).
    # Activations and accumulation stay ``dtype``/f32 regardless.
    wdtype: str = "float32"


_WDTYPES = ("float32", "int8")


def conv_spec(kind: str, x_shape: Sequence[int], kernel_shape: Sequence[int],
              *, strides=(1, 1), padding=((0, 0), (0, 0)), dilation=(1, 1),
              dtype=None, backend: str = "auto",
              spatial: Pair = (1, 1), wdtype: str = "float32") -> ConvSpec:
    """Build a normalized (cache-canonical) spec from array shapes."""
    r, s, c, n = kernel_shape
    if x_shape[-1] != c:
        raise ValueError(f"channel mismatch {x_shape[-1]} vs {c}")
    return ConvSpec(
        kind=kind, in_hw=(int(x_shape[-3]), int(x_shape[-2])),
        in_c=int(c), out_c=int(n), kernel_hw=(int(r), int(s)),
        strides=tuple(int(v) for v in strides),
        padding=norm_padding(padding, (r, s)),
        dilation=tuple(int(v) for v in dilation),
        dtype=str(jnp.dtype(dtype)) if dtype is not None else "float32",
        backend=backend, spatial=tuple(int(v) for v in spatial),
        wdtype=str(wdtype))


def _weight_itemsize(spec: ConvSpec) -> int:
    """Per-element byte cost of the *stored* weights for VMEM/route
    accounting — 1 for the int8 superpack (scale rows are charged
    separately by the estimators), the activation itemsize otherwise."""
    return 1 if spec.wdtype == "int8" else jnp.dtype(spec.dtype).itemsize


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(eq=False)
class QuantizedSuperpack:
    """The int8 superpack: the tap-major weight buffer quantized per row.

    ``q`` is the ``(rows, N)`` int8 buffer in the exact row order the f32
    superpack uses (transposed: phase-concatenated taps; conv/dilated: tap
    ``t = m·S + n`` owns rows ``[t·C, (t+1)·C)``); ``scale`` is the f32
    ``(rows, 1)`` per-tap-row scale column riding with it — appended to the
    layout, so slicing rows of ``q`` and ``scale`` together yields a
    dequantizable panel at any plan-time offset.  Scales come from
    ``runtime.compress.quantize_int8_rows`` (symmetric, max/127, floored),
    which bounds the per-element weight error by ``0.5 · scale[row]``.

    Registered as a pytree so it rides through jit / custom_vjp / serving
    param trees like any other leaf pair."""

    q: jax.Array                  # (rows, N) int8
    scale: jax.Array              # (rows, 1) f32

    def tree_flatten(self):
        return (self.q, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)

    @property
    def shape(self):
        return self.q.shape

    def dequant(self) -> jax.Array:
        """The f32 superpack view — a row-broadcast multiply that XLA fuses
        into the consuming GEMM (the dequant-on-the-fly read)."""
        from repro.runtime.compress import dequantize_int8
        return dequantize_int8(self.q, self.scale)

    def nbytes(self) -> int:
        """Stored bytes: 1/elem for ``q`` plus the f32 scale rows."""
        return int(self.q.size) + 4 * int(self.scale.size)


# ---------------------------------------------------------------------------
# per-phase execution record
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PhaseExec:
    """Plan-time geometry record for one output phase (or the whole conv).

    Offsets are superpack / fused-kernel coordinates, fixed at plan time:
    ``tap_off`` rows (in taps) into the superpacked weight buffer,
    ``acc_off`` rows (in output pixels) into the fused kernel's accumulator,
    ``xoff`` the phase's tap origin inside the globally padded plane.
    """

    key: str                      # legacy per-phase pytree key (checkpoints)
    q: Pair                       # (q_h, q_w) output phase
    rho: Pair                     # first kernel tap per dim
    taps: Pair                    # (T_h, T_w) sub-kernel extent
    pad: tuple[Pair, Pair]        # input pad/crop for this phase's stride-1 conv
    out_hw: Pair                  # (U, V) phase output extent
    tap_off: int = 0              # taps preceding this phase in the superpack
    acc_off: int = 0              # U·V rows preceding this phase in scratch
    xoff: Pair = (0, 0)           # tap origin in the globally padded plane


def _choose_path(backend: str, hp: int, wp: int, c: int, n: int,
                 taps: Pair, out_hw: Pair, itemsize: int) -> tuple[str, Pair | None]:
    """Per-phase path choice — kept as the measured baseline policy for
    ``apply_per_phase`` (the pre-fusion transposed executor)."""
    th, tw = taps
    u, v = out_hw
    if th == 0 or tw == 0 or u == 0 or v == 0:
        return "zeros", None
    want_pallas = backend == "pallas" or (
        backend == "auto" and jax.default_backend() == "tpu")
    if want_pallas:
        tiles = pick_vmem_tiles(hp, wp, c, n, th, tw, u, v, itemsize)
        if tiles is not None:
            return "pallas", tiles
    if u * v <= _FUSE_MAX_ROWS and th * tw > 2:
        return "fused", None
    return "taps", None


@dataclasses.dataclass(frozen=True)
class Route:
    """One batch bucket's execution decision, fixed at plan time.

    ``batch`` is the bucket the byte caps were evaluated at; ``path`` /
    ``tiles`` are the whole-conv forward route for that bucket, and
    ``fused_bwd`` says whether the single-correlation backward may
    materialize its ``(B, OH, OW, ΣT, ·)`` f32 buffers (one wide dy GEMM +
    one stacked dK GEMM) or must fall back to per-tap GEMMs.

    ``sp_tiles`` is the spatial output-tile shape when the 'pallas' route is
    the *tiled* kernel — ``(T_oh, T_ow)`` output pixels for the single-
    correlation kinds, ``(T_u, T_v)`` phase-output pixels for the transposed
    kind (the interleaved tile is ``(T_u·s_h, T_v·s_w)``).  ``None`` means
    whole-plane VMEM residency (the small-plane fast path).

    ``dev_tiles`` is the *device*-tiling verdict, sitting one level above
    ``sp_tiles``: ``(D_h, D_w)`` devices the plane shards over when the spec
    requests spatial tiling, the geometry admits one-hop halo exchange, and
    this bucket's working set clears ``_SPATIAL_MIN_BYTES``
    (``core.spatial``).  ``path``/``tiles`` remain the *single-device*
    verdict — each shard (and any mesh-less fallback) executes through
    them unchanged."""

    batch: int
    # 'pallas'|'fused_plane'|'fused_tap'|'taps'|'pixel_shuffle', plus
    # (transposed, autotune-only) 'per_phase' — the PR-1 per-phase executor
    # promoted to a first-class route so the tuner can rank it (the
    # heuristic never emits it; BENCH_fig7 shows it winning on some hosts,
    # e.g. DC2).  'pixel_shuffle' is the transposed sub-pixel rewrite:
    # eligible specs (every phase shares (U,V)==(H,W), tap extent and pad)
    # run as ONE dense stride-1 conv against the (Q,T,C,N) superpack view
    # followed by depth-to-space — the tap buffer is Q× smaller than
    # 'fused_tap''s (T views instead of ΣT=Q·T).
    path: str
    tiles: Pair | None            # (C_t, N_t) when path == 'pallas'
    fused_bwd: bool = True
    sp_tiles: Pair | None = None  # spatial tile when 'pallas' is tiled
    dev_tiles: Pair | None = None  # (D_h, D_w) plane-parallel verdict


def _dev_verdict(spec: ConvSpec, out_hw: Pair, itemsize: int,
                 batch: int) -> Pair | None:
    """The per-bucket device-tiling verdict: the spec must request tiling,
    the geometry must admit one-hop halo exchange (``spatial.spatial_plan``
    — pure arithmetic, identical on every host), and the bucket's resident
    planes must outgrow the single-device floor."""
    if spec.spatial == (1, 1):
        return None
    from repro.core import spatial
    sp = spatial.spatial_plan(spec)
    if sp is None:
        return None
    if spatial.plane_parallel_bytes(spec, out_hw, batch,
                                    itemsize) <= _SPATIAL_MIN_BYTES:
        return None
    return spec.spatial


def _single_route(spec: ConvSpec, hp: int, wp: int, out_hw: Pair,
                  itemsize: int, batch: int) -> Route:
    """Single-correlation bucket route + the device-tiling verdict."""
    route = _single_route_1dev(spec, hp, wp, out_hw, itemsize, batch)
    dev = _dev_verdict(spec, out_hw, itemsize, batch)
    return dataclasses.replace(route, dev_tiles=dev) if dev else route


def _single_route_1dev(spec: ConvSpec, hp: int, wp: int, out_hw: Pair,
                       itemsize: int, batch: int) -> Route:
    """Whole-conv route for the single-correlation kinds ('conv'/'dilated')
    at one batch bucket: one Pallas launch / one wide GEMM / per-tap
    fallback.

    The same plane-ratio heuristic as the transposed path, extended with
    the dilation-aware VMEM working set: ``hp``/``wp`` are padded-plane
    dims that already carry the dilated tap reach ``(R-1)·d``, while the
    superpack tile stays R·S rows regardless of dilation — a dilated
    kernel costs plane residency, never weight bytes.  The tap-stacked
    GEMM buffer carries R·S copies of the output extent (exact FLOPs,
    im2col-sized layout) and grows linearly in the bucket, so big buckets
    route to 'taps' where small ones fuse."""
    r, s = spec.kernel_hw
    c, n = spec.in_c, spec.out_c
    oh, ow = out_hw
    # tap-stack blowup vs the resident plane: B*oh*ow*R*S rows of C against
    # B*hp*wp plane rows; cap the materialized f32 buffer.  The backward's
    # dy-GEMM / stacked-dK buffers are the same size, so one cap governs
    # both directions of the bucket.
    fused_ok = 4 * batch * oh * ow * r * s * c <= _PLANE_BYTES_MAX
    want_pallas = spec.backend == "pallas" or (
        spec.backend == "auto" and jax.default_backend() == "tpu")
    witemsize = _weight_itemsize(spec)
    if want_pallas:
        # the 'pallas' verdict is a *tile*-fits check: whole-plane residency
        # when it fits (no halo waste), else spatial output tiling — plane
        # size alone never pushes a site off the Pallas route
        tiles = pick_vmem_tiles(hp, wp, c, n, r, s, oh, ow, itemsize,
                                witemsize=witemsize)
        if tiles is not None:
            return Route(batch, "pallas", tiles, fused_bwd=fused_ok)
        dil = spec.dilation if spec.kind == "dilated" else (1, 1)
        tiled = pick_tiled_single(c, n, r, s, oh, ow, spec.strides, dil,
                                  itemsize, witemsize=witemsize)
        if tiled is not None:
            c_t, n_t, sp = tiled
            return Route(batch, "pallas", (c_t, n_t), fused_bwd=fused_ok,
                         sp_tiles=sp)
    if fused_ok:
        return Route(batch, "fused_tap", None, fused_bwd=True)
    return Route(batch, "taps", None, fused_bwd=False)


def _pixel_shuffle_geom(spec: ConvSpec, phases) -> tuple[Pair, tuple[Pair, Pair]] | None:
    """The sub-pixel rewrite's shared stride-1 footprint, or ``None``.

    A transposed spec is eligible when every phase shares the *same*
    stride-1 correlation: output extent ``(U, V) == (H, W)`` (so the
    interleave is an exact ×s_h×s_w depth-to-space), tap extent ``(T_h,
    T_w)`` and input pad.  Then the Q = s_h·s_w per-phase sub-kernels are
    one dense ``(T_h, T_w, C, Q·N)`` kernel and the whole conv is a single
    stride-1 correlation + depth-to-space — zero inserted zeros, exact
    FLOPs.  ``deconv_padding`` sites with ``k % s == 0`` (cGAN/VAE-decoder
    k=4 s=2) qualify; k=5 s=2 (DCGAN) does not (phase tap counts 3 vs 2) —
    exactly the geometry-dependent transposed-vs-sub-pixel tradeoff of
    arXiv:2107.07647."""
    if not phases:
        return None
    first = phases[0]
    th, tw = first.taps
    if th == 0 or tw == 0:
        return None
    if first.out_hw != spec.in_hw:
        return None
    for ex in phases[1:]:
        if (ex.taps != first.taps or ex.pad != first.pad
                or ex.out_hw != first.out_hw):
            return None
    return first.taps, first.pad


def _pixel_shuffle_route(spec: ConvSpec, phases, batch: int) -> Route | None:
    """The 'pixel_shuffle' verdict at one bucket: the spec must admit the
    rewrite and the bucket's tap-stacked GEMM buffer (T views of the input
    plane, f32) must clear the plane-bytes cap."""
    geom = _pixel_shuffle_geom(spec, phases)
    if geom is None:
        return None
    (th, tw), _ = geom
    h, w = spec.in_hw
    if 4 * batch * th * tw * h * w * spec.in_c > _PLANE_BYTES_MAX:
        return None
    return Route(batch, "pixel_shuffle", None)


def _transposed_route(spec: ConvSpec, hg: int, wg: int, out_hw: Pair,
                      total_taps: int, sum_uv: int, sum_uvt: int,
                      uniform: bool, phases, itemsize: int,
                      batch: int) -> Route:
    """Transposed bucket route + the device-tiling verdict."""
    route = _transposed_route_1dev(spec, hg, wg, out_hw, total_taps, sum_uv,
                                   sum_uvt, uniform, phases, itemsize, batch)
    dev = _dev_verdict(spec, out_hw, itemsize, batch)
    return dataclasses.replace(route, dev_tiles=dev) if dev else route


def _transposed_route_1dev(spec: ConvSpec, hg: int, wg: int, out_hw: Pair,
                           total_taps: int, sum_uv: int, sum_uvt: int,
                           uniform: bool, phases, itemsize: int,
                           batch: int) -> Route:
    """Whole-conv route for the transposed kind at one batch bucket: one
    launch / one wide GEMM, the plane-GEMM intermediate capped at the
    bucket's size."""
    c, n = spec.in_c, spec.out_c
    oh, ow = out_hw
    if total_taps == 0:
        # every phase is empty; executor emits zeros
        return Route(batch, "taps", None)
    want_pallas = spec.backend == "pallas" or (
        spec.backend == "auto" and jax.default_backend() == "tpu")
    witemsize = _weight_itemsize(spec)
    if want_pallas:
        tiles = pick_fused_tiles(hg, wg, c, n, total_taps, sum_uv, oh, ow,
                                 itemsize, witemsize=witemsize)
        if tiles is not None:
            return Route(batch, "pallas", tiles)
        # big planes: spatially tiled kernel (uniform phases — equivalently
        # out % stride == 0 — so the interleaved output tiles block cleanly)
        if uniform and oh % spec.strides[0] == 0 and ow % spec.strides[1] == 0:
            tiled = pick_tiled_transposed(c, n, total_taps, phases, itemsize,
                                          witemsize=witemsize)
            if tiled is not None:
                c_t, n_t, sp = tiled
                return Route(batch, "pallas", (c_t, n_t), sp_tiles=sp)
    # sub-pixel rewrite ahead of the fused routes: exact FLOPs like
    # fused_tap but a Q×-smaller GEMM buffer, and no plane-GEMM blowup
    ps = _pixel_shuffle_route(spec, phases, batch)
    if ps is not None:
        return ps
    plane_ratio = hg * wg * total_taps / max(1, sum_uvt)
    plane_bytes = 4 * batch * hg * wg * total_taps * n
    if plane_ratio <= _PLANE_RATIO_MAX and plane_bytes <= _PLANE_BYTES_MAX:
        return Route(batch, "fused_plane", None)
    if uniform:
        return Route(batch, "fused_tap", None)
    return Route(batch, "taps", None)


def _route_exact(plan: "ConvPlan", batch: int) -> Route:
    """Re-run the plan-time route choice for an exact (bucket-less) batch —
    the geometry is rebuilt from the plan's own constants."""
    spec = plan.spec
    itemsize = jnp.dtype(spec.dtype).itemsize
    h, w = spec.in_hw
    if spec.kind == "transposed":
        (glh, ghh), (glw, ghw) = plan.gpad
        sum_uvt = sum(ex.out_hw[0] * ex.out_hw[1] * ex.taps[0] * ex.taps[1]
                      for ex in plan.phases)
        return _transposed_route(
            spec, h + glh + ghh, w + glw + ghw, plan.out_hw, plan.total_taps,
            plan.sum_uv, sum_uvt, plan.uniform, plan.phases, itemsize, batch)
    (ph, pw) = spec.padding
    return _single_route(spec, h + ph[0] + ph[1], w + pw[0] + pw[1],
                         plan.out_hw, itemsize, batch)


# ---------------------------------------------------------------------------
# plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class ConvPlan:
    """Compiled execution plan.  Identity-hashable (plans are cache singletons),
    so it can ride through ``jax.custom_vjp`` as a static argument."""

    spec: ConvSpec
    out_hw: Pair
    phases: tuple[PhaseExec, ...]          # len 1 for 'conv'/'dilated'
    gpad: tuple[Pair, Pair] | None         # transposed: single global input pad
    total_taps: int                        # Σ_q T_h·T_w (superpack rows / C)
    sum_uv: int                            # Σ_q U·V (fused accumulator rows)
    uniform: bool                          # all phases share (U, V)
    bwd_pad: tuple[Pair, Pair] | None      # transposed: dy padding for dx/dK
    # (m, n, superpack row) tap schedule.  transposed: dx rows of the
    # flipped/swapped read.  conv/dilated: the forward row order m·S+n,
    # walked by both the taps-fallback forward and the backward.
    dx_taps: tuple[tuple, ...] | None
    # per-bucket routes, ascending by Route.batch (one per BATCH_BUCKETS)
    routes: tuple[Route, ...] = ()
    build_ms: float = 0.0
    # True when the routes came from measurement (autotune), not heuristics
    tuned: bool = False
    # memo for batches beyond the largest bucket (plans are cache
    # singletons, so this fills at most once per distinct oversize batch)
    _xl_routes: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def path(self) -> str:
        """The B=1 bucket's path (introspection / the benches' headline)."""
        return self.routes[0].path

    @property
    def tiles(self) -> Pair | None:
        """(C_t, N_t) when the B=1 route is 'pallas'."""
        return self.routes[0].tiles

    def route_for_batch(self, batch: int) -> Route:
        """The execution route sized for ``batch``: the smallest plan-time
        bucket that fits it (callers pad up to ``Route.batch``).  A batch
        beyond the largest bucket gets an exactly-sized route, built once
        and memoized — still plan-level arithmetic, never a traced branch."""
        for r in self.routes:
            if batch <= r.batch:
                return r
        if batch not in self._xl_routes:
            self._xl_routes[batch] = _route_exact(self, batch)
        return self._xl_routes[batch]

    def with_routes(self, routes: tuple[Route, ...],
                    tuned: bool = True) -> "ConvPlan":
        """A sibling plan sharing every piece of compiled geometry but with
        a replaced per-bucket route table (how the autotuner installs
        measured winners, and how tests force a route).  The copy is its
        own identity (fresh jit/vjp cache key) with an empty oversize-batch
        memo."""
        return ConvPlan(
            spec=self.spec, out_hw=self.out_hw, phases=self.phases,
            gpad=self.gpad, total_taps=self.total_taps, sum_uv=self.sum_uv,
            uniform=self.uniform, bwd_pad=self.bwd_pad, dx_taps=self.dx_taps,
            routes=tuple(routes), build_ms=self.build_ms, tuned=tuned)

    # -- weight layout -----------------------------------------------------
    def pack(self, kernel: jax.Array):
        """Kernel (R,S,C,N) -> the superpacked GEMM-ready weight buffer.

        'transposed': ``(Σ_q T_h·T_w·C, N)`` — all phase sub-kernels
        flattened tap-major and concatenated in phase order (row offsets
        are plan-time constants).  'conv'/'dilated': the single-phase
        tap-major flatten ``(R·S·C, N)`` — tap ``t = m·S + n`` owns rows
        ``[t·C, (t+1)·C)``; dilation changes the *plan geometry*, never the
        packed layout, so a dilated kernel packs bit-identically to a dense
        one.

        ``wdtype='int8'`` specs emit a ``QuantizedSuperpack`` instead: the
        same tap-major rows quantized per row (``runtime.compress
        .quantize_int8_rows``) with the f32 scale column appended — the
        quantize-at-pack half of the checkpoint round-trip."""
        if self.spec.kind != "transposed":
            r, s = self.spec.kernel_hw
            packed = kernel.reshape(r * s * self.spec.in_c, self.spec.out_c)
            return self._maybe_quantize(packed)
        subs = dec.decompose_kernel(kernel, self.spec.strides,
                                    self.spec.padding)
        c, n = self.spec.in_c, self.spec.out_c
        segs = []
        for ex in self.phases:
            th, tw = ex.taps
            if th * tw == 0:
                continue
            segs.append(subs[ex.q].reshape(th * tw * c, n))
        if not segs:
            packed = jnp.zeros((0, n), kernel.dtype)
        else:
            packed = jnp.concatenate(segs, axis=0)
        return self._maybe_quantize(packed)

    def _maybe_quantize(self, packed):
        """f32 superpack -> ``QuantizedSuperpack`` when the spec stores int8
        weights (idempotent: already-quantized buffers pass through)."""
        if self.spec.wdtype != "int8" or isinstance(packed,
                                                    QuantizedSuperpack):
            return packed
        from repro.runtime.compress import quantize_int8_rows
        q, scale = quantize_int8_rows(packed.astype(jnp.float32))
        return QuantizedSuperpack(q, scale)

    def as_superpack(self, packed):
        """Adapt legacy weight layouts onto the superpack; superpack arrays
        pass through unchanged.  Transposed: per-phase dicts ({'q0x1': buf}
        or {(0,1): buf}) from pre-superpack checkpoints.  'conv'/'dilated':
        full (R,S,C,N) HWIO kernels from pre-superpack params (the flatten
        is free — same memory order).  ``wdtype='int8'`` specs quantize any
        float layout they adapt, so f32 checkpoints load straight into a
        quantized plan; a ``QuantizedSuperpack`` passes through unchanged."""
        if isinstance(packed, QuantizedSuperpack):
            return packed
        if not isinstance(packed, dict):
            if self.spec.kind != "transposed" and getattr(
                    packed, "ndim", 2) == 4:
                return self.pack(packed)
            return self._maybe_quantize(packed)
        segs = []
        for ex in self.phases:
            if ex.taps[0] * ex.taps[1] == 0:
                continue
            sub = packed[ex.key] if ex.key in packed else packed[ex.q]
            segs.append(sub.reshape(-1, self.spec.out_c))
        if not segs:
            return self._maybe_quantize(
                jnp.zeros((0, self.spec.out_c), self.spec.dtype))
        return self._maybe_quantize(jnp.concatenate(segs, axis=0))

    def unpack(self, packed):
        """Packed weights -> full (R,S,C,N) kernel (offline use only).
        Accepts the superpack, a full HWIO kernel, or (transposed) a legacy
        per-phase dict; round-trips ``pack`` exactly, so checkpoints survive
        the layout migration.  A ``QuantizedSuperpack`` dequantizes first
        (``runtime.compress.dequantize_int8``), so an int8 checkpoint
        round-trips to HWIO within one quantization step per element."""
        packed = self.as_superpack(packed)
        if isinstance(packed, QuantizedSuperpack):
            packed = packed.dequant()
        if self.spec.kind != "transposed":
            r, s = self.spec.kernel_hw
            return packed.reshape(r, s, self.spec.in_c, self.spec.out_c)
        r, s = self.spec.kernel_hw
        c, n = self.spec.in_c, self.spec.out_c
        (sh, sw) = self.spec.strides
        kernel = jnp.zeros((r, s, c, n), packed.dtype)
        for ex in self.phases:
            th, tw = ex.taps
            if th * tw == 0:
                continue
            sub = jax.lax.slice(packed, [ex.tap_off * c, 0],
                                [(ex.tap_off + th * tw) * c, n])
            kernel = kernel.at[ex.rho[0]::sh, ex.rho[1]::sw].set(
                sub.reshape(th, tw, c, n))
        return kernel

    # -- execution ---------------------------------------------------------
    def apply(self, x: jax.Array, packed) -> jax.Array:
        """Planned execution on packed weights (differentiable)."""
        if (tuple(x.shape[-3:-1]) != self.spec.in_hw
                or x.shape[-1] != self.spec.in_c):
            raise ValueError(
                f"input {x.shape[-3:]} does not match plan spec "
                f"{self.spec.in_hw + (self.spec.in_c,)} — plans bake geometry "
                f"at build time; plan_conv a spec for this shape")
        if self.spec.spatial != (1, 1):
            # plane-parallel dispatch sits *above* the custom VJP: jax
            # differentiates through the shard_map (the shard-local plan's
            # own VJP runs per device), so the backward is plane-parallel
            # too.  Returns None without a matching bound mesh — the
            # route's single-device path/tiles fields take over below.
            from repro.core import spatial
            y = spatial.try_spatial(self, x, packed)
            if y is not None:
                return y
        if self.spec.kind == "transposed":
            return _planned_transposed(self, x, self.as_superpack(packed))
        return _planned_single(self, x, self.as_superpack(packed))

    __call__ = apply

    def apply_kernel(self, x: jax.Array, kernel: jax.Array) -> jax.Array:
        """Compatibility path: pack per call, then execute.  Under jit this
        re-slices the kernel every invocation — serve from ``pack`` instead."""
        return self.apply(x, self.pack(kernel))

    def apply_per_phase(self, x: jax.Array, packed) -> jax.Array:
        """The pre-fusion per-phase executor (one pad + GEMM chain per phase,
        stack/transpose interleave).  Kept as the measurement baseline for
        the fused single-launch path and as a parity oracle in tests; not
        differentiable through the custom VJP."""
        if self.spec.kind != "transposed":
            return self.apply(x, packed)
        return _transposed_per_phase(self, x, self.as_superpack(packed))


def plan_conv(spec: ConvSpec, autotune=None) -> ConvPlan:
    """Compile ``spec`` into a ``ConvPlan`` (LRU-cached; one build per live
    site).  ``autotune`` is an optional ``repro.core.autotune
    .AutotunePolicy``: when set, the heuristic per-bucket routes are
    replaced by measured winners — cached per-host results when available,
    live microbenchmarks on a cache miss under ``mode='measure'`` — with
    heuristic routes as the universal fallback (cold cache, unmeasurable
    candidates, unreadable cache file)."""
    plan = _plan_conv_heuristic(spec)
    if autotune is None or getattr(autotune, "mode", "off") == "off":
        return plan
    from repro.core.autotune import autotune_plan
    return autotune_plan(plan, autotune)


@functools.lru_cache(maxsize=4096)
def _plan_conv_heuristic(spec: ConvSpec) -> ConvPlan:
    """The heuristic compile: geometry + analytic per-bucket routes (the
    bound only matters for workloads cycling through thousands of distinct
    shapes, which evict oldest-first rather than grow unbounded)."""
    t0 = time.perf_counter()
    if spec.wdtype not in _WDTYPES:
        raise ValueError(f"unsupported wdtype {spec.wdtype!r} "
                         f"(supported: {_WDTYPES})")
    itemsize = jnp.dtype(spec.dtype).itemsize
    h, w = spec.in_hw
    r, s = spec.kernel_hw
    c, n = spec.in_c, spec.out_c
    (sh, sw) = spec.strides
    (ph, pw) = spec.padding

    if spec.kind == "transposed":
        if spec.dilation != (1, 1):
            raise ValueError("transposed plans do not support rhs dilation")
        plans_h = dec.plan_phases_1d(h, r, sh, ph)
        plans_w = dec.plan_phases_1d(w, s, sw, pw)
        oh = dec.transposed_out_size(h, r, sh, ph)
        ow = dec.transposed_out_size(w, s, sw, pw)
        # single global pad: one residency of the input serves every phase
        # (phase tap origins become plan-time offsets into the padded plane)
        gl_h = max(0, max(p.pad[0] for p in plans_h))
        gh_h = max(0, max(p.pad[1] for p in plans_h))
        gl_w = max(0, max(p.pad[0] for p in plans_w))
        gh_w = max(0, max(p.pad[1] for p in plans_w))
        gpad = ((gl_h, gh_h), (gl_w, gh_w))
        hg, wg = h + gl_h + gh_h, w + gl_w + gh_w
        phases = []
        tap_off = acc_off = sum_uvt = 0
        for p_h in plans_h:
            for p_w in plans_w:
                taps = (p_h.taps, p_w.taps)
                out_hw = (p_h.out_size, p_w.out_size)
                phases.append(PhaseExec(
                    key=f"q{p_h.phase}x{p_w.phase}", q=(p_h.phase, p_w.phase),
                    rho=(p_h.rho, p_w.rho), taps=taps,
                    pad=(p_h.pad, p_w.pad), out_hw=out_hw,
                    tap_off=tap_off, acc_off=acc_off,
                    xoff=(gl_h - p_h.pad[0], gl_w - p_w.pad[0])))
                tap_off += taps[0] * taps[1]
                acc_off += out_hw[0] * out_hw[1]
                sum_uvt += out_hw[0] * out_hw[1] * taps[0] * taps[1]
        total_taps, sum_uv = tap_off, acc_off
        uniform = len({ex.out_hw for ex in phases}) == 1
        routes = tuple(_transposed_route(
            spec, hg, wg, (oh, ow), total_taps, sum_uv, sum_uvt, uniform,
            tuple(phases), itemsize, bb) for bb in BATCH_BUCKETS)
        # dx schedule (strided-conv form): tap (m, n) of the flipped/swapped
        # kernel reads full-kernel tap (r-1-m, s-1-n), which lives in phase
        # ((pl-r') % s) at superpack row tap_off + r'//s (tap units).
        by_q = {ex.q: ex for ex in phases}
        dx_taps = []
        for m in range(r):
            for nn in range(s):
                rp, sp = r - 1 - m, s - 1 - nn
                qh, qw = (ph[0] - rp) % sh, (pw[0] - sp) % sw
                ex = by_q[(qh, qw)]
                row = ex.tap_off + (rp // sh) * ex.taps[1] + (sp // sw)
                dx_taps.append((m, nn, row))
        bwd_pad = ((r - 1 - ph[0], r - 1 - ph[1]),
                   (s - 1 - pw[0], s - 1 - pw[1]))
        plan = ConvPlan(spec=spec, out_hw=(oh, ow), phases=tuple(phases),
                        gpad=gpad, total_taps=total_taps, sum_uv=sum_uv,
                        uniform=uniform, bwd_pad=bwd_pad,
                        dx_taps=tuple(dx_taps), routes=routes)

    elif spec.kind in ("conv", "dilated"):
        (dh, dw) = spec.dilation if spec.kind == "dilated" else (1, 1)
        hp, wp = h + ph[0] + ph[1], w + pw[0] + pw[1]
        oh = dec.single_out_size(h, r, sh, dh, ph)
        ow = dec.single_out_size(w, s, sw, dw, pw)
        if oh <= 0 or ow <= 0:
            raise ValueError(f"non-positive output {oh}x{ow}")
        routes = tuple(_single_route(spec, hp, wp, (oh, ow), itemsize, bb)
                       for bb in BATCH_BUCKETS)
        ex = PhaseExec(key="k", q=(0, 0), rho=(0, 0), taps=(r, s),
                       pad=spec.padding, out_hw=(oh, ow))
        # superpack row of tap (m, n) is m*S + n — recorded like the
        # transposed dx schedule so the backward never re-derives layout.
        taps_sched = tuple((m, nn, m * s + nn)
                           for m in range(r) for nn in range(s))
        plan = ConvPlan(spec=spec, out_hw=(oh, ow), phases=(ex,),
                        gpad=None, total_taps=r * s, sum_uv=oh * ow,
                        uniform=True, bwd_pad=None, dx_taps=taps_sched,
                        routes=routes)
    else:
        raise ValueError(f"unknown conv kind {spec.kind!r}")

    plan.build_ms = (time.perf_counter() - t0) * 1e3
    return plan


def plan_cache_info():
    return _plan_conv_heuristic.cache_info()


def plan_cache_clear():
    _plan_conv_heuristic.cache_clear()
    # tuned plans / loaded route caches index into the heuristic plans;
    # drop them together so patched-constant contexts rebuild both sides
    import sys
    autotune = sys.modules.get("repro.core.autotune")
    if autotune is not None:
        autotune.reset()
    spatial = sys.modules.get("repro.core.spatial")
    if spatial is not None:
        spatial.reset()


# ---------------------------------------------------------------------------
# executors (all geometry is plan-time constant)
# ---------------------------------------------------------------------------

def _deq(packed):
    """The f32 superpack view of either layout: identity on dense buffers,
    the dequant-on-the-fly broadcast multiply on a ``QuantizedSuperpack``
    (one ``convert_element_type`` + one ``mul`` ahead of the consuming
    GEMM — every fused route keeps its single dot_general)."""
    if isinstance(packed, QuantizedSuperpack):
        return packed.dequant()
    return packed


def _weight_cotangent(packed, dk):
    """The backward's cotangent for the packed operand.  Dense superpacks
    take the f32 dK directly.  Quantized superpacks chain through
    ``w = q · scale``: the int8 codes are non-differentiable (float0 —
    there is nothing to train there), the scale column gets the exact
    ``dscale[row] = Σ_n dK[row, n] · q[row, n]``."""
    if not isinstance(packed, QuantizedSuperpack):
        return dk.astype(packed.dtype)
    import numpy as np
    dscale = jnp.sum(dk.astype(jnp.float32) * packed.q.astype(jnp.float32),
                     axis=-1, keepdims=True).astype(packed.scale.dtype)
    dq = np.zeros(packed.q.shape, jax.dtypes.float0)
    return QuantizedSuperpack(dq, dscale)


def _exec_phase(xp: jax.Array, sub4: jax.Array, path: str, tiles: Pair | None,
                taps: Pair, out_hw: Pair, strides: Pair, dilation: Pair,
                out_dtype, interpret=None) -> jax.Array:
    """One planned stride/dilation correlation of pre-padded ``xp`` with the
    4-D sub-kernel, along the path chosen at plan time."""
    th, tw = taps
    u, v = out_hw
    (sh, sw), (dh, dw) = strides, dilation
    cc = xp.shape[-1]

    def tap_view(m, nn):
        return jax.lax.slice(
            xp, [0] * (xp.ndim - 3) + [m * dh, nn * dw, 0],
            list(xp.shape[:-3]) + [m * dh + (u - 1) * sh + 1,
                                   nn * dw + (v - 1) * sw + 1, cc],
            [1] * (xp.ndim - 3) + [sh, sw, 1])

    if path == "pallas":
        from repro.kernels.untangled_conv import untangled_conv2d_pallas
        lead = xp.shape[:-3]
        xp4 = xp.reshape((-1,) + xp.shape[-3:])
        y = untangled_conv2d_pallas(xp4, sub4, strides=strides,
                                    rhs_dilation=dilation,
                                    c_tile=tiles[0], n_tile=tiles[1],
                                    out_dtype=out_dtype, interpret=interpret)
        return y.reshape(lead + y.shape[1:])
    if path == "fused":
        buf = jnp.concatenate([tap_view(m, nn) for m in range(th)
                               for nn in range(tw)], axis=-1)
        w2 = sub4.reshape(th * tw * cc, sub4.shape[-1])
        y = jax.lax.dot_general(buf, w2, (((buf.ndim - 1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        return y.astype(out_dtype)
    acc = None
    for m in range(th):
        for nn in range(tw):
            xs = tap_view(m, nn)
            t = jax.lax.dot_general(
                xs, sub4[m, nn], (((xs.ndim - 1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            acc = t if acc is None else acc + t
    return acc.astype(out_dtype)


# -- transposed: fused single-launch executors ------------------------------

def _global_plane(plan: ConvPlan, x4: jax.Array) -> jax.Array:
    (glh, ghh), (glw, ghw) = plan.gpad
    if glh or ghh or glw or ghw:
        return jnp.pad(x4, ((0, 0), (glh, ghh), (glw, ghw), (0, 0)))
    return x4


def _phase_tap_view(xg: jax.Array, ex: PhaseExec, ti: int, tj: int):
    u, v = ex.out_hw
    return jax.lax.slice(
        xg, [0, ex.xoff[0] + ti, ex.xoff[1] + tj, 0],
        [xg.shape[0], ex.xoff[0] + ti + u, ex.xoff[1] + tj + v, xg.shape[3]])


def _fused_tap_fwd(plan: ConvPlan, xg: jax.Array, packed: jax.Array):
    """One wide GEMM, exact FLOPs: every tap view of every phase stacked
    against the superpack (ΣT, C, N), then per-phase tap-segment sums."""
    spec = plan.spec
    c, n = spec.in_c, spec.out_c
    b = xg.shape[0]
    views = []
    for ex in plan.phases:
        th, tw = ex.taps
        for t in range(th * tw):
            views.append(_phase_tap_view(xg, ex, *divmod(t, tw)))
    buf = jnp.stack(views, axis=0)                     # (ΣT, B, U, V, C)
    w3 = packed.reshape(plan.total_taps, c, n)
    yt = jax.lax.dot_general(buf, w3, (((4,), (1,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)
    outs = []
    for ex in plan.phases:
        th, tw = ex.taps
        u, v = ex.out_hw
        if th * tw == 0:
            outs.append(jnp.zeros((b, u, v, n), jnp.float32))
            continue
        outs.append(yt[ex.tap_off:ex.tap_off + th * tw].sum(axis=0))
    return outs


def _fused_plane_fwd(plan: ConvPlan, xg: jax.Array, packed: jax.Array):
    """One wide GEMM of the whole resident plane against the superpack viewed
    (C, ΣT·N); per-phase shifted slice-accumulate reads the tap planes."""
    spec = plan.spec
    c, n = spec.in_c, spec.out_c
    b, hg, wg, _ = xg.shape
    w2 = packed.reshape(plan.total_taps, c, n).transpose(1, 0, 2) \
        .reshape(c, plan.total_taps * n)
    yf = jax.lax.dot_general(xg.reshape(b * hg * wg, c), w2,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    yf = yf.reshape(b, hg, wg, plan.total_taps, n)
    outs = []
    for ex in plan.phases:
        th, tw = ex.taps
        u, v = ex.out_hw
        if th * tw == 0 or u == 0 or v == 0:
            outs.append(jnp.zeros((b, u, v, n), jnp.float32))
            continue
        acc = None
        for t in range(th * tw):
            ti, tj = divmod(t, tw)
            sl = jax.lax.slice(
                yf, [0, ex.xoff[0] + ti, ex.xoff[1] + tj, ex.tap_off + t, 0],
                [b, ex.xoff[0] + ti + u, ex.xoff[1] + tj + v,
                 ex.tap_off + t + 1, n])[..., 0, :]
            acc = sl if acc is None else acc + sl
        outs.append(acc)
    return outs


def _pixel_shuffle_fwd(plan: ConvPlan, x4: jax.Array, packed: jax.Array):
    """Sub-pixel route: the eligible transposed conv as ONE dense stride-1
    correlation + depth-to-space.

    Eligibility (``_pixel_shuffle_geom``) guarantees every phase shares the
    same pad, tap extent and ``(U, V) == (H, W)`` output, so one padded
    plane serves all Q phases and the superpack — phase-major ``(Q·T·C,
    N)`` — reshapes to ``(Q, T, C, N)`` with zero data movement.  The T
    shared tap views stack to ``(T, B, H, W, C)`` (concat, no transpose)
    and a single ``dot_general`` contracting (tap, C) against (T, C) yields
    ``(B, H, W, Q, N)``; the trailing reshape/transpose/reshape IS
    depth-to-space (phases are q_h-major, matching the ``(s_h, s_w)``
    split) and is the route's only transpose."""
    spec = plan.spec
    sh, sw = spec.strides
    c, n = spec.in_c, spec.out_c
    th, tw = plan.phases[0].taps
    h, w = spec.in_hw
    xp = pad_or_crop(x4, plan.phases[0].pad)
    b = xp.shape[0]
    views = [jax.lax.slice(xp, [0, ti, tj, 0], [b, ti + h, tj + w, c])
             for ti in range(th) for tj in range(tw)]
    buf = jnp.stack(views, axis=0)                    # (T, B, H, W, C)
    w4 = packed.reshape(sh * sw, th * tw, c, n)       # (Q, T, C, N)
    y = jax.lax.dot_general(buf, w4, (((0, 4), (1, 2)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y.reshape(b, h, w, sh, sw, n).transpose(0, 1, 3, 2, 4, 5)
    return y.reshape(b, h * sh, w * sw, n)


def _taps_fallback_fwd(plan: ConvPlan, xg: jax.Array, packed: jax.Array):
    """General fallback: still one global pad (phases read the single
    resident plane through plan-time offsets), but per-phase GEMMs."""
    spec = plan.spec
    c, n = spec.in_c, spec.out_c
    b = xg.shape[0]
    outs = {}
    for ex in plan.phases:
        th, tw = ex.taps
        u, v = ex.out_hw
        if th * tw == 0 or u == 0 or v == 0:
            outs[ex.q] = jnp.zeros((b, u, v, n), xg.dtype)
            continue
        seg = jax.lax.slice(packed, [ex.tap_off * c, 0],
                            [(ex.tap_off + th * tw) * c, n])
        if u * v <= _FUSE_MAX_ROWS and th * tw > 2:
            buf = jnp.concatenate(
                [_phase_tap_view(xg, ex, *divmod(t, tw))
                 for t in range(th * tw)], axis=-1)
            acc = jax.lax.dot_general(buf, seg, (((3,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
        else:
            acc = None
            for t in range(th * tw):
                xs = _phase_tap_view(xg, ex, *divmod(t, tw))
                wt = jax.lax.slice(packed, [(ex.tap_off + t) * c, 0],
                                   [(ex.tap_off + t + 1) * c, n])
                term = jax.lax.dot_general(
                    xs, wt, (((3,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
                acc = term if acc is None else acc + term
        outs[ex.q] = acc.astype(xg.dtype)
    return dec.interleave_phases(outs, spec.strides, plan.out_hw)


def _transposed_fwd(plan: ConvPlan, x, packed, interpret=None):
    spec = plan.spec
    lead = x.shape[:-3]
    x4 = x.reshape((-1,) + x.shape[-3:])
    b = x4.shape[0]
    if plan.total_taps == 0:
        y = jnp.zeros((b, *plan.out_hw, spec.out_c), x.dtype)
        return y.reshape(lead + y.shape[1:])
    # the bucket's route was sized against the byte caps at plan time —
    # a large batch lands on a bucket whose plane-GEMM intermediate fits
    route = plan.route_for_batch(b)
    path = route.path
    if path == "per_phase":
        # autotune-only route: the per-phase executor measured faster than
        # any fused whole-conv launch on this host (pads per phase, so it
        # bypasses the global plane below)
        y = _transposed_per_phase(plan, x4, _deq(packed))
        return y.reshape(lead + y.shape[1:])
    if path == "pixel_shuffle":
        # sub-pixel route: pads with the shared phase footprint directly
        # (eligibility guarantees one pad fits all phases), so it bypasses
        # the global plane below
        y = _pixel_shuffle_fwd(plan, x4, _deq(packed)).astype(x.dtype)
        return y.reshape(lead + y.shape[1:])
    xg = _global_plane(plan, x4)
    if path == "pallas":
        from repro.kernels.untangled_conv import untangled_deconv2d_pallas
        quant = isinstance(packed, QuantizedSuperpack)
        y = untangled_deconv2d_pallas(
            xg, packed.q if quant else packed,
            scales=packed.scale if quant else None,
            phases=plan.phases, out_hw=plan.out_hw,
            strides=spec.strides, sum_uv=plan.sum_uv,
            c_tile=route.tiles[0], n_tile=route.tiles[1],
            sp_tiles=route.sp_tiles, out_dtype=x.dtype, interpret=interpret)
    elif path in ("fused_tap", "fused_plane"):
        fwd = _fused_tap_fwd if path == "fused_tap" else _fused_plane_fwd
        outs = fwd(plan, xg, _deq(packed))
        y = dec.interleave_uniform(outs, spec.strides, plan.out_hw) \
            .astype(x.dtype) if plan.uniform else dec.interleave_phases(
                {ex.q: o.astype(x.dtype)
                 for ex, o in zip(plan.phases, outs)},
                spec.strides, plan.out_hw)
    else:
        y = _taps_fallback_fwd(plan, xg, _deq(packed))
    return y.reshape(lead + y.shape[1:])


def _transposed_per_phase(plan: ConvPlan, x, packed):
    """Pre-fusion executor: pad/copy + GEMM chain per phase, then
    stack/transpose interleave (the PR-1 baseline)."""
    spec = plan.spec
    c, n = spec.in_c, spec.out_c
    itemsize = jnp.dtype(spec.dtype).itemsize
    outs = {}
    for ex in plan.phases:
        th, tw = ex.taps
        u, v = ex.out_hw
        if th * tw == 0 or u == 0 or v == 0:
            outs[ex.q] = jnp.zeros(
                (*x.shape[:-3], u, v, n), x.dtype)
            continue
        sub4 = jax.lax.slice(packed, [ex.tap_off * c, 0],
                             [(ex.tap_off + th * tw) * c, n]) \
            .reshape(th, tw, c, n)
        xp = pad_or_crop(x, ex.pad)
        hp, wp = xp.shape[-3], xp.shape[-2]
        # same per-phase path policy PR 1 used (incl. per-phase Pallas when
        # the plan's backend asks for it) — this IS the measured baseline
        path, tiles = _choose_path(spec.backend, hp, wp, c, n, ex.taps,
                                   ex.out_hw, itemsize)
        outs[ex.q] = _exec_phase(xp, sub4, path, tiles, ex.taps, ex.out_hw,
                                 (1, 1), (1, 1), x.dtype)
    return dec.interleave_phases(outs, spec.strides, plan.out_hw)


# -- single-correlation ('conv' / 'dilated'): superpack executors -----------

def _single_geom(plan: ConvPlan):
    spec = plan.spec
    (dh, dw) = spec.dilation if spec.kind == "dilated" else (1, 1)
    return spec.strides, (dh, dw), spec.kernel_hw, plan.out_hw


def _single_tap_view(xp: jax.Array, m: int, nn: int, strides: Pair,
                     dilation: Pair, out_hw: Pair):
    """Tap (m, n)'s strided/dilated window of the resident padded plane —
    the zero-free read the naive engine replaces with kernel zero-insertion."""
    (sh, sw), (dh, dw) = strides, dilation
    u, v = out_hw
    return jax.lax.slice(
        xp, [0, m * dh, nn * dw, 0],
        [xp.shape[0], m * dh + (u - 1) * sh + 1, nn * dw + (v - 1) * sw + 1,
         xp.shape[3]],
        [1, sh, sw, 1])


def _single_fwd(plan: ConvPlan, x, packed, interpret=None):
    """Planned single-correlation forward on the (R·S·C, N) superpack:
    pad once, keep the plane resident, shift-and-add tap GEMMs."""
    spec = plan.spec
    strides, dilation, (r, s), out_hw = _single_geom(plan)
    c, n = spec.in_c, spec.out_c
    lead = x.shape[:-3]
    x4 = x.reshape((-1,) + x.shape[-3:])
    xp = pad_or_crop(x4, spec.padding)
    route = plan.route_for_batch(x4.shape[0])
    path = route.path
    if path == "pallas":
        from repro.kernels.untangled_conv import untangled_conv2d_superpack_pallas
        quant = isinstance(packed, QuantizedSuperpack)
        y = untangled_conv2d_superpack_pallas(
            xp, packed.q if quant else packed,
            scales=packed.scale if quant else None,
            taps_hw=(r, s), strides=strides,
            rhs_dilation=dilation, c_tile=route.tiles[0],
            n_tile=route.tiles[1], sp_tiles=route.sp_tiles,
            out_dtype=x.dtype, interpret=interpret)
    elif path == "fused_tap":
        # ONE wide GEMM: tap views concatenated channel-major in superpack
        # row order against the whole (R·S·C, N) buffer (dequantized on the
        # fly for int8 superpacks — still exactly one dot_general).
        buf = jnp.concatenate(
            [_single_tap_view(xp, m, nn, strides, dilation, out_hw)
             for m in range(r) for nn in range(s)], axis=-1)
        y = jax.lax.dot_general(buf, _deq(packed), (((3,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        y = y.astype(x.dtype)
    else:
        # per-tap shift-and-add GEMMs; panels are superpack rows [t·C,(t+1)·C)
        w = _deq(packed)
        acc = None
        for (m, nn, row) in plan.dx_taps:
            xs = _single_tap_view(xp, m, nn, strides, dilation, out_hw)
            panel = jax.lax.slice(w, [row * c, 0], [(row + 1) * c, n])
            t = jax.lax.dot_general(xs, panel, (((3,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
            acc = t if acc is None else acc + t
        y = acc.astype(x.dtype)
    return y.reshape(lead + y.shape[1:])


# ---------------------------------------------------------------------------
# transposed conv: custom VJP on the superpack (§3.2.3, Fig. 6)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _planned_transposed(plan: ConvPlan, x, packed):
    return _transposed_fwd(plan, x, packed)


def _pt_fwd(plan, x, packed):
    return _transposed_fwd(plan, x, packed), (x, packed)


def _pt_bwd(plan, res, dy):
    x, packed = res
    spec = plan.spec
    h, w = spec.in_hw
    r, s = spec.kernel_hw
    (sh, sw) = spec.strides
    c = spec.in_c
    x4 = x.reshape((-1,) + x.shape[-3:])
    dy4 = dy.reshape((-1,) + dy.shape[-3:])
    dy_p = pad_or_crop(dy4, plan.bwd_pad)

    # dx — strided-conv form, panels fetched from the superpack at the
    # plan-time row offsets (dequantized once for int8 superpacks).
    wdq = _deq(packed)
    acc = None
    for (m, nn, row) in plan.dx_taps:
        panel = jax.lax.slice(wdq, [row * c, 0],
                              [(row + 1) * c, spec.out_c])   # (C, N)
        wnd = jax.lax.slice(
            dy_p, [0, m, nn, 0],
            [dy_p.shape[0], m + sh * (h - 1) + 1, nn + sw * (w - 1) + 1,
             dy_p.shape[3]], [1, sh, sw, 1])
        t = jax.lax.dot_general(wnd, panel, (((wnd.ndim - 1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        acc = t if acc is None else acc + t
    dx = acc.astype(x.dtype).reshape(x.shape)

    # dK — dilated-kernel form, emitted directly in superpack order.
    dk_segs = []
    for ex in plan.phases:
        th, tw = ex.taps
        if th * tw == 0:
            continue
        rows = []
        for t_h in range(th):
            rr = ex.rho[0] + sh * t_h
            cols = []
            for t_w in range(tw):
                ss = ex.rho[1] + sw * t_w
                wnd = jax.lax.slice(
                    dy_p, [0, r - 1 - rr, s - 1 - ss, 0],
                    [dy_p.shape[0], r - 1 - rr + sh * (h - 1) + 1,
                     s - 1 - ss + sw * (w - 1) + 1, dy_p.shape[3]],
                    [1, sh, sw, 1])
                cols.append(jnp.einsum("buvc,buvn->cn", x4, wnd,
                                       preferred_element_type=jnp.float32))
            rows.append(jnp.stack(cols, 0))
        sub = jnp.stack(rows, 0)                      # (T_h, T_w, C, N)
        dk_segs.append(sub.reshape(th * tw * c, spec.out_c))
    if dk_segs:
        dk = jnp.concatenate(dk_segs, axis=0)
    else:
        dk = jnp.zeros(packed.shape, jnp.float32)
    return dx, _weight_cotangent(packed, dk)


_planned_transposed.defvjp(_pt_fwd, _pt_bwd)


# ---------------------------------------------------------------------------
# single correlation ('conv' / 'dilated'): custom VJP on the superpack,
# mirroring _pt_bwd — no flipped kernel is ever assembled, no zero inserted
# ---------------------------------------------------------------------------

def _unpad_transpose(dxp: jax.Array, pads, in_hw: Pair) -> jax.Array:
    """Exact transpose of ``pad_or_crop``: slice off the positive pads,
    zero-pad back anything the forward cropped (negative pads)."""
    (ph, pw) = pads
    hp, wp = dxp.shape[-3], dxp.shape[-2]
    dx = dxp[..., max(0, ph[0]):hp - max(0, ph[1]),
             max(0, pw[0]):wp - max(0, pw[1]), :]
    grow = [(0, 0)] * (dxp.ndim - 3) + [
        (max(0, -ph[0]), max(0, -ph[1])),
        (max(0, -pw[0]), max(0, -pw[1])), (0, 0)]
    if any(g != (0, 0) for g in grow):
        dx = jnp.pad(dx, grow)
    assert dx.shape[-3] == in_hw[0] and dx.shape[-2] == in_hw[1]
    return dx


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _planned_single(plan: ConvPlan, x, packed):
    return _single_fwd(plan, x, packed)


def _ps_fwd(plan, x, packed):
    return _single_fwd(plan, x, packed), (x, packed)


def _ps_bwd(plan, res, dy):
    x, packed = res
    spec = plan.spec
    strides, dilation, (r, s), (oh, ow) = _single_geom(plan)
    (sh, sw), (dh, dw) = strides, dilation
    c, n = spec.in_c, spec.out_c
    x4 = x.reshape((-1,) + x.shape[-3:])
    dy4 = dy.reshape((-1,) + dy.shape[-3:])
    xp = pad_or_crop(x4, spec.padding)
    b, hp, wp = xp.shape[0], xp.shape[1], xp.shape[2]
    # the fused backward materializes (B, OH, OW, ΣT, C) f32 buffers; the
    # bucket's route carries the same plane-bytes verdict that governs the
    # forward, falling back to per-tap GEMMs on exactly the plans that need it
    fused_bwd = plan.route_for_batch(b).fused_bwd

    # dx — transposed-tap form: GEMMs of dy against superpack (C, N) panels
    # (one wide GEMM over the (ΣT, C, N) view when the buffer fits), each
    # tap's plane scattered back through the exact transpose of its forward
    # strided/dilated read.
    wdq = _deq(packed)
    g = None
    if fused_bwd:
        w3 = wdq.reshape(r * s, c, n)
        g = jax.lax.dot_general(dy4, w3, (((3,), (2,)), ((), ())),
                                preferred_element_type=jnp.float32)
        # g: (B, OH, OW, ΣT, C)
    dxp = jnp.zeros((b, hp, wp, c), jnp.float32)
    for (m, nn, row) in plan.dx_taps:
        if g is not None:
            gt = g[..., row, :]
        else:
            panel = jax.lax.slice(wdq, [row * c, 0], [(row + 1) * c, n])
            gt = jax.lax.dot_general(dy4, panel, (((3,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32)
        dxp = dxp.at[:, m * dh:m * dh + (oh - 1) * sh + 1:sh,
                     nn * dw:nn * dw + (ow - 1) * sw + 1:sw, :].add(gt)
    dx = _unpad_transpose(dxp, spec.padding, spec.in_hw)
    dx = dx.astype(x.dtype).reshape(x.shape)

    # dK — tap views of the resident plane against dy (one GEMM over the
    # stacked views when they fit, else per tap), emitted directly in
    # superpack row order (paper Fig. 6 step 3, packed layout).
    if fused_bwd:
        buf = jnp.stack(
            [_single_tap_view(xp, m, nn, strides, dilation, (oh, ow))
             for (m, nn, _) in plan.dx_taps], axis=0)
        dk3 = jax.lax.dot_general(buf, dy4,
                                  (((1, 2, 3), (0, 1, 2)), ((), ())),
                                  preferred_element_type=jnp.float32)
        dk = dk3.reshape(r * s * c, n)
    else:
        dk = jnp.concatenate(
            [jax.lax.dot_general(
                _single_tap_view(xp, m, nn, strides, dilation, (oh, ow)),
                dy4, (((0, 1, 2), (0, 1, 2)), ((), ())),
                preferred_element_type=jnp.float32)
             for (m, nn, _) in plan.dx_taps], axis=0)
    return dx, _weight_cotangent(packed, dk)


_planned_single.defvjp(_ps_fwd, _ps_bwd)
