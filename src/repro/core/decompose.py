"""Phase decomposition of transposed (fractionally-strided) convolutions.

This is the paper's §3.1 contribution, in exact index algebra.

Reference semantics (the oracle everything is tested against)::

    y = lax.conv_general_dilated(
        x, K, window_strides=(1, 1),
        padding=((pl_h, ph_h), (pl_w, ph_w)),
        lhs_dilation=(s_h, s_w),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))

i.e. insert ``s-1`` zeros between input pixels, pad, and correlate with K.
The naive engine (DarkNet's ``im2col`` path, see ``reference.py``) does exactly
that, materializing the zero-inserted tensor.

The decomposition: write each output index ``o = s*u + q`` with *phase*
``q = o mod s``.  In 1-D::

    y[o] = sum_r  x_hat[o - pl + r] * K[r]          (x_hat = s-dilated x)

non-zero only when ``(o - pl + r) % s == 0``, i.e. taps ``r ≡ (pl - q) (mod s)``.
Writing ``rho_q = (pl - q) % s`` and ``r = rho_q + s*t``::

    y[s*u + q] = sum_t  x[u + a_q + t] * K[rho_q + s*t],
    a_q = (q + rho_q - pl) // s            (exact integer)

— a *dense, stride-1* correlation of the raw input with the sub-kernel
``K[rho_q::s]``, shifted by ``a_q``.  The s_h*s_w phase outputs are disjoint
and interleave into y.  No zero is ever materialized or multiplied.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

Pair = tuple[int, int]


def transposed_out_size(in_size: int, k: int, stride: int, pad: Pair) -> int:
    """Output length of the lhs-dilated correlation along one dim."""
    dil = (in_size - 1) * stride + 1
    return dil + pad[0] + pad[1] - k + 1


def single_out_size(in_size: int, k: int, stride: int, dilation: int,
                    pad: Pair) -> int:
    """Output length of the single-correlation (strided / rhs-dilated) conv
    along one dim: the effective tap reach is ``(k-1)·d + 1`` but the tap
    *count* stays ``k`` — the zero-free fact the superpack layout encodes.
    Delegates to ``untangle.conv_out_size`` (one formula, one owner)."""
    from repro.core.untangle import conv_out_size
    return conv_out_size(in_size, k, stride, dilation, pad)


@dataclasses.dataclass(frozen=True)
class PhasePlan1D:
    """Everything needed to compute output phase q along one spatial dim."""

    phase: int          # q
    rho: int            # first tap index used by this phase
    taps: int           # T_q = number of taps (len(range(rho, R, s)))
    pad: Pair           # (lo, hi) padding (possibly negative = crop) for the
                        # stride-1 correlation of raw x with K[rho::s]
    out_size: int       # U_q = number of output pixels with this phase


def plan_phases_1d(in_size: int, k: int, stride: int, pad: Pair) -> list[PhasePlan1D]:
    """Build the per-phase plans along one dimension."""
    if stride < 1:
        raise ValueError(f"stride must be >= 1, got {stride}")
    out = transposed_out_size(in_size, k, stride, pad)
    if out <= 0:
        raise ValueError(f"non-positive output size {out}")
    pl_, _ = pad
    plans = []
    for q in range(stride):
        rho = (pl_ - q) % stride
        taps = len(range(rho, k, stride))
        u_q = max(0, -(-(out - q) // stride))  # ceil((out - q)/s), clipped
        if taps == 0 or u_q == 0:
            plans.append(PhasePlan1D(q, rho, taps, (0, 0), u_q))
            continue
        a_q = (q + rho - pl_) // stride
        assert (q + rho - pl_) % stride == 0
        lo = -a_q
        # conv output length: in + lo + hi - taps + 1 == u_q
        hi = u_q - 1 + taps - in_size - lo
        plans.append(PhasePlan1D(q, rho, taps, (lo, hi), u_q))
    assert sum(p.out_size for p in plans) == out
    return plans


def decompose_kernel(kernel: jax.Array, strides: Sequence[int],
                     padding: Sequence[Pair]) -> dict[Pair, jax.Array]:
    """Slice the HWIO kernel into per-phase sub-kernels K[rho_h::s_h, rho_w::s_w].

    Returns {(q_h, q_w): sub_kernel}.  Sub-kernels may be empty (0 taps) for
    strides larger than the kernel — callers emit zeros for those phases.
    """
    r, s = kernel.shape[0], kernel.shape[1]
    (sh, sw) = strides
    (ph, pw) = padding
    subs = {}
    for qh in range(sh):
        rho_h = (ph[0] - qh) % sh
        for qw in range(sw):
            rho_w = (pw[0] - qw) % sw
            subs[(qh, qw)] = kernel[rho_h::sh, rho_w::sw]
    return subs


def interleave_uniform(phase_outputs: Sequence[jax.Array],
                       strides: Sequence[int], out_hw: Pair) -> jax.Array:
    """Interleave uniform-extent phase outputs (phase-ordered list, q_h-major)
    with a single stack + transpose + reshape — the one layout transform the
    fused single-launch executors emit after their wide GEMM.

    Requires every phase output to share (U, V) with ``U*s_h == out_h`` and
    ``V*s_w == out_w`` (guaranteed by ``ConvPlan.uniform``).
    """
    (sh, sw) = strides
    oh, ow = out_hw
    b = phase_outputs[0].shape[0]
    n = phase_outputs[0].shape[-1]
    u, v = phase_outputs[0].shape[-3], phase_outputs[0].shape[-2]
    y = jnp.stack(phase_outputs, axis=0).reshape(sh, sw, b, u, v, n)
    return y.transpose(2, 3, 0, 4, 1, 5).reshape(b, oh, ow, n)


def interleave_phases(phase_outputs: dict[Pair, jax.Array],
                      strides: Sequence[int], out_hw: Pair) -> jax.Array:
    """Interleave per-phase outputs O[.., s_h*u+q_h, s_w*v+q_w, :] = y_q[.., u, v, :].

    Fast path (all phases same spatial size, out divisible by stride): a pure
    stack + transpose + reshape — a layout transform, no scatter.  This is the
    TPU-native replacement for the paper's race-free scattered writes.
    """
    (sh, sw) = strides
    oh, ow = out_hw
    any_y = next(iter(phase_outputs.values()))
    uniform = (oh % sh == 0 and ow % sw == 0 and all(
        y.shape[-3] == oh // sh and y.shape[-2] == ow // sw
        for y in phase_outputs.values()))
    if uniform:
        # (B, U, V, N) per phase -> (B, U, sh, V, sw, N) -> (B, oh, ow, N)
        rows = []
        for qh in range(sh):
            cols = [phase_outputs[(qh, qw)] for qw in range(sw)]
            rows.append(jnp.stack(cols, axis=-2))      # (B, U, V, sw, N)
        y = jnp.stack(rows, axis=-4)                   # (B, U, sh, V, sw, N)
        b = y.shape[:-5]
        return y.reshape(*b, oh, ow, any_y.shape[-1])
    # General path: strided update into zeros.
    out = jnp.zeros((*any_y.shape[:-3], oh, ow, any_y.shape[-1]), any_y.dtype)
    for (qh, qw), y in phase_outputs.items():
        if y.shape[-3] == 0 or y.shape[-2] == 0:
            continue
        out = out.at[..., qh::sh, qw::sw, :].set(y)
    return out
