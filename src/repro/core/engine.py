"""HUGE² public ops: thin dispatchers over the plan/executor engine.

Every convolution site is described by a ``ConvSpec`` and compiled exactly
once by ``repro.core.plan.plan_conv`` into a ``ConvPlan`` (keyed LRU cache).
The plan owns all the geometry the old engine recomputed inside every jitted
call — phase decomposition (§3.1), untangled execution paths (§3.2), VMEM
tile selection, and the §3.2.3 backward schedules — so these wrappers only
build the spec from argument shapes and hand off.

Forward ops
-----------
- ``huge_conv_transpose2d``  — §3.1 phase decomposition + §3.2 untangling.
- ``huge_conv2d``            — strided conv (discriminator) via untangling.
- ``huge_dilated_conv2d``    — §3.2.2 untangled atrous conv (no kernel zeros).

Backward (§3.2.3, Fig. 6) lives on the plans as ``jax.custom_vjp`` rules that
run on the *packed* weight layout — for **all three kinds**:
- grad-wrt-input of a transposed conv == a *strided* conv of the output
  derivative maps, with tap panels fetched straight from the packed buffers.
- grad-wrt-kernel == a *dilated* convolution over the derivative maps,
  emitted directly in the packed per-phase layout.
- grad-wrt-input of a strided/dilated conv == the mirrored transposed-tap
  form (one GEMM of dy against the superpack viewed (ΣT, C, N), per-tap
  shift-and-add); grad-wrt-kernel is emitted in superpack row order.

Note these wrappers take the full HWIO kernel and therefore *pack per call*
(the slicing is traced into the jitted computation).  That is fine for
experimentation and keeps the seed API; serving and training hot paths
should hold packed weights and call ``plan.apply`` directly — see
``repro.models.gan`` for the load-time pattern.

Every VJP here is validated in tests against ``jax.vjp`` of the XLA oracle.
"""
from __future__ import annotations

from repro.core import decompose as dec
from repro.core.plan import conv_spec, norm_padding, plan_conv

# kept under the old private name for callers inside the package
_norm_padding = norm_padding


def huge_conv_transpose2d(x, kernel, strides=(2, 2), padding=((2, 2), (2, 2)),
                          backend="xla"):
    """Transposed conv via a cached plan (phase decomposition + untangling).

    x: (...,H,W,C); kernel: (R,S,C,N) HWIO.  Semantics identical to
    ``lax.conv_general_dilated(..., lhs_dilation=strides, padding=padding)``.
    """
    spec = conv_spec("transposed", x.shape, kernel.shape, strides=strides,
                     padding=padding, dtype=x.dtype, backend=backend)
    return plan_conv(spec).apply_kernel(x, kernel)


def huge_conv2d(x, kernel, strides=(1, 1), padding=((0, 0), (0, 0)),
                backend="xla"):
    """Standard / strided conv via untangling (discriminator layers)."""
    spec = conv_spec("conv", x.shape, kernel.shape, strides=strides,
                     padding=padding, dtype=x.dtype, backend=backend)
    return plan_conv(spec).apply(x, kernel)


def huge_dilated_conv2d(x, kernel, *, dilation=(2, 2), strides=(1, 1),
                        padding=((0, 0), (0, 0)), backend="xla"):
    """Atrous conv via untangling — the dilated kernel is never materialized.

    Differentiable through the plan's custom VJP on the superpacked layout
    (the HWIO kernel is flattened tap-major on the way in — a free reshape).
    """
    spec = conv_spec("dilated", x.shape, kernel.shape, strides=strides,
                     padding=padding, dilation=dilation, dtype=x.dtype,
                     backend=backend)
    return plan_conv(spec).apply(x, kernel)


# ---------------------------------------------------------------------------
# legacy offline-decomposition API (pre-plan era), kept as thin adapters
# ---------------------------------------------------------------------------

def precompute_transposed_weights(kernel, strides, padding):
    """Offline: slice + flatten phase sub-kernels.  Returns
    {(qh, qw): (T_h*T_w*C, N) array} — tap-major, GEMM-ready.

    Same layout as ``ConvPlan.pack`` but tuple-keyed; prefer building a plan
    and calling ``plan.pack`` directly.
    """
    padding = norm_padding(padding, kernel.shape[:2])
    subs = dec.decompose_kernel(kernel, tuple(strides), padding)
    return {q: sub.reshape(-1, sub.shape[-1]) for q, sub in subs.items()}


def huge_conv_transpose2d_pre(x, pre_subs, kernel_hw, strides=(2, 2),
                              padding=((2, 2), (2, 2))):
    """Transposed conv with offline-decomposed weights (legacy entry).

    Adapts the tuple-keyed per-phase ``pre_subs`` onto the planned executor:
    ``ConvPlan.as_superpack`` concatenates the phase buffers into the
    superpacked layout at the plan's row offsets, then execution is
    ``ConvPlan.apply`` — not a separate code path.
    """
    n = max(sub.shape[-1] for sub in pre_subs.values())
    spec = conv_spec("transposed", x.shape,
                     (kernel_hw[0], kernel_hw[1], x.shape[-1], n),
                     strides=strides, padding=padding, dtype=x.dtype,
                     backend="xla")
    plan = plan_conv(spec)
    return plan.apply(x, plan.as_superpack(pre_subs))
