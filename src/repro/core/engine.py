"""HUGE² public ops: decomposed + untangled deconvolutions with the paper's
GAN-training backward formulations wired as ``jax.custom_vjp``.

Forward ops
-----------
- ``huge_conv_transpose2d``  — §3.1 phase decomposition + §3.2 untangling.
- ``huge_conv2d``            — strided conv (discriminator) via untangling.
- ``huge_dilated_conv2d``    — §3.2.2 untangled atrous conv (no kernel zeros).

Backward (§3.2.3, Fig. 6)
-------------------------
- grad-wrt-input of a transposed conv == a *strided* conv of the output
  derivative maps (discriminator-style) — computed through the engine.
- grad-wrt-kernel == a *dilated* convolution in which one operand acts as an
  s-dilated kernel sliding over the other, contracted over the batch — the
  paper's "make C copies of the N derivative maps to form dilated kernels".

Every VJP here is validated in tests against ``jax.vjp`` of the XLA oracle.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import decompose as dec
from repro.core import untangle as unt
from repro.core.untangle import pad_or_crop

Pair = tuple[int, int]


def _norm_padding(padding, k_hw) -> tuple[Pair, Pair]:
    if isinstance(padding, str):
        r, s = k_hw
        if padding.upper() == "SAME":
            return ((r // 2, (r - 1) // 2), (s // 2, (s - 1) // 2))
        if padding.upper() == "VALID":
            return ((0, 0), (0, 0))
        raise ValueError(padding)
    (a, b) = padding
    if isinstance(a, int):
        return ((a, a), (b, b))
    return (tuple(a), tuple(b))


# ---------------------------------------------------------------------------
# forward implementations
# ---------------------------------------------------------------------------

def _conv_transpose_fwd(x, kernel, strides, padding, backend="xla"):
    """Phase-decomposed, untangled transposed conv (NHWC / HWIO)."""
    r, s, c, n = kernel.shape
    (sh, sw), (ph, pw) = strides, padding
    h, w = x.shape[-3], x.shape[-2]
    plans_h = dec.plan_phases_1d(h, r, sh, ph)
    plans_w = dec.plan_phases_1d(w, s, sw, pw)
    oh = dec.transposed_out_size(h, r, sh, ph)
    ow = dec.transposed_out_size(w, s, sw, pw)
    subs = dec.decompose_kernel(kernel, strides, padding)
    outs = {}
    for qh in range(sh):
        for qw in range(sw):
            p_h, p_w = plans_h[qh], plans_w[qw]
            sub = subs[(qh, qw)]
            if p_h.taps == 0 or p_w.taps == 0 or p_h.out_size == 0 or p_w.out_size == 0:
                outs[(qh, qw)] = jnp.zeros(
                    (*x.shape[:-3], p_h.out_size, p_w.out_size, n), x.dtype)
                continue
            if backend == "pallas":
                from repro.kernels import ops as kops
                outs[(qh, qw)] = kops.untangled_conv2d(
                    x, sub, strides=(1, 1), padding=(p_h.pad, p_w.pad))
            else:
                outs[(qh, qw)] = unt.untangled_conv2d(
                    x, sub, strides=(1, 1), padding=(p_h.pad, p_w.pad))
    return dec.interleave_phases(outs, strides, (oh, ow))


def _conv_fwd(x, kernel, strides, padding, backend="xla"):
    if backend == "pallas":
        from repro.kernels import ops as kops
        return kops.untangled_conv2d(x, kernel, strides=strides, padding=padding)
    return unt.untangled_conv2d(x, kernel, strides=strides, padding=padding)


# ---------------------------------------------------------------------------
# §3.2.3 gradient building blocks
# ---------------------------------------------------------------------------

def _flip_swap(kernel):
    """(R,S,C,N) -> spatially flipped, channels swapped (R,S,N,C)."""
    return jnp.transpose(jnp.flip(kernel, (0, 1)), (0, 1, 3, 2))


def _grad_kernel_dilated(inp, dy, k_hw, strides, padding):
    """dK for a *transposed* conv: slide ``inp`` (H taps, s-dilated) over the
    padded derivative maps, contracting batch — the paper's dilated-kernel
    convolution, computed tap-by-tap with GEMMs (no zeros materialized).

    dK[r, s', c, n] = sum_{b,u,v} inp[b,u,v,c] * dy_pad[b, sh*u + R-1-r, sw*v + S-1-s', n]
    """
    r, s = k_hw
    (sh, sw), (ph, pw) = strides, padding
    hh, ww = inp.shape[-3], inp.shape[-2]
    dy_p = pad_or_crop(dy, ((r - 1 - ph[0], r - 1 - ph[1]),
                            (s - 1 - pw[0], s - 1 - pw[1])))
    rows = []
    for rr in range(r):
        cols = []
        for ss in range(s):
            wnd = jax.lax.slice(
                dy_p, [0, r - 1 - rr, s - 1 - ss, 0],
                [dy_p.shape[0], r - 1 - rr + sh * (hh - 1) + 1,
                 s - 1 - ss + sw * (ww - 1) + 1, dy_p.shape[3]],
                [1, sh, sw, 1])
            cols.append(jnp.einsum("buvc,buvn->cn", inp, wnd,
                                   preferred_element_type=jnp.float32))
        rows.append(jnp.stack(cols, 0))
    return jnp.stack(rows, 0)


def _grad_kernel_strided(x, dy, k_hw, strides, padding):
    """dK for a *strided* conv (discriminator): correlate the padded input
    with the s-dilated derivative maps (paper Fig. 6 step 3).

    dK[r, s', c, n] = sum_{b,o,o2} x_pad[b, sh*o + r, sw*o2 + s', c] * dy[b,o,o2,n]
    """
    r, s = k_hw
    (sh, sw), (ph, pw) = strides, padding
    oh, ow = dy.shape[-3], dy.shape[-2]
    x_p = pad_or_crop(x, (ph, pw))
    rows = []
    for rr in range(r):
        cols = []
        for ss in range(s):
            wnd = jax.lax.slice(
                x_p, [0, rr, ss, 0],
                [x_p.shape[0], rr + sh * (oh - 1) + 1,
                 ss + sw * (ow - 1) + 1, x_p.shape[3]],
                [1, sh, sw, 1])
            cols.append(jnp.einsum("bouc,boun->cn", wnd, dy,
                                   preferred_element_type=jnp.float32))
        rows.append(jnp.stack(cols, 0))
    return jnp.stack(rows, 0)


# ---------------------------------------------------------------------------
# public ops with custom VJPs
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# offline weight decomposition (serving fast path, §Perf P0)
# ---------------------------------------------------------------------------
#
# Slicing the full kernel into phase sub-kernels *inside* the jitted call
# costs ~R*S strided copies of the whole weight per invocation — measured
# 25-30 ms/call on DCGAN DC1, dwarfing the 5 ms of useful GEMMs.  A real
# engine (like the paper's) decomposes weights once at model-load time.

def precompute_transposed_weights(kernel, strides, padding):
    """Offline: slice + flatten phase sub-kernels.  Returns
    {(qh, qw): (T_h*T_w*C, N) array} — tap-major, GEMM-ready."""
    padding = _norm_padding(padding, kernel.shape[:2])
    subs = dec.decompose_kernel(kernel, tuple(strides), padding)
    out = {}
    for q, sub in subs.items():
        th, tw, c, n = sub.shape
        out[q] = sub.reshape(th * tw * c, n) if th * tw else sub
    return out


def huge_conv_transpose2d_pre(x, pre_subs, kernel_hw, strides=(2, 2),
                              padding=((2, 2), (2, 2))):
    """Transposed conv with offline-decomposed weights: per phase, build the
    tap buffer from the *raw* input (zero-free) and issue one wide GEMM."""
    r, s = kernel_hw
    strides = tuple(strides)
    padding = _norm_padding(padding, kernel_hw)
    (sh, sw), (ph, pw) = strides, padding
    h, w = x.shape[-3], x.shape[-2]
    plans_h = dec.plan_phases_1d(h, r, sh, ph)
    plans_w = dec.plan_phases_1d(w, s, sw, pw)
    oh = dec.transposed_out_size(h, r, sh, ph)
    ow = dec.transposed_out_size(w, s, sw, pw)
    outs = {}
    for qh in range(sh):
        for qw in range(sw):
            p_h, p_w = plans_h[qh], plans_w[qw]
            sub = pre_subs[(qh, qw)]
            if p_h.taps == 0 or p_w.taps == 0 or min(p_h.out_size,
                                                     p_w.out_size) == 0:
                outs[(qh, qw)] = jnp.zeros(
                    (*x.shape[:-3], p_h.out_size, p_w.out_size,
                     sub.shape[-1]), x.dtype)
                continue
            xp = pad_or_crop(x, (p_h.pad, p_w.pad))
            uo, vo = p_h.out_size, p_w.out_size
            buf = jnp.concatenate(
                [jax.lax.slice(
                    xp, [0] * (x.ndim - 3) + [m, n, 0],
                    list(xp.shape[:-3]) + [m + uo, n + vo, xp.shape[-1]])
                 for m in range(p_h.taps) for n in range(p_w.taps)], axis=-1)
            y = jax.lax.dot_general(
                buf, sub, (((buf.ndim - 1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            outs[(qh, qw)] = y.astype(x.dtype)
    return dec.interleave_phases(outs, strides, (oh, ow))


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def huge_conv_transpose2d(x, kernel, strides=(2, 2), padding=((2, 2), (2, 2)),
                          backend="xla"):
    """Transposed conv via phase decomposition + untangling.

    x: (B,H,W,C); kernel: (R,S,C,N) HWIO.  Semantics identical to
    ``lax.conv_general_dilated(..., lhs_dilation=strides, padding=padding)``.
    """
    padding = _norm_padding(padding, kernel.shape[:2])
    return _conv_transpose_fwd(x, kernel, tuple(strides), padding, backend)


def _ct_fwd(x, kernel, strides, padding, backend):
    padding = _norm_padding(padding, kernel.shape[:2])
    return _conv_transpose_fwd(x, kernel, tuple(strides), padding, backend), (x, kernel)


def _ct_bwd(strides, padding, backend, res, dy):
    x, kernel = res
    r, s = kernel.shape[0], kernel.shape[1]
    padding = _norm_padding(padding, (r, s))
    (ph, pw) = padding
    # dx: strided conv of dy with the flipped/swapped kernel (discriminator
    # form) — routed through the Pallas kernel when the fwd was
    bwd_pads = ((r - 1 - ph[0], r - 1 - ph[1]),
                (s - 1 - pw[0], s - 1 - pw[1]))
    if backend == "pallas":
        from repro.kernels import ops as kops
        dx = kops.untangled_conv2d(dy, _flip_swap(kernel),
                                   strides=tuple(strides),
                                   padding=bwd_pads).astype(x.dtype)
    else:
        dx = unt.untangled_conv2d(
            dy, _flip_swap(kernel), strides=tuple(strides),
            padding=bwd_pads, out_dtype=x.dtype)
    # dK: dilated-kernel convolution over the derivative maps (paper Fig. 6)
    dk = _grad_kernel_dilated(x, dy, (r, s), tuple(strides), padding)
    return dx, dk.astype(kernel.dtype)


huge_conv_transpose2d.defvjp(_ct_fwd, _ct_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def huge_conv2d(x, kernel, strides=(1, 1), padding=((0, 0), (0, 0)),
                backend="xla"):
    """Standard / strided conv via untangling (discriminator layers)."""
    padding = _norm_padding(padding, kernel.shape[:2])
    return _conv_fwd(x, kernel, tuple(strides), padding, backend)


def _c_fwd(x, kernel, strides, padding, backend):
    padding = _norm_padding(padding, kernel.shape[:2])
    return _conv_fwd(x, kernel, tuple(strides), padding, backend), (x, kernel)


def _c_bwd(strides, padding, backend, res, dy):
    x, kernel = res
    r, s = kernel.shape[0], kernel.shape[1]
    padding = _norm_padding(padding, (r, s))
    (ph, pw) = padding
    # dx of a strided conv == transposed conv of dy (generator form).  When the
    # stride does not tile the input exactly, the tail input pixels still
    # receive gradient from in-range dy taps: extend the high padding so the
    # transposed conv emits exactly H (resp. W) outputs.
    h, w = x.shape[-3], x.shape[-2]
    (sh, sw) = strides
    oh, ow = dy.shape[-3], dy.shape[-2]
    def_h = h - ((oh - 1) * sh + (r - 1 - ph[0]) + (r - 1 - ph[1]) - r + 2)
    def_w = w - ((ow - 1) * sw + (s - 1 - pw[0]) + (s - 1 - pw[1]) - s + 2)
    dx = _conv_transpose_fwd(
        dy, _flip_swap(kernel), tuple(strides),
        ((r - 1 - ph[0], r - 1 - ph[1] + def_h),
         (s - 1 - pw[0], s - 1 - pw[1] + def_w)),
        "xla").astype(x.dtype)
    assert dx.shape[-3:] == x.shape[-3:], (dx.shape, x.shape)
    dk = _grad_kernel_strided(x, dy, (r, s), tuple(strides), padding)
    return dx, dk.astype(kernel.dtype)


huge_conv2d.defvjp(_c_fwd, _c_bwd)


def huge_dilated_conv2d(x, kernel, *, dilation=(2, 2), strides=(1, 1),
                        padding=((0, 0), (0, 0)), backend="xla"):
    """Atrous conv via untangling — the dilated kernel is never materialized.

    Differentiable through JAX autodiff (slices + GEMMs only).
    """
    padding = _norm_padding(padding, kernel.shape[:2])
    if backend == "pallas":
        from repro.kernels import ops as kops
        return kops.untangled_conv2d(x, kernel, strides=tuple(strides),
                                     padding=padding,
                                     rhs_dilation=tuple(dilation))
    return unt.untangled_conv2d(x, kernel, strides=tuple(strides),
                                padding=padding, rhs_dilation=tuple(dilation))
