"""HUGE² core: phase decomposition + untangling, planned once per site."""
from repro.core.decompose import (decompose_kernel, interleave_phases,
                                  interleave_uniform, plan_phases_1d,
                                  transposed_out_size)
from repro.core.engine import (huge_conv2d, huge_conv_transpose2d,
                               huge_dilated_conv2d)
from repro.core.plan import (BATCH_BUCKETS, ConvPlan, ConvSpec, Route,
                             conv_spec, plan_cache_clear, plan_cache_info,
                             plan_conv)
from repro.core.untangle import (untangled_conv2d, untangled_depthwise_conv1d)
from repro.core.autotune import (AutotunePolicy, RouteCache, measure_fn)
from repro.core import reference

__all__ = [
    "decompose_kernel", "interleave_phases", "interleave_uniform",
    "plan_phases_1d",
    "transposed_out_size", "huge_conv2d", "huge_conv_transpose2d",
    "huge_dilated_conv2d", "untangled_conv2d", "untangled_depthwise_conv1d",
    "BATCH_BUCKETS", "ConvPlan", "ConvSpec", "Route", "conv_spec",
    "plan_conv", "plan_cache_info", "plan_cache_clear", "reference",
    "AutotunePolicy", "RouteCache", "measure_fn",
]
