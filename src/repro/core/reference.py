"""DarkNet-equivalent naive baselines + analytic memory-traffic model.

The paper benchmarks against DarkNet's implementation: materialize the
zero-inserted input (transposed conv) or the zero-inserted kernel (dilated
conv), then run a standard convolution through an explicit ``im2col`` buffer
and one big GEMM.  We reproduce that pipeline faithfully in JAX so the Fig. 7
speedups and Fig. 8 byte reductions are measured against the same algorithm
the paper measured against.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

Pair = tuple[int, int]


def zero_insert(x: jax.Array, strides: Pair) -> jax.Array:
    """Materialize the s-dilated input x_hat (the thing HUGE2 never builds)."""
    sh, sw = strides
    if sh == 1 and sw == 1:
        return x
    *b, h, w, c = x.shape
    out = jnp.zeros((*b, (h - 1) * sh + 1, (w - 1) * sw + 1, c), x.dtype)
    return out.at[..., ::sh, ::sw, :].set(x)


def dilate_kernel(kernel: jax.Array, dilation: Pair) -> jax.Array:
    """Materialize the zero-inserted (atrous) kernel."""
    dh, dw = dilation
    if dh == 1 and dw == 1:
        return kernel
    r, s, c, n = kernel.shape
    out = jnp.zeros(((r - 1) * dh + 1, (s - 1) * dw + 1, c, n), kernel.dtype)
    return out.at[::dh, ::dw].set(kernel)


def im2col(x: jax.Array, rs: Pair, strides: Pair = (1, 1)) -> jax.Array:
    """Explicit im2col: (B,H,W,C) -> (B, OH, OW, R*S*C) patch buffer."""
    r, s = rs
    sh, sw = strides
    *b, h, w, c = x.shape
    oh = (h - r) // sh + 1
    ow = (w - s) // sw + 1
    cols = []
    for m in range(r):
        for n in range(s):
            cols.append(jax.lax.slice(
                x, [0] * len(b) + [m, n, 0],
                list(b) + [m + (oh - 1) * sh + 1, n + (ow - 1) * sw + 1, c],
                [1] * len(b) + [sh, sw, 1]))
    return jnp.concatenate(cols, axis=-1)  # (B, OH, OW, R*S*C)


def im2col_conv(x: jax.Array, kernel: jax.Array, *, strides: Pair = (1, 1),
                padding: Sequence[Pair] = ((0, 0), (0, 0))) -> jax.Array:
    """Standard conv through the explicit im2col buffer + one GEMM."""
    r, s, c, n = kernel.shape
    pad_cfg = [(0, 0)] * (x.ndim - 3) + [tuple(padding[0]), tuple(padding[1]), (0, 0)]
    xp = jnp.pad(x, pad_cfg)
    buf = im2col(xp, (r, s), strides)                        # materialized!
    w = kernel.reshape(r * s * c, n)
    y = jax.lax.dot_general(buf, w, (((buf.ndim - 1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def naive_conv_transpose2d(x: jax.Array, kernel: jax.Array, *, strides: Pair,
                           padding: Sequence[Pair]) -> jax.Array:
    """DarkNet path: zero-insert the input, then im2col GEMM at stride 1."""
    return im2col_conv(zero_insert(x, strides), kernel, strides=(1, 1),
                       padding=padding)


def naive_conv_transpose2d_pre(x, w_flat, kernel_hw, *, strides: Pair,
                               padding: Sequence[Pair]) -> jax.Array:
    """Same naive engine but with the weight pre-reshaped offline to
    (R*S*C, N) — the fair baseline against the engine's precomputed path."""
    xh = zero_insert(x, strides)
    pad_cfg = [(0, 0)] * (x.ndim - 3) + [tuple(padding[0]), tuple(padding[1]),
                                         (0, 0)]
    xp = jnp.pad(xh, pad_cfg)
    buf = im2col(xp, kernel_hw, (1, 1))
    y = jax.lax.dot_general(buf, w_flat,
                            (((buf.ndim - 1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def naive_dilated_conv2d(x: jax.Array, kernel: jax.Array, *, dilation: Pair,
                         strides: Pair = (1, 1),
                         padding: Sequence[Pair] = ((0, 0), (0, 0))) -> jax.Array:
    """DarkNet path: materialize the dilated kernel, then im2col GEMM."""
    return im2col_conv(x, dilate_kernel(kernel, dilation), strides=strides,
                       padding=padding)


def oracle_conv_transpose2d(x, kernel, *, strides, padding):
    """XLA's own lhs-dilated conv — correctness oracle for everything."""
    return jax.lax.conv_general_dilated(
        x, kernel, window_strides=(1, 1),
        padding=tuple(map(tuple, padding)), lhs_dilation=tuple(strides),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def oracle_dilated_conv2d(x, kernel, *, dilation, strides=(1, 1),
                          padding=((0, 0), (0, 0))):
    return jax.lax.conv_general_dilated(
        x, kernel, window_strides=tuple(strides),
        padding=tuple(map(tuple, padding)), rhs_dilation=tuple(dilation),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


# ---------------------------------------------------------------------------
# Analytic memory-traffic model (Fig. 8).  Counts bytes moved to/from main
# memory by each algorithm, assuming a cold cache and perfect reuse inside one
# GEMM tile (both algorithms get the same generous GEMM assumption; what
# differs is the *buffers each must stream*).
# ---------------------------------------------------------------------------

def bytes_naive_transpose(b, h, w, c, r, s, n, stride, itemsize=4):
    sh = sw = stride
    hd, wd = (h - 1) * sh + 1, (w - 1) * sw + 1         # zero-inserted size
    oh, ow = hd + (r - 1), wd + (s - 1)                  # 'full'-ish pad; scale-free
    read_x = b * h * w * c
    write_xhat = b * hd * wd * c                         # materialize x_hat
    read_xhat_patches = b * oh * ow * r * s * c          # im2col reads
    write_im2col = b * oh * ow * r * s * c               # im2col buffer
    read_im2col = b * oh * ow * r * s * c                # GEMM streams buffer
    read_k = r * s * c * n
    write_y = b * oh * ow * n
    return itemsize * (read_x + write_xhat + read_xhat_patches + write_im2col +
                       read_im2col + read_k + write_y)


def bytes_huge_transpose(b, h, w, c, r, s, n, stride, itemsize=4):
    sh = sw = stride
    oh, ow = (h - 1) * sh + r, (w - 1) * sw + s
    taps = r * s                                          # total taps across phases
    read_x_taps = b * taps * h * w * c / (sh * sw) * (sh * sw)  # each phase reads
    # each of the s^2 phases slides its ~(r/s * s/s) sub-kernel: total tap-reads
    # equal r*s tap-GEMMs over (h*w) rows -> b*h*w*c per tap, but only taps/(s^2)
    # taps per phase touch each pixel once:
    read_x_taps = b * h * w * c * taps / (sh * sw)
    read_k = r * s * c * n
    write_y = b * oh * ow * n
    return itemsize * (read_x_taps + read_k + write_y + b * h * w * c)


def memory_reduction_transpose(b, h, w, c, r, s, n, stride, itemsize=4):
    base = bytes_naive_transpose(b, h, w, c, r, s, n, stride, itemsize)
    huge = bytes_huge_transpose(b, h, w, c, r, s, n, stride, itemsize)
    return dict(naive_bytes=base, huge_bytes=huge, reduction=1.0 - huge / base)


def bytes_naive_dilated(b, h, w, c, r, s, n, out_hw, dilation, itemsize=4):
    """Traffic of the DarkNet dilated path: materialize the zero-inserted
    kernel, then im2col at the *dilated* kernel extent — every inserted zero
    is written once, then streamed through the patch buffer.  ``out_hw`` and
    ``dilation`` come from the actual plan geometry, so strided and
    asymmetrically padded sites are modeled exactly (stride and padding are
    already folded into ``out_hw``)."""
    (dh, dw) = dilation
    rd, sd = (r - 1) * dh + 1, (s - 1) * dw + 1
    oh, ow = out_hw
    read_k = r * s * c * n
    write_kd = rd * sd * c * n                       # zero-inserted kernel
    read_x = b * h * w * c
    read_patches = b * oh * ow * rd * sd * c         # im2col reads
    write_im2col = b * oh * ow * rd * sd * c
    read_im2col = b * oh * ow * rd * sd * c          # GEMM streams buffer
    read_kd = rd * sd * c * n
    write_y = b * oh * ow * n
    return itemsize * (read_k + write_kd + read_x + read_patches +
                       write_im2col + read_im2col + read_kd + write_y)


def bytes_planned_single(plan, b=1, itemsize=4):
    """Traffic model of one planned single-correlation site ('conv' /
    'dilated') vs the naive dilated engine, derived from the actual
    ``ConvPlan`` geometry:

    - ``naive``: zero-inserted kernel + im2col buffer at the dilated extent.
    - ``untangled``: ONE padded plane written and resident once, R·S
      strided/dilated tap reads of it, the (R·S·C, N) superpack streamed
      once, the output written once.  No zero is ever written or read.
    """
    spec = plan.spec
    h, w = spec.in_hw
    c, n = spec.in_c, spec.out_c
    r, s = spec.kernel_hw
    dil = spec.dilation if spec.kind == "dilated" else (1, 1)
    (ph, pw) = spec.padding
    oh, ow = plan.out_hw
    naive = bytes_naive_dilated(b, h, w, c, r, s, n, plan.out_hw, dil,
                                itemsize)
    hp, wp = h + ph[0] + ph[1], w + pw[0] + pw[1]
    untangled = b * h * w * c                        # read x
    untangled += b * hp * wp * c                     # single padded plane
    untangled += b * r * s * oh * ow * c             # tap reads of the plane
    untangled += r * s * c * n                       # superpack streams once
    untangled += b * oh * ow * n                     # output write
    untangled *= itemsize
    return dict(naive_bytes=naive, untangled_bytes=untangled,
                reduction=1.0 - untangled / naive)


def bytes_planned_transpose(plan, b=1, itemsize=4):
    """Traffic model derived from an actual ``ConvPlan`` (not the closed
    form): what each planned executor must stream per call.

    - ``per_phase``: every phase writes its own padded copy of the plane,
      its taps re-read that copy, and the stack/transpose interleave
      re-reads + re-writes the full output (the PR-1 executor).
    - ``fused``: ONE padded plane written and resident once, every phase's
      taps read it in place, the superpack streams once, and the output is
      written once, already interleaved (the single-launch executor).
    """
    spec = plan.spec
    h, w = spec.in_hw
    c, n = spec.in_c, spec.out_c
    oh, ow = plan.out_hw
    read_x = b * h * w * c

    per_phase = read_x
    for ex in plan.phases:
        th, tw = ex.taps
        u, v = ex.out_hw
        if th * tw == 0 or u * v == 0:
            continue
        hp = h + max(0, ex.pad[0][0]) + max(0, ex.pad[0][1])
        wp = w + max(0, ex.pad[1][0]) + max(0, ex.pad[1][1])
        per_phase += b * hp * wp * c                 # phase's padded copy
        per_phase += b * th * tw * u * v * c         # tap-view reads
        per_phase += th * tw * c * n                 # phase weights
        per_phase += b * u * v * n                   # phase output write
    per_phase += 2 * b * oh * ow * n                 # interleave read+write

    (glh, ghh), (glw, ghw) = plan.gpad
    hg, wg = h + glh + ghh, w + glw + ghw
    fused = read_x
    fused += b * hg * wg * c                         # single padded plane
    fused += b * hg * wg * c                         # one residency, read once
    fused += plan.total_taps * c * n                 # superpack streams once
    fused += b * oh * ow * n                         # interleaved output write
    return dict(per_phase_bytes=itemsize * per_phase,
                fused_bytes=itemsize * fused)
