"""Untangling (paper §3.2): a convolution as a tap-accumulated sum of 1x1 convs.

A stride-1 (or strided / dilated) correlation of x:(B,H,W,C) with K:(R,S,C,N)
is rewritten as

    y = sum_{m,n}  x[:, m*dh :: sh, n*dw :: sw, :]  @  K[m, n]      (C x N GEMM)

Each tap is a tall-skinny matmul over the *raw* input — no im2col buffer
(R*S x input duplication) and no zero-materialization for dilated kernels.
On TPU each tap maps to an MXU matmul with C/N on the contracting/lane dims;
fp32 accumulation across taps.

This module is the pure-JAX (XLA) realization; ``repro.kernels`` holds the
Pallas VMEM-tiled version of the same loop for the hot path.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

Pair = tuple[int, int]


def pad_or_crop(x: jax.Array, pads: Sequence[Pair]) -> jax.Array:
    """jnp.pad that also accepts negative amounts (crop). pads cover H,W dims."""
    (ph, pw) = pads
    # crops first
    h_lo = max(0, -ph[0]); h_hi = max(0, -ph[1])
    w_lo = max(0, -pw[0]); w_hi = max(0, -pw[1])
    if h_lo or h_hi or w_lo or w_hi:
        x = x[..., h_lo:x.shape[-3] - h_hi, w_lo:x.shape[-2] - w_hi, :]
    pad_cfg = [(0, 0)] * (x.ndim - 3) + [(max(0, ph[0]), max(0, ph[1])),
                                         (max(0, pw[0]), max(0, pw[1])), (0, 0)]
    if any(p != (0, 0) for p in pad_cfg):
        x = jnp.pad(x, pad_cfg)
    return x


def conv_out_size(in_size: int, k: int, stride: int, dilation: int,
                  pad: Pair) -> int:
    eff_k = (k - 1) * dilation + 1
    return (in_size + pad[0] + pad[1] - eff_k) // stride + 1


def untangled_conv2d(x: jax.Array, kernel: jax.Array, *,
                     strides: Pair = (1, 1),
                     padding: Sequence[Pair] = ((0, 0), (0, 0)),
                     rhs_dilation: Pair = (1, 1),
                     accum_dtype=jnp.float32,
                     out_dtype=None,
                     fuse_taps: bool | None = None) -> jax.Array:
    """Standard / strided / dilated correlation via per-tap GEMMs.

    x: (..., H, W, C) NHWC;  kernel: (R, S, C, N) HWIO.
    ``rhs_dilation`` > 1 gives the dilated (atrous) convolution *without ever
    materializing the zero-inserted kernel* (paper §3.2.2).

    ``fuse_taps`` (beyond-paper, §Perf P0): concatenate the tap-shifted views
    along the contraction dim and issue ONE wide GEMM instead of R*S small
    ones.  Still zero-free (the buffer is built from the *raw* input), still
    the s^2 FLOP reduction — but with the naive engine's GEMM efficiency.
    Wins when the per-phase spatial extent is tiny (compute-bound shallow
    layers, paper Fig. 7 DC1); the default heuristic fuses when the GEMM
    rows (oh*ow) are too few to amortize per-tap dispatch.
    """
    r, s, c, n = kernel.shape
    if x.shape[-1] != c:
        raise ValueError(f"channel mismatch {x.shape[-1]} vs {c}")
    (sh, sw) = strides
    (dh, dw) = rhs_dilation
    x = pad_or_crop(x, padding)
    hp, wp = x.shape[-3], x.shape[-2]
    oh = (hp - (r - 1) * dh - 1) // sh + 1
    ow = (wp - (s - 1) * dw - 1) // sw + 1
    if oh <= 0 or ow <= 0:
        raise ValueError(f"non-positive output {oh}x{ow}")
    out_dtype = out_dtype or x.dtype
    if fuse_taps is None:
        fuse_taps = (oh * ow <= 128) and (r * s > 2)

    def tap_view(m, nn):
        return jax.lax.slice(
            x,
            [0] * (x.ndim - 3) + [m * dh, nn * dw, 0],
            list(x.shape[:-3]) + [m * dh + (oh - 1) * sh + 1,
                                  nn * dw + (ow - 1) * sw + 1, c],
            [1] * (x.ndim - 3) + [sh, sw, 1])

    if fuse_taps:
        buf = jnp.concatenate([tap_view(m, nn) for m in range(r)
                               for nn in range(s)], axis=-1)
        w = kernel.reshape(r * s * c, n)
        acc = jax.lax.dot_general(
            buf, w, (((buf.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=accum_dtype)
        return acc.astype(out_dtype)

    acc = None
    for m in range(r):
        for nn in range(s):
            xs = tap_view(m, nn)
            # (..., oh, ow, C) @ (C, N) on the MXU, fp32 accumulation.
            t = jax.lax.dot_general(
                xs, kernel[m, nn],
                dimension_numbers=(((xs.ndim - 1,), (0,)), ((), ())),
                preferred_element_type=accum_dtype)
            acc = t if acc is None else acc + t
    return acc.astype(out_dtype)


def untangled_depthwise_conv1d(x: jax.Array, kernel: jax.Array, *,
                               causal: bool = True,
                               accum_dtype=jnp.float32) -> jax.Array:
    """Depthwise temporal conv via the C=1 "outer product" untangling
    (paper §3.2.3): a sum of shifted, per-channel-scaled copies.

    x: (..., T, C); kernel: (K, C).  Used by mamba2 / recurrentgemma mixers.
    """
    k, c = kernel.shape
    t = x.shape[-2]
    pads = [(0, 0)] * (x.ndim - 2) + [((k - 1, 0) if causal else
                                       ((k - 1) // 2, k // 2)), (0, 0)]
    xp = jnp.pad(x, pads)
    acc = None
    for i in range(k):
        term = xp[..., i:i + t, :].astype(accum_dtype) * kernel[i].astype(accum_dtype)
        acc = term if acc is None else acc + term
    return acc.astype(x.dtype)
