"""Measured route autotuning: microbenchmark-backed plan decisions with a
persistent per-host route cache.

The heuristic route builders (``plan._single_route`` /
``plan._transposed_route``) decide execution paths from plane-bytes caps
and VMEM estimates — pure arithmetic over the spec constants.  That
arithmetic is host-blind, and the perf record shows it losing (BENCH_fig7:
DC2 routes ``fused_plane`` while the per-phase executor is ~1.4x faster on
the dev host).  Kernel-Segregated Transpose Convolution (2502.20493) and
EcoFlow (2202.02310) make the general argument: the best kernel layout for
a transposed/dilated conv is geometry- *and* machine-dependent, so the
plan step should **measure, not guess**.

This module is that measurement step:

- ``measure_fn``       — the one noise-robust timing loop (block-until-
  ready inside the timed region, min + median reported).  It is the shared
  implementation: ``benchmarks/util.time_fn`` delegates here, so plan-time
  microbenchmarks and bench-time wall-clocks are the same code.
- ``candidate_routes`` — the 2–4 feasible candidates the heuristic already
  enumerates for a (site, bucket): Pallas whole-plane and spatially tiled
  variants (``pick_tiled_single`` / ``pick_tiled_transposed``),
  ``fused_tap``, ``fused_plane``, ``taps``, and — transposed only — the
  ``per_phase`` executor as a first-class route.
- ``measure_bucket``   — time every measurable candidate on the live
  device and pick the winner; the heuristic route only loses when a
  challenger beats it by ``AutotunePolicy.min_gain`` (guards against
  noise-driven flips).
- ``RouteCache``       — persistent per-host winners, keyed by the spec
  constants + a device fingerprint, in the same JSON route schema as the
  golden fixture ``tests/fixtures/route_table.json`` /
  ``tools/gen_route_table.py``.  A fleet of identical hosts ships one
  cache and pays the search once at model load.  Corrupt, truncated,
  stale-schema, or wrong-fingerprint files fall back to heuristic routes
  with a warning — never a crash.  The file also carries the serving
  layer's warmup-measured per-bucket launch costs
  (``DynamicImageBatcher``), so a restarted server skips re-measuring.
- ``autotune_plan``    — the entry ``plan.plan_conv(spec, autotune=...)``
  dispatches to: per bucket, cache hit → cached ``Route`` (zero
  microbenchmark runs), miss under ``mode='measure'`` → measure + persist,
  miss under ``mode='cache'`` → heuristic route unchanged.

The fallback ladder, end to end::

    cache hit  →  measured winner (no timing runs)
    cache miss + mode='measure'  →  microbenchmark candidates, persist
    cache miss + mode='cache'    →  heuristic route
    unmeasurable heuristic route (Pallas interpret on CPU)  →  heuristic
    unreadable/stale/foreign cache  →  warn once, heuristic

Pallas candidates are only ever *timed* on a real TPU backend: on CPU
hosts Pallas runs in interpret mode, whose wall-clock says nothing about
the kernel (same rule as the benches' ``pallas_tiled`` column).
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time
import warnings
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as planmod
from repro.core.plan import (BATCH_BUCKETS, ConvPlan, ConvSpec, Route,
                             pick_fused_tiles, pick_tiled_single,
                             pick_tiled_transposed, pick_vmem_tiles)

SCHEMA = "huge2-route-cache/v1"
CACHE_ENV = "HUGE2_ROUTE_CACHE"
DEFAULT_CACHE = "~/.cache/huge2/route_cache.json"

# monotonic count of microbenchmark runs this process has performed —
# tests assert warm-cache model loads leave it unchanged
_MEASURE_CALLS = 0

# in-process singletons: one loaded cache per path, one tuned plan per
# (spec, policy) — cleared by ``reset()`` / ``plan.plan_cache_clear()``
_OPEN_CACHES: dict[str, "RouteCache"] = {}
_TUNED: dict[tuple[ConvSpec, "AutotunePolicy"], ConvPlan] = {}


def measure_calls() -> int:
    """Total microbenchmark runs so far (monotonic; compare before/after)."""
    return _MEASURE_CALLS


def reset():
    """Drop in-process autotune state (tuned plans + loaded caches) so the
    next build re-reads the cache file.  The measurement counter stays
    monotonic."""
    _OPEN_CACHES.clear()
    _TUNED.clear()


# ---------------------------------------------------------------------------
# timing: the one noise-robust implementation (benches delegate here)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Timing:
    """One microbenchmark result.  ``min_s`` is the headline (every source
    of interference only ever adds time, so the minimum is the closest
    observable to the uncontended cost); ``median_s`` is reported alongside
    as the robustness check — a median far above the min flags a noisy
    measurement window."""

    min_s: float
    median_s: float
    iters: int

    @property
    def min_us(self) -> float:
        return self.min_s * 1e6


def measure_fn(fn: Callable, *args, iters: int = 10, warmup: int = 3
               ) -> Timing:
    """Time a jitted callable: ``warmup`` untimed runs (absorbing compile),
    then ``iters`` timed runs with ``block_until_ready`` **inside** the
    timed region (async dispatch must not leak work past the clock)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return Timing(float(np.min(ts)), float(np.median(ts)), iters)


# ---------------------------------------------------------------------------
# cache schema: spec keys, route (de)serialization, device fingerprint
# ---------------------------------------------------------------------------

def device_fingerprint() -> dict:
    """What has to match for measured winners to transfer between hosts:
    accelerator platform + device kind + count, and the jax version (a
    runtime upgrade can reshuffle route rankings)."""
    dev = jax.devices()[0]
    return {
        "platform": str(jax.default_backend()),
        "device_kind": str(getattr(dev, "device_kind", "unknown")),
        "device_count": int(jax.device_count()),
        "jax": str(jax.__version__),
    }


def spec_key(spec: ConvSpec) -> str:
    """Deterministic cache key over every plan-relevant spec constant.
    The spatial / wdtype suffixes only appear for device-tiled / quantized
    specs, so every pre-existing cache entry keeps its key."""
    (ph, pw) = spec.padding
    key = (f"{spec.kind}:{spec.in_hw[0]}x{spec.in_hw[1]}"
           f":c{spec.in_c}->{spec.out_c}"
           f":k{spec.kernel_hw[0]}x{spec.kernel_hw[1]}"
           f":s{spec.strides[0]}x{spec.strides[1]}"
           f":p{ph[0]},{ph[1]},{pw[0]},{pw[1]}"
           f":d{spec.dilation[0]}x{spec.dilation[1]}"
           f":{spec.dtype}:{spec.backend}")
    if spec.spatial != (1, 1):
        key += f":sp{spec.spatial[0]}x{spec.spatial[1]}"
    if spec.wdtype != "float32":
        key += f":w{spec.wdtype}"
    return key


def spec_to_json(spec: ConvSpec) -> dict:
    """The fixture's spec record (``tools/gen_route_table.py`` shares it)."""
    return {
        "kind": spec.kind, "in_hw": list(spec.in_hw),
        "in_c": spec.in_c, "out_c": spec.out_c,
        "kernel_hw": list(spec.kernel_hw),
        "strides": list(spec.strides),
        "padding": [list(p) for p in spec.padding],
        "dilation": list(spec.dilation),
        "spatial": list(spec.spatial),
        "wdtype": spec.wdtype,
    }


def route_to_json(route: Route) -> dict:
    """The fixture's route record — one schema for the golden fixture and
    the per-host cache."""
    return {
        "batch": route.batch,
        "path": route.path,
        "tiles": list(route.tiles) if route.tiles else None,
        "sp_tiles": list(route.sp_tiles) if route.sp_tiles else None,
        "dev_tiles": list(route.dev_tiles) if route.dev_tiles else None,
        "fused_bwd": route.fused_bwd,
    }


def route_from_json(d: dict) -> Route:
    return Route(
        batch=int(d["batch"]), path=str(d["path"]),
        tiles=tuple(d["tiles"]) if d.get("tiles") else None,
        fused_bwd=bool(d.get("fused_bwd", True)),
        sp_tiles=tuple(d["sp_tiles"]) if d.get("sp_tiles") else None,
        dev_tiles=tuple(d["dev_tiles"]) if d.get("dev_tiles") else None)


def cache_path(path: Optional[str] = None) -> Optional[str]:
    """Resolve the cache location: explicit arg > ``$HUGE2_ROUTE_CACHE`` >
    the per-user default.  ``''`` means memory-only (no file)."""
    if path == "":
        return None
    if path is None:
        path = os.environ.get(CACHE_ENV) or DEFAULT_CACHE
    return str(pathlib.Path(path).expanduser())


class RouteCache:
    """Persistent per-host route winners + serving bucket costs.

    One JSON file, schema-versioned and fingerprint-guarded.  Every load
    failure mode (missing file, corrupt/truncated JSON, stale schema,
    foreign fingerprint, malformed entries) degrades to an *empty* cache
    with a ``RuntimeWarning`` — the caller falls back to heuristic routes
    and a later ``save`` rewrites the file cleanly."""

    def __init__(self, path: Optional[str] = None):
        self.path = cache_path(path)
        self.fingerprint = device_fingerprint()
        # spec_key -> {"spec": {...}, "routes": {batch(str): route-json}}
        self.entries: dict[str, dict] = {}
        # serving-side warmup costs: cache_key -> {bucket(str): seconds}
        self.bucket_costs: dict[str, dict] = {}
        self.loaded_from_disk = False
        if self.path is not None:
            self._load()

    # -- persistence ---------------------------------------------------------
    def _warn(self, why: str):
        warnings.warn(
            f"route cache {self.path}: {why} — falling back to heuristic "
            f"routes (the cache will be rewritten on the next save)",
            RuntimeWarning, stacklevel=3)

    def _load(self):
        p = pathlib.Path(self.path)
        if not p.exists():
            return
        try:
            raw = json.loads(p.read_text())
        except (OSError, ValueError) as e:
            self._warn(f"unreadable ({e.__class__.__name__}: {e})")
            return
        if not isinstance(raw, dict) or raw.get("schema") != SCHEMA:
            self._warn(f"stale or unknown schema {raw.get('schema')!r} "
                       f"(want {SCHEMA!r})")
            return
        if raw.get("fingerprint") != self.fingerprint:
            self._warn(f"device fingerprint mismatch "
                       f"(file {raw.get('fingerprint')!r}, "
                       f"host {self.fingerprint!r})")
            return
        try:
            entries = dict(raw.get("entries", {}))
            # validate eagerly: every route record must deserialize
            for key, ent in entries.items():
                for b, rj in ent["routes"].items():
                    int(b), route_from_json(rj)
            self.entries = entries
            self.bucket_costs = {
                k: {str(b): float(c) for b, c in v.items()}
                for k, v in dict(raw.get("bucket_costs", {})).items()}
        except (KeyError, TypeError, ValueError, AttributeError) as e:
            self._warn(f"malformed entries ({e.__class__.__name__}: {e})")
            self.entries, self.bucket_costs = {}, {}
            return
        self.loaded_from_disk = True

    def save(self):
        """Atomic write (tmp + rename) of the full cache state."""
        if self.path is None:
            return
        p = pathlib.Path(self.path)
        p.parent.mkdir(parents=True, exist_ok=True)
        payload = {
            "schema": SCHEMA,
            "fingerprint": self.fingerprint,
            "generated_by": "repro.core.autotune",
            "entries": self.entries,
            "bucket_costs": self.bucket_costs,
        }
        tmp = p.with_suffix(p.suffix + ".tmp")
        tmp.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
        tmp.replace(p)

    # -- routes --------------------------------------------------------------
    def get(self, spec: ConvSpec, batch: int) -> Optional[Route]:
        ent = self.entries.get(spec_key(spec))
        if ent is None:
            return None
        rj = ent["routes"].get(str(batch))
        return None if rj is None else route_from_json(rj)

    def put(self, spec: ConvSpec, route: Route,
            timings: Optional[dict] = None):
        ent = self.entries.setdefault(
            spec_key(spec), {"spec": spec_to_json(spec),
                             "backend": spec.backend, "routes": {}})
        rj = route_to_json(route)
        if timings:
            rj["measured_us"] = {k: round(v * 1e6, 3)
                                 for k, v in timings.items()}
        ent["routes"][str(route.batch)] = rj

    # -- serving bucket costs ------------------------------------------------
    def get_bucket_costs(self, key: str) -> dict[int, float]:
        return {int(b): float(c)
                for b, c in self.bucket_costs.get(key, {}).items()}

    def put_bucket_costs(self, key: str, costs: dict[int, float]):
        self.bucket_costs[key] = {str(b): float(c) for b, c in costs.items()}


def open_cache(path: Optional[str] = None) -> RouteCache:
    """Load-or-create the cache at ``path`` (process-wide singleton per
    resolved path, so concurrent plan builds share one view and saves
    merge instead of clobbering)."""
    resolved = cache_path(path)
    if resolved is None:
        return RouteCache("")
    if resolved not in _OPEN_CACHES:
        _OPEN_CACHES[resolved] = RouteCache(resolved)
    return _OPEN_CACHES[resolved]


# ---------------------------------------------------------------------------
# policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AutotunePolicy:
    """How ``plan_conv(spec, autotune=...)`` resolves routes.

    ``mode``: ``'measure'`` microbenchmarks cache misses on the live device
    and persists winners; ``'cache'`` only consumes cached winners (a fleet
    host that ships the cache — never runs a timing loop); ``'off'`` is the
    heuristic (same as passing ``autotune=None``).

    ``cache_path``: ``None`` → ``$HUGE2_ROUTE_CACHE`` or the per-user
    default; ``''`` → memory-only (measure, never touch disk — what the
    benches use).  ``buckets`` limits tuning to a subset of the plan's
    batch buckets (``None`` = all); untuned buckets keep heuristic routes.

    ``min_gain``: a measured challenger must beat the heuristic route's
    min time by this factor to flip it — the hysteresis that keeps noise
    from rewriting routes that are actually ties."""

    mode: str = "measure"             # 'off' | 'cache' | 'measure'
    cache_path: Optional[str] = None  # None=env/default, ''=memory-only
    buckets: Optional[tuple[int, ...]] = None
    iters: int = 5
    warmup: int = 2
    min_gain: float = 1.03

    def __post_init__(self):
        if self.mode not in ("off", "cache", "measure"):
            raise ValueError(f"bad autotune mode {self.mode!r}")


# ---------------------------------------------------------------------------
# candidate enumeration: the feasible set the heuristic already knows
# ---------------------------------------------------------------------------

def _dedupe(routes: Sequence[Route]) -> tuple[Route, ...]:
    seen, out = set(), []
    for r in routes:
        k = (r.path, r.tiles, r.sp_tiles, r.dev_tiles)
        if k not in seen:
            seen.add(k)
            out.append(r)
    return tuple(out)


def _with_dev_candidates(plan: ConvPlan, batch: int,
                         cands: Sequence[Route]) -> tuple[Route, ...]:
    """Device-tiled candidates for a spatial spec: each single-device
    candidate paired with its plane-parallel twin (same per-shard path,
    ``dev_tiles`` attached), so ``measure_bucket`` ranks sharded vs
    single-device execution on the live mesh like any other route flip."""
    if plan.spec.spatial == (1, 1):
        return _dedupe(cands)
    from repro.core import spatial as spatialmod
    if spatialmod.spatial_plan(plan.spec) is None:
        return _dedupe(cands)
    both = []
    for r in cands:
        both.append(dataclasses.replace(r, dev_tiles=None))
        both.append(dataclasses.replace(r, dev_tiles=plan.spec.spatial))
    return _dedupe(both)


def candidate_routes(plan: ConvPlan, batch: int) -> tuple[Route, ...]:
    """Every feasible whole-conv route for this (site, bucket) — the same
    set the heuristic chooses *one* of, enumerated for measurement.  All
    candidates share the bucket's ``fused_bwd`` verdict (a memory cap on
    the backward, not a tunable)."""
    spec = plan.spec
    itemsize = jnp.dtype(spec.dtype).itemsize
    witemsize = planmod._weight_itemsize(spec)
    c, n = spec.in_c, spec.out_c
    oh, ow = plan.out_hw
    want_pallas = spec.backend == "pallas" or (
        spec.backend == "auto" and jax.default_backend() == "tpu")
    cands: list[Route] = []

    if spec.kind == "transposed":
        if plan.total_taps == 0:
            return (Route(batch, "taps", None),)
        (glh, ghh), (glw, ghw) = plan.gpad
        hg = spec.in_hw[0] + glh + ghh
        wg = spec.in_hw[1] + glw + ghw
        if want_pallas:
            tiles = pick_fused_tiles(hg, wg, c, n, plan.total_taps,
                                     plan.sum_uv, oh, ow, itemsize,
                                     witemsize=witemsize)
            if tiles is not None:
                cands.append(Route(batch, "pallas", tiles))
            if plan.uniform and oh % spec.strides[0] == 0 \
                    and ow % spec.strides[1] == 0:
                tiled = pick_tiled_transposed(c, n, plan.total_taps,
                                              plan.phases, itemsize,
                                              witemsize=witemsize)
                if tiled is not None:
                    c_t, n_t, sp = tiled
                    cands.append(Route(batch, "pallas", (c_t, n_t),
                                       sp_tiles=sp))
        ps = planmod._pixel_shuffle_route(spec, plan.phases, batch)
        if ps is not None:
            cands.append(ps)
        plane_bytes = 4 * batch * hg * wg * plan.total_taps * n
        if plane_bytes <= planmod._PLANE_BYTES_MAX:
            cands.append(Route(batch, "fused_plane", None))
        if plan.uniform:
            cands.append(Route(batch, "fused_tap", None))
        cands.append(Route(batch, "taps", None))
        cands.append(Route(batch, "per_phase", None))
        return _with_dev_candidates(plan, batch, cands)

    # 'conv' / 'dilated': the single-correlation feasible set
    (ph, pw) = spec.padding
    hp = spec.in_hw[0] + ph[0] + ph[1]
    wp = spec.in_hw[1] + pw[0] + pw[1]
    r, s = spec.kernel_hw
    fused_ok = (4 * batch * oh * ow * r * s * c
                <= planmod._PLANE_BYTES_MAX)
    if want_pallas:
        tiles = pick_vmem_tiles(hp, wp, c, n, r, s, oh, ow, itemsize,
                                witemsize=witemsize)
        if tiles is not None:
            cands.append(Route(batch, "pallas", tiles, fused_bwd=fused_ok))
        dil = spec.dilation if spec.kind == "dilated" else (1, 1)
        tiled = pick_tiled_single(c, n, r, s, oh, ow, spec.strides, dil,
                                  itemsize, witemsize=witemsize)
        if tiled is not None:
            c_t, n_t, sp = tiled
            cands.append(Route(batch, "pallas", (c_t, n_t),
                               fused_bwd=fused_ok, sp_tiles=sp))
    if fused_ok:
        cands.append(Route(batch, "fused_tap", None, fused_bwd=True))
    cands.append(Route(batch, "taps", None, fused_bwd=fused_ok))
    return _with_dev_candidates(plan, batch, cands)


def _measurable(route: Route) -> bool:
    """Pallas wall-clock is only meaningful on a real TPU backend; interpret
    mode (CPU hosts) would time the Python interpreter, not the kernel.
    Device-tiled routes need the matching spatial mesh bound — without it
    the forced plan would silently measure the single-device fallback."""
    if route.path == "pallas":
        if jax.default_backend() != "tpu":
            return False
    if route.dev_tiles is not None:
        from repro.core import spatial as spatialmod
        active = spatialmod.active_spatial_mesh()
        if active is None:
            return False
        mesh, axes = active
        if not spatialmod.mesh_matches(mesh, axes, route.dev_tiles):
            return False
    return True


def route_label(route: Route) -> str:
    lab = route.path
    if route.tiles:
        lab += f"@{route.tiles[0]}x{route.tiles[1]}"
    if route.sp_tiles:
        lab += f"@sp{route.sp_tiles[0]}x{route.sp_tiles[1]}"
    if route.dev_tiles:
        lab += f"@dev{route.dev_tiles[0]}x{route.dev_tiles[1]}"
    return lab


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------

def _bench_inputs(plan: ConvPlan, batch: int):
    """Seeded synthetic (x, packed) at the bucket's batch — same
    distribution every host, so identical hardware measures identical
    work."""
    spec = plan.spec
    dtype = jnp.dtype(spec.dtype)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(
        k1, (batch, spec.in_hw[0], spec.in_hw[1], spec.in_c), dtype)
    kernel = jax.random.normal(
        k2, (*spec.kernel_hw, spec.in_c, spec.out_c), dtype)
    packed = plan.pack(kernel)
    return jax.block_until_ready(x), jax.block_until_ready(packed)


def measure_route(plan: ConvPlan, route: Route, x, packed, *,
                  iters: int = 5, warmup: int = 2) -> Timing:
    """Microbenchmark ONE candidate route: jit the plan's apply with the
    route forced for every bucket, time it with the shared loop.  This is
    the single choke point every timing run goes through — the monotonic
    counter behind ``measure_calls()`` lives here (and is what the
    warm-cache "zero microbenchmark runs" test asserts on)."""
    global _MEASURE_CALLS
    _MEASURE_CALLS += 1
    forced = plan.with_routes((route,))
    return measure_fn(jax.jit(forced.apply), x, packed,
                      iters=iters, warmup=warmup)


def measure_bucket(plan: ConvPlan, batch: int,
                   policy: Optional[AutotunePolicy] = None
                   ) -> tuple[Route, dict[str, float]]:
    """Measure every feasible candidate for (plan, bucket) and return
    ``(winner, {label: min_seconds})``.

    The heuristic route is always in the candidate set and wins ties: a
    challenger must beat it by ``policy.min_gain``.  If the heuristic
    route itself cannot be measured honestly (Pallas interpret mode on a
    CPU host) the bucket is not tuned at all."""
    policy = policy or AutotunePolicy()
    heuristic = plan.route_for_batch(batch)
    if not _measurable(heuristic):
        return heuristic, {}
    cands = [r for r in _dedupe((heuristic,) + candidate_routes(plan, batch))
             if _measurable(r)]
    if len(cands) < 2:
        return heuristic, {}
    x, packed = _bench_inputs(plan, batch)
    timings: dict[str, float] = {}
    for cand in cands:
        t = measure_route(plan, cand, x, packed,
                          iters=policy.iters, warmup=policy.warmup)
        timings[route_label(cand)] = t.min_s
    h_t = timings[route_label(heuristic)]
    best_route, best_t = heuristic, None
    for cand in cands:
        t = timings[route_label(cand)]
        if cand == heuristic:
            continue
        if t * policy.min_gain < h_t and (best_t is None or t < best_t):
            best_route, best_t = cand, t
    return best_route, timings


# ---------------------------------------------------------------------------
# the plan-level entry: what plan_conv(spec, autotune=...) dispatches to
# ---------------------------------------------------------------------------

def autotune_plan(plan: ConvPlan, policy: AutotunePolicy) -> ConvPlan:
    """Resolve measured routes for ``plan`` under ``policy`` and return the
    tuned plan (in-process singleton per (spec, policy) — repeated model
    loads reuse it).  Fallback ladder per bucket: cache hit → cached
    winner; miss + ``mode='measure'`` → microbenchmark + persist; miss +
    ``mode='cache'`` → heuristic route unchanged."""
    if policy.mode == "off":
        return plan
    key = (plan.spec, policy)
    if key in _TUNED:
        return _TUNED[key]
    cache = open_cache(policy.cache_path)
    tune_buckets = (set(policy.buckets) if policy.buckets is not None
                    else set(BATCH_BUCKETS))
    routes, dirty = [], False
    for hr in plan.routes:
        if hr.batch not in tune_buckets:
            routes.append(hr)
            continue
        cached = cache.get(plan.spec, hr.batch)
        if cached is not None:
            routes.append(cached)
            continue
        if policy.mode != "measure":
            routes.append(hr)
            continue
        best, timings = measure_bucket(plan, hr.batch, policy)
        routes.append(best)
        if timings:
            cache.put(plan.spec, best, timings)
            dirty = True
    if dirty:
        cache.save()
    tuned = plan.with_routes(tuple(routes))
    _TUNED[key] = tuned
    return tuned
