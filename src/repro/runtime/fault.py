"""Fault-tolerance runtime: step heartbeats, EWMA straggler detection,
failure injection for tests, and the restart policy driver.

At 1000+ nodes the dominant events are (a) hard node loss — handled by
checkpoint/restart onto a (possibly smaller) mesh, and (b) stragglers —
handled by detection + operator alerting / re-scheduling.  On a single-host
CPU run these are *simulated*: the monitor watches wall-clock per step and
the injector raises at a chosen step, which the training driver turns into
a restore-from-latest (``launch/train.py``) and the serving control plane
turns into re-queue + replay (``serving/control_plane.py``).  Unit
coverage for these primitives lives in tests/test_fault.py; the serving
replay integration test is tests/test_control_plane.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


class NodeFailure(RuntimeError):
    """Raised (or injected) when a worker is lost mid-step."""


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time tracker; flags steps slower than mean + k * stddev."""

    alpha: float = 0.2
    k: float = 3.0
    warmup: int = 5
    _mean: float = 0.0
    _var: float = 0.0
    _n: int = 0
    events: list = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        self._n += 1
        if self._n <= self.warmup:
            self._mean = dt if self._n == 1 else (
                self._mean + (dt - self._mean) / self._n)
            return False
        dev = dt - self._mean
        # floor the stddev at 5% of the mean: sub-noise jitter never flags
        std = max(self._var ** 0.5, 0.05 * abs(self._mean), 1e-9)
        flagged = dev > self.k * std
        self._mean += self.alpha * dev
        self._var = (1 - self.alpha) * (self._var + self.alpha * dev * dev)
        if flagged:
            self.events.append((step, dt, self._mean))
        return flagged


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure injection for integration tests."""

    fail_at_steps: tuple = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise NodeFailure(f"injected node failure at step {step}")


@dataclasses.dataclass
class Heartbeat:
    """Wall-clock watchdog: a step exceeding ``timeout`` marks the worker
    dead (at scale this triggers the coordinator's restart path)."""

    timeout: float = 600.0
    last: float = dataclasses.field(default_factory=time.monotonic)

    def beat(self):
        now = time.monotonic()
        dt = now - self.last
        self.last = now
        return dt

    def expired(self) -> bool:
        return (time.monotonic() - self.last) > self.timeout


def run_with_restarts(train_loop: Callable[[int], int], *,
                      max_restarts: int = 3,
                      on_restart: Optional[Callable[[int, Exception], None]] = None,
                      restore: Optional[Callable[[], int]] = None,
                      initial_step: int = 0) -> int:
    """Drive ``train_loop(start_step) -> final_step`` with restart-on-failure.

    The explicit restore contract: the first attempt enters at
    ``initial_step``.  After a ``NodeFailure`` (and ``on_restart``), the
    driver calls ``restore()`` and re-enters ``train_loop`` at the step it
    returns — e.g. ``lambda: ckpt.latest_step() or 0``; the callback may
    also restore state it closes over (``launch/train.py`` reloads the
    train state there).  Without a ``restore`` callback, restarts re-enter
    at ``initial_step`` — only correct for loops that rebuild all state
    from the start step (our data pipeline is keyed by step, so resume is
    exact either way).
    """
    restarts = 0
    start = initial_step
    while True:
        try:
            return train_loop(start)
        except NodeFailure as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart is not None:
                on_restart(restarts, e)
            start = restore() if restore is not None else initial_step
