"""Elastic scaling: restore a checkpoint onto a different mesh.

Checkpoints are mesh-independent (flat numpy), so elasticity reduces to
recomputing shardings for the surviving mesh and ``device_put``-ing each
leaf.  ``shrink_mesh`` models the coordinator's decision after node loss:
drop the data-parallel extent to the largest power-of-two that the remaining
chips support (model-parallel extent is preserved — TP groups must stay
intact, only whole DP replicas are dropped).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.sharding import DistContext
from repro.train.checkpoint import CheckpointManager

# jax.sharding.AxisType landed after the pinned jax; Auto is the default
# axis type either way, so pass it only where available
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def shrink_mesh(devices_left: int, model: int, pod: int = 0):
    """Largest (data, model) mesh from the surviving chips, TP preserved."""
    if devices_left < model:
        raise ValueError(f"cannot keep TP={model} with {devices_left} chips")
    data = 1
    while data * 2 * model * max(pod, 1) <= devices_left:
        data *= 2
    shape = (pod, data, model) if pod else (data, model)
    names = ("pod", "data", "model") if pod else ("data", "model")
    kw = ({"axis_types": (_AXIS_TYPE.Auto,) * len(shape)}
          if _AXIS_TYPE is not None else {})
    return jax.make_mesh(shape, names, **kw)


def restore_on_mesh(ckpt: CheckpointManager, template, logical_specs,
                    dist: DistContext, step: Optional[int] = None):
    """Restore ``template``-shaped state, placed per ``logical_specs`` on the
    (new) mesh carried by ``dist``."""
    shardings = jax.tree.map(
        lambda sp: dist.sharding(sp), logical_specs,
        is_leaf=lambda x: hasattr(x, "index") or type(x).__name__ == "PartitionSpec")
    return ckpt.restore(template, step=step, shardings=shardings)
