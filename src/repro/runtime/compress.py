"""Int8 quantization primitives: gradient compression and the checkpoint /
superpack weight-quantization home.

Two roles, one module:

1. **Error-feedback gradient compression** (``quantize_int8`` /
   ``crosspod_allreduce_compressed``): gradients exchanged across the
   data-parallel axis ride as int8 with a per-tensor scale, and the
   quantization error is fed back into the next step's gradient (Seide et
   al. 1-bit SGD lineage).  Exposed as a pure transform so the train step
   composes it with ``shard_map``.
2. **Checkpoint / superpack quantization** (``quantize_int8_rows`` /
   ``dequantize_int8``): the per-row symmetric scheme behind
   ``ConvSpec.wdtype='int8'`` — ``ConvPlan.pack`` quantizes each tap row of
   the superpacked weight buffer here (one f32 scale per ``(tap, c)`` row),
   and ``ConvPlan.unpack`` dequantizes through the same primitives so HWIO
   checkpoints round-trip within one quantization step.  One module owns
   the rounding/clipping/scale-floor rules for both paths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# scale floor: keeps the divide finite for all-zero / subnormal inputs.
# Applied AFTER the /127 so the floor is the smallest *normal* f32 — a
# subnormal floor would flush to zero under XLA's FTZ and turn the
# quantizing divide into 0/0
_SCALE_FLOOR = float(np.finfo(np.float32).tiny)

# scale ceiling: f32max/127 rounds UP in f32, so the extreme code's
# dequant 127·scale would overflow to inf; nudge down until the product
# is finite (error stays far under one grid step at that magnitude)
_SCALE_MAX = np.float32(np.finfo(np.float32).max) / np.float32(127.0)
with np.errstate(over="ignore"):        # the probe overflow is the point
    while not np.isfinite(np.float32(127.0) * _SCALE_MAX):
        _SCALE_MAX = np.nextafter(_SCALE_MAX, np.float32(0.0))
_SCALE_MAX = float(_SCALE_MAX)


def quantize_int8(g: jax.Array, err: jax.Array):
    """g, err: f32 -> (q int8, scale f32 scalar, new_err).

    Per-*tensor* symmetric scale with error feedback — the gradient-
    compression flavor.  ``new_err`` is the quantization residual to carry
    into the next step's gradient."""
    gc = g + err
    scale = jnp.clip(jnp.max(jnp.abs(gc)) / 127.0, _SCALE_FLOOR, _SCALE_MAX)
    q = jnp.clip(jnp.round(gc / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gc - deq


def quantize_int8_rows(w: jax.Array):
    """(rows, N) f32 -> (q int8 (rows, N), scale f32 (rows, 1)).

    Per-*row* symmetric scale ``scale[r] = max|w[r, :]| / 127`` (floored /
    capped so all-zero, subnormal, and ±f32max rows stay finite both ways
    through the grid) — the superpack/checkpoint
    flavor: one scale per tap row of the tap-major weight buffer, so the
    per-element quantization error is bounded by ``0.5 · scale[r]`` (half a
    step of the int8 grid) and dequantization is a row-broadcast multiply
    that fuses into the tap GEMM."""
    a = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
    scale = jnp.clip(a / 127.0, _SCALE_FLOOR, _SCALE_MAX).astype(jnp.float32)
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Shared dequant: broadcasts a scalar (per-tensor) or (rows, 1)
    (per-row) scale."""
    return q.astype(jnp.float32) * scale


def crosspod_allreduce_compressed(grads, errs, axis_name: str = "pod"):
    """Inside shard_map: psum int8-quantized grads across pods with error
    feedback.  Returns (mean_grads, new_errs)."""
    def one(g, e):
        q, scale, ne = quantize_int8(g, e)
        # int8 psum is not universally supported: widen to int32 lanes for
        # the wire format; the cost model still counts 1 byte/elt (documented)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        scale_max = jax.lax.pmax(scale, axis_name)
        return summed.astype(jnp.float32) * scale_max / n, ne

    flat, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(errs)
    out, new_e = [], []
    for g, e in zip(flat, flat_e):
        m, ne = one(g, e)
        out.append(m)
        new_e.append(ne)
    return jax.tree.unflatten(tdef, out), jax.tree.unflatten(tdef, new_e)


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
