"""Error-feedback int8 gradient compression for the slow inter-pod links.

Hierarchical DP all-reduce: gradients reduce in-pod at full precision (fast
ICI), then the *cross-pod* exchange — the bandwidth-scarce hop — carries an
int8 quantized tensor with a per-tensor scale, and the quantization error is
fed back into the next step's gradient (Seide et al. 1-bit SGD lineage).
Exposed as a pure transform so the train step composes it with shard_map
over the 'pod' axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array, err: jax.Array):
    """g, err: f32 -> (q int8, scale f32 scalar, new_err)."""
    gc = g + err
    scale = jnp.maximum(jnp.max(jnp.abs(gc)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gc / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gc - deq


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def crosspod_allreduce_compressed(grads, errs, axis_name: str = "pod"):
    """Inside shard_map: psum int8-quantized grads across pods with error
    feedback.  Returns (mean_grads, new_errs)."""
    def one(g, e):
        q, scale, ne = quantize_int8(g, e)
        # int8 psum is not universally supported: widen to int32 lanes for
        # the wire format; the cost model still counts 1 byte/elt (documented)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        scale_max = jax.lax.pmax(scale, axis_name)
        return summed.astype(jnp.float32) * scale_max / n, ne

    flat, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(errs)
    out, new_e = [], []
    for g, e in zip(flat, flat_e):
        m, ne = one(g, e)
        out.append(m)
        new_e.append(ne)
    return jax.tree.unflatten(tdef, out), jax.tree.unflatten(tdef, new_e)


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
