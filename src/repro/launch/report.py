"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report > /root/repo/results/roofline_tables.md
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import registry
from repro.configs.base import SHAPES

DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "results", "dryrun")


def fmt_bytes(b):
    for u in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024:
            return f"{b:.1f}{u}"
        b /= 1024
    return f"{b:.1f}EB"


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.0f}us"
    if x < 1:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def load_all():
    recs = {}
    for f in glob.glob(os.path.join(DIR, "*.json")):
        r = json.load(open(f))
        parts = os.path.basename(f)[:-5].split("__")
        if len(parts) != 3:
            continue                       # tagged hillclimb variants
        arch, shape, mesh = parts
        recs[(arch, shape, mesh)] = r
    return recs


def roofline_table(recs, mesh="single"):
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "bytes/chip | MODEL/HLO flops | MFU@roof |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in registry.ARCH_IDS:
        for shape in SHAPES:
            r = recs.get((arch, shape, mesh))
            if r is None:
                lines.append(f"| {arch} | {shape} | — | — | — | MISSING | | | |")
                continue
            if "skipped" in r:
                lines.append(f"| {arch} | {shape} | — | — | — | "
                             f"skip: {r['skipped'][:40]} | | | |")
                continue
            rf = r["roofline"]
            m = r["memory"]
            per_chip = (m["argument_bytes"] + m["temp_bytes"]
                        + m["output_bytes"] - m["alias_bytes"])
            lines.append(
                f"| {arch} | {shape} | {fmt_s(rf['compute_s'])} | "
                f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
                f"**{rf['dominant']}** | {fmt_bytes(per_chip)} | "
                f"{rf['model_over_hlo_flops']:.2f} | "
                f"{rf['mfu_at_roofline'] * 100:.1f}% |")
    return "\n".join(lines)


def dryrun_summary(recs):
    n_ok = sum(1 for r in recs.values() if "roofline" in r)
    n_skip = sum(1 for r in recs.values() if "skipped" in r)
    lines = [f"cells compiled: {n_ok}; skipped (documented): {n_skip}", ""]
    lines.append("| arch | shape | mesh | lower | compile | args/chip | "
                 "temp/chip | collective ops |")
    lines.append("|---|---|---|---|---|---|---|---|")
    for arch in registry.ARCH_IDS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                r = recs.get((arch, shape, mesh))
                if r is None or "roofline" not in r:
                    continue
                m = r["memory"]
                lines.append(
                    f"| {arch} | {shape} | {r['mesh']} | {r['lower_s']}s | "
                    f"{r['compile_s']}s | "
                    f"{fmt_bytes(m['argument_bytes'])} | "
                    f"{fmt_bytes(m['temp_bytes'])} | "
                    f"{r['collectives']['num_ops']} |")
    return "\n".join(lines)


def main():
    recs = load_all()
    print("## §Dry-run\n")
    print(dryrun_summary(recs))
    print("\n## §Roofline — single-pod 16x16 (256 chips), per-chip terms\n")
    print(roofline_table(recs, "single"))
    print("\n## §Roofline — multi-pod 2x16x16 (512 chips)\n")
    print(roofline_table(recs, "multi"))


if __name__ == "__main__":
    main()
