import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("REPRO_DRYRUN", "1")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
production mesh with 512 placeholder host devices; record memory_analysis,
cost_analysis and the parsed collective schedule for §Roofline.

Usage:
    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both] [--force]

Plane-parallel topology planning (``core.spatial``): lower + compile one
conv site's device-tiled executor across candidate ``dev_tiles`` meshes and
record per-shard memory, the halo geometry, and the collective schedule —
the offline answer to "how many ways should this plane split on this pod":

    python -m repro.launch.dryrun --convplane dilated_context_385
    python -m repro.launch.dryrun --convplane decoder_96 --dev-tiles 2x2,4x1

Results append incrementally to results/dryrun/<arch>__<shape>__<mesh>.json
(resp. convplane__<site>__<DhxDw>.json) so a long sweep is restartable.
"""
import argparse       # noqa: E402
import json           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402

import jax            # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import registry                      # noqa: E402
from repro.configs.base import SHAPES                   # noqa: E402
from repro.launch import hlo_analysis                   # noqa: E402
from repro.launch import roofline as rl                 # noqa: E402
from repro.launch import specs as specs_lib             # noqa: E402
from repro.launch import steps as steps_lib             # noqa: E402
from repro.launch.mesh import make_production_mesh      # noqa: E402
from repro.models import transformer as tfm             # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _mem_dict(mem) -> dict:
    return {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "generated_code_bytes": mem.generated_code_size_in_bytes,
    }


def lower_cell(arch: str, shape_name: str, multi_pod: bool, *,
               seq_parallel: bool = False, grad_accum: int = 0,
               kv_chunk: int = 0, remat: bool = True,
               parallelism: str = "auto"):
    """Build + lower + compile one cell; returns (record, compiled)."""
    cfg = registry.get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = registry.shape_applicable(cfg, shape)
    if not ok:
        return {"skipped": reason}, None
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    dist = steps_lib.make_dist(mesh, cfg, shape, seq_parallel=seq_parallel,
                               parallelism=parallelism)
    kv_chunk = kv_chunk or (2048 if shape.seq_len > 8192 else 1024)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            accum = grad_accum or steps_lib.default_grad_accum(cfg, shape)
            opt_cfg = steps_lib.opt_config_for(cfg)
            state_sds, state_sh, grad_sh = steps_lib.train_state_specs(
                cfg, dist, opt_cfg)
            # huge MoEs: bf16 grad accumulation (f32 accum alone is 10.5
            # GB/chip for 671B even fully sharded) — documented in DESIGN.md
            acc_dt = (jnp.bfloat16 if cfg.name in
                      ("deepseek-v3-671b", "dbrx-132b") else jnp.float32)
            step_fn = steps_lib.make_train_step(cfg, dist, opt_cfg,
                                                grad_accum=accum,
                                                kv_chunk=kv_chunk,
                                                accum_dtype=acc_dt,
                                                grad_shardings=grad_sh,
                                                remat=remat)
            batch_sds, batch_logical = specs_lib.batch_specs(cfg, shape)
            batch_sh = {k: dist.sharding(v) for k, v in batch_logical.items()}
            metrics_sh = {"loss": NamedSharding(mesh, P()),
                          "gnorm": NamedSharding(mesh, P())}
            lowered = jax.jit(
                step_fn, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, metrics_sh),
                donate_argnums=(0,)).lower(state_sds, batch_sds)
            extra = {"grad_accum": accum, "optimizer": opt_cfg.name}
        elif shape.kind == "prefill":
            step_fn = steps_lib.make_prefill_step(cfg, dist, kv_chunk=kv_chunk)
            p_sds, p_logical = specs_lib.param_specs(cfg)
            p_sh = dist.param_shardings(p_logical)
            batch_sds, batch_logical = specs_lib.batch_specs(cfg, shape)
            batch_sh = {k: dist.sharding(v) for k, v in batch_logical.items()}
            lowered = jax.jit(
                step_fn, in_shardings=(p_sh, batch_sh)).lower(p_sds, batch_sds)
            extra = {}
        else:  # decode
            step_fn = steps_lib.make_serve_step(cfg, dist)
            p_sds, p_logical = specs_lib.param_specs(cfg)
            p_sh = dist.param_shardings(p_logical)
            cache_sds, cache_logical = specs_lib.cache_specs(cfg, shape)
            cache_sh = jax.tree.map(
                lambda sp: dist.sharding(sp), cache_logical,
                is_leaf=lambda x: isinstance(x, P))
            tok, tok_l, mem_s, mem_l = specs_lib.decode_specs(cfg, shape)
            idx = jax.ShapeDtypeStruct((), jnp.int32)
            args = [p_sds, cache_sds, tok, idx]
            shardings = [p_sh, cache_sh, dist.sharding(tok_l),
                         NamedSharding(mesh, P())]
            if mem_s is not None:
                args.append(mem_s)
                shardings.append(dist.sharding(mem_l))
            lowered = jax.jit(
                step_fn, in_shardings=tuple(shardings),
                donate_argnums=(1,)).lower(*args)
            extra = {}
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    t0 = time.time()
    # loop-aware analysis (XLA's cost_analysis counts while bodies once)
    hc = hlo_analysis.analyze(hlo, default_group=chips)
    t_analyze = time.time() - t0
    model_flops = rl.model_flops_for(cfg, shape)
    # HLO totals are whole-program across chips; collectives per participant.
    roof = rl.roofline_from(
        {"flops": hc["flops"], "bytes accessed": hc["hbm_bytes"]},
        {"total": hc["coll_total"]}, chips, model_flops)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "seq_parallel": seq_parallel, "kv_chunk": kv_chunk,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "analyze_s": round(t_analyze, 1),
        "memory": _mem_dict(mem),
        # memory_analysis() reports the PER-DEVICE program's buffers
        "bytes_per_chip": (mem.argument_size_in_bytes
                           + mem.temp_size_in_bytes
                           + mem.output_size_in_bytes
                           - mem.alias_size_in_bytes),
        "xla_cost_analysis": {k: cost[k] for k in ("flops", "bytes accessed")
                              if k in cost},
        "collectives": {"per_kind": hc["coll_per_kind"],
                        "total": hc["coll_total"],
                        "num_ops": hc["num_collectives"]},
        "roofline": roof.to_dict(),
        **extra,
    }
    return rec, compiled


# -- plane-parallel conv topology planning ----------------------------------

# named conv sites the topology planner sweeps: the BENCH_spatial
# geometries plus a big SegNet-style encoder plane.  (kind, in_hw, c, n,
# kernel, strides, padding, dilation, batch)
CONVPLANE_SITES = {
    "dilated_context_385": dict(kind="dilated", in_hw=(385, 385), c=32, n=32,
                                kernel=(3, 3), strides=(1, 1),
                                padding=((2, 2), (2, 2)), dilation=(2, 2),
                                batch=4),
    # padding is the zoo's deconv_padding(4, 2) = (1, 3): out = 2·in
    "decoder_96": dict(kind="transposed", in_hw=(96, 96), c=64, n=32,
                       kernel=(4, 4), strides=(2, 2),
                       padding=((1, 3), (1, 3)), dilation=(1, 1), batch=4),
    "encoder_512": dict(kind="conv", in_hw=(512, 512), c=16, n=32,
                        kernel=(3, 3), strides=(1, 1),
                        padding=((1, 1), (1, 1)), dilation=(1, 1), batch=4),
}

DEFAULT_DEV_TILES = ((2, 1), (4, 1), (2, 2), (8, 1), (4, 2))


def convplane_spec(site: str, dev_tiles):
    from repro.core.plan import ConvSpec
    g = CONVPLANE_SITES[site]
    return ConvSpec(kind=g["kind"], in_hw=g["in_hw"], in_c=g["c"],
                    out_c=g["n"], kernel_hw=g["kernel"],
                    strides=g["strides"], padding=g["padding"],
                    dilation=g["dilation"], backend="xla",
                    spatial=tuple(dev_tiles))


def lower_convplane(site: str, dev_tiles):
    """Lower + compile one conv site's plane-parallel executor on a
    ``make_spatial_mesh(D_h, D_w)`` of placeholder host devices; returns the
    per-shard memory / halo-geometry / collective record."""
    from repro.core import spatial
    from repro.core.plan import plan_conv
    from repro.launch.mesh import make_spatial_mesh

    spec = convplane_spec(site, dev_tiles)
    sp = spatial.spatial_plan(spec)
    if sp is None:
        return {"site": site, "dev_tiles": list(dev_tiles),
                "skipped": "geometry does not admit one-hop halo exchange"}
    plan = plan_conv(spec)
    b = CONVPLANE_SITES[site]["batch"]
    h, w = spec.in_hw
    x = jax.ShapeDtypeStruct((b, h, w, spec.in_c), jnp.float32)
    pk = jax.ShapeDtypeStruct(
        (plan.total_taps * spec.in_c, spec.out_c), jnp.float32)
    mesh = make_spatial_mesh(*dev_tiles)

    t0 = time.time()
    with spatial.use_spatial_mesh(mesh):
        lowered = jax.jit(lambda a, k: plan.apply(a, k)).lower(x, pk)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    hc = hlo_analysis.analyze(compiled.as_text(), default_group=mesh.size)
    th, tw = sp.dims
    return {
        "site": site, "spec": dataclasses_asdict_spec(spec),
        "dev_tiles": list(dev_tiles), "devices": mesh.size,
        "route": plan.route_for_batch(b).path,
        "halo": {
            "h": {"block": th.block, "tin": th.tin, "halo_lo": th.halo_lo,
                  "halo_hi": th.halo_hi, "pad_to": th.pad_to},
            "w": {"block": tw.block, "tin": tw.tin, "halo_lo": tw.halo_lo,
                  "halo_hi": tw.halo_hi, "pad_to": tw.pad_to},
        },
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": _mem_dict(mem),
        "bytes_per_chip": (mem.argument_size_in_bytes
                           + mem.temp_size_in_bytes
                           + mem.output_size_in_bytes
                           - mem.alias_size_in_bytes),
        "collectives": {"per_kind": hc["coll_per_kind"],
                        "total": hc["coll_total"],
                        "num_ops": hc["num_collectives"]},
    }


def dataclasses_asdict_spec(spec) -> dict:
    import dataclasses as _dc
    return {k: list(v) if isinstance(v, tuple) else v
            for k, v in _dc.asdict(spec).items()}


def run_convplane(site: str, dev_tiles, force=False):
    dh, dw = dev_tiles
    out = os.path.join(RESULTS_DIR, f"convplane__{site}__{dh}x{dw}.json")
    if os.path.exists(out) and not force:
        print(f"[skip-cached] {out}")
        return json.load(open(out))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    print(f"[convplane] {site} x {dh}x{dw} ...", flush=True)
    try:
        rec = lower_convplane(site, dev_tiles)
    except Exception as e:
        rec = {"site": site, "dev_tiles": list(dev_tiles),
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
        with open(out + ".err", "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[FAIL] {site} {dh}x{dw}: {e}", flush=True)
        return rec
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    if "skipped" in rec:
        print(f"[skip] {site} {dh}x{dw}: {rec['skipped']}", flush=True)
    else:
        print(f"[ok] lower {rec['lower_s']}s compile {rec['compile_s']}s | "
              f"{rec['bytes_per_chip'] / 2**20:.1f} MiB/chip, "
              f"collectives {rec['collectives']['num_ops']}", flush=True)
    return rec


def cell_path(arch, shape_name, multi_pod, tag=""):
    mesh = "multi" if multi_pod else "single"
    sfx = f"__{tag}" if tag else ""
    return os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{mesh}{sfx}.json")


def run_cell(arch, shape_name, multi_pod, force=False, tag="", **kw):
    out = cell_path(arch, shape_name, multi_pod, tag)
    if os.path.exists(out) and not force:
        print(f"[skip-cached] {out}")
        return json.load(open(out))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    print(f"[dryrun] {arch} x {shape_name} x "
          f"{'2x16x16' if multi_pod else '16x16'} ...", flush=True)
    try:
        rec, compiled = lower_cell(arch, shape_name, multi_pod, **kw)
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
        with open(out + ".err", "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[FAIL] {arch} {shape_name}: {e}", flush=True)
        return rec
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    if "skipped" in rec:
        print(f"[skip] {arch} {shape_name}: {rec['skipped']}", flush=True)
    else:
        r = rec["roofline"]
        print(f"[ok] lower {rec['lower_s']}s compile {rec['compile_s']}s | "
              f"compute {r['compute_s']:.3e}s memory {r['memory_s']:.3e}s "
              f"collective {r['collective_s']:.3e}s -> {r['dominant']}",
              flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=0)
    ap.add_argument("--kv-chunk", type=int, default=0)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--parallelism", choices=("auto", "dp_only"),
                    default="auto")
    ap.add_argument("--tag", default="", help="suffix for the result file "
                    "(hillclimb variants keep the baseline intact)")
    ap.add_argument("--convplane", choices=tuple(CONVPLANE_SITES),
                    help="plane-parallel topology sweep for one conv site "
                    "(skips the transformer grid)")
    ap.add_argument("--dev-tiles", default="",
                    help="comma-separated DhxDw list for --convplane "
                    "(default: the standard candidate set)")
    args = ap.parse_args()

    if args.convplane:
        if args.dev_tiles:
            tiles = tuple(tuple(int(v) for v in t.split("x"))
                          for t in args.dev_tiles.split(","))
        else:
            tiles = DEFAULT_DEV_TILES
        for dt in tiles:
            run_convplane(args.convplane, dt, force=args.force)
        return

    archs = registry.ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = {"single": (False,), "multi": (True,),
              "both": (False, True)}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                run_cell(arch, shape, mp, force=args.force, tag=args.tag,
                         seq_parallel=args.seq_parallel,
                         grad_accum=args.grad_accum,
                         kv_chunk=args.kv_chunk,
                         remat=not args.no_remat,
                         parallelism=args.parallelism)


if __name__ == "__main__":
    main()
