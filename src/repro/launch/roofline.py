"""Three-term roofline from the compiled dry-run artifact.

    compute     = HLO_FLOPs / (chips * peak_FLOP/s)
    memory      = HLO_bytes / (chips * HBM_bw)
    collective  = per-chip collective traffic / link_bw
                  (== global traffic / (chips * link_bw))

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-program,
all chips).  Collective traffic is parsed from the post-SPMD compiled HLO
text: for each all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute op we take the result shape bytes (per participant) and
apply the standard ring-traffic factor:

    all-reduce(S)        2 * S * (n-1)/n        (reduce-scatter + all-gather)
    all-gather(S_out)    S_out * (n-1)/n
    reduce-scatter(S_o)  S_o * (n-1)            (streams (n-1)/n of its input)
    all-to-all(S)        S * (n-1)/n
    collective-permute   S

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\()?(\w+)\[([\d,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_TUPLE_COLL_RE = re.compile(
    r"=\s+\((.*?)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    if "collective-permute" in line:
        return 2
    return default


def _traffic(kind: str, size: int, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * size * (n - 1) / n
    if kind == "all-gather":
        return size * (n - 1) / n
    if kind == "reduce-scatter":
        return float(size) * (n - 1)
    if kind == "all-to-all":
        return size * (n - 1) / n
    return float(size)        # collective-permute


def collective_bytes(hlo_text: str, default_group: int) -> dict:
    """Per-participant collective traffic summed over the program."""
    per_kind: dict[str, float] = {}
    count = 0
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        kind = None
        size = 0
        if m:
            kind = m.group(3)
            size = _shape_bytes(m.group(1), m.group(2))
        else:
            mt = _TUPLE_COLL_RE.search(line)
            if mt:
                kind = mt.group(2)
                size = sum(_shape_bytes(d, s)
                           for d, s in _SHAPE_RE.findall(mt.group(1)))
        if kind is None:
            continue
        n = _group_size(line, default_group)
        per_kind[kind] = per_kind.get(kind, 0.0) + _traffic(kind, size, n)
        count += 1
    return {"per_kind": per_kind, "total": sum(per_kind.values()),
            "num_ops": count}


@dataclasses.dataclass
class Roofline:
    """All inputs are PER-CHIP (post-SPMD compiled HLO is one device's
    program); model_flops is global and normalized by chips."""

    compute_s: float
    memory_s: float
    collective_s: float
    flops: float              # per chip
    bytes_hbm: float          # per chip
    bytes_coll: float         # per chip
    model_flops: float        # global
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def mfu(self) -> float:
        """model-FLOPs utilization at the roofline-predicted step time."""
        t = self.step_time_s
        return (self.model_flops / (self.chips * PEAK_FLOPS)) / t if t else 0.0

    @property
    def flops_ratio(self) -> float:
        """useful (model) FLOPs / compiled FLOPs — remat/redundancy waste."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def to_dict(self):
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "hlo_flops_per_chip": self.flops,
            "hlo_bytes_per_chip": self.bytes_hbm,
            "coll_bytes_per_chip": self.bytes_coll,
            "model_flops": self.model_flops,
            "model_over_hlo_flops": self.flops_ratio,
            "mfu_at_roofline": self.mfu, "chips": self.chips,
        }


def roofline_from(cost: dict, coll: dict, chips: int,
                  model_flops: float) -> Roofline:
    """cost/coll values are per-chip quantities from the partitioned HLO."""
    flops = float(cost.get("flops", 0.0))
    bts = float(cost.get("bytes accessed", 0.0))
    coll_b = float(coll["total"])
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=bts / HBM_BW,
        collective_s=coll_b / LINK_BW,
        flops=flops, bytes_hbm=bts, bytes_coll=coll_b,
        model_flops=model_flops, chips=chips)


# ---------------------------------------------------------------------------
# MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference)
# ---------------------------------------------------------------------------

def active_param_count(cfg) -> float:
    """Matmul parameters touched per token (MoE: top-k + shared only)."""
    d = cfg.d_model

    def layer_params(kind: str) -> float:
        if kind == "ssd":
            di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
            return d * (2 * di + 2 * g * n + h) + di * d
        if kind == "rec":
            dr = cfg.lru_width
            return 2 * d * dr + 2 * dr * dr + dr * d + 3 * d * cfg.d_ff
        if kind in ("mla", "mla_moe"):
            a = (d * cfg.q_lora_rank
                 + cfg.q_lora_rank * cfg.num_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
                 + d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                 + cfg.kv_lora_rank * cfg.num_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
                 + cfg.num_heads * cfg.v_head_dim * d)
        else:
            hd = cfg.head_dim
            a = d * cfg.num_heads * hd + 2 * d * cfg.num_kv_heads * hd \
                + cfg.num_heads * hd * d
            if kind == "dec":
                a *= 2  # + cross attention
        if kind in ("moe", "mla_moe"):
            f = (cfg.top_k * 3 * d * cfg.d_expert
                 + cfg.n_shared * 3 * d * cfg.d_expert + d * cfg.n_experts)
        else:
            f = 3 * d * cfg.d_ff
        return a + f

    total = 0.0
    for kinds, reps in cfg.stages:
        total += reps * sum(layer_params(k) for k in kinds)
    for kinds, reps in getattr(cfg, "encoder_stages", ()):
        total += reps * sum(layer_params(k) for k in kinds)
    total += d * cfg.vocab_size          # lm head (tied or not, compute is real)
    return total


def model_flops_for(cfg, shape, chips_tokens: Optional[int] = None) -> float:
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
