"""LM serving driver: batched greedy decode with a persistent KV/state cache.

Runs a reduced config end-to-end on CPU (the production mesh path is
exercised by the dry-run):

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import transformer as tfm


def serve(arch: str, *, batch=4, prompt_len=8, gen_tokens=16, reduced=True):
    cfg = registry.get_reduced(arch) if reduced else registry.get_config(arch)
    key = jax.random.PRNGKey(0)
    params, _ = tfm.init(key, cfg)
    max_len = prompt_len + gen_tokens
    cache, _ = tfm.init_cache(cfg, batch, max_len)
    memory = None
    if cfg.is_encoder_decoder:
        memory = jax.random.normal(key, (batch, 16, cfg.d_model),
                                   jnp.bfloat16)

    @jax.jit
    def step(params, cache, tok, idx):
        logits, cache = tfm.decode_step(params, cache, tok, idx, cfg,
                                        memory=memory)
        return jnp.argmax(logits[:, -1:], -1).astype(jnp.int32), cache

    prompt = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    # prefill token-by-token (simple driver; prefill_32k shape covers bulk)
    tok = prompt[:, :1]
    t0 = time.perf_counter()
    out_tokens = []
    for i in range(max_len - 1):
        nxt, cache = step(params, cache,
                          prompt[:, i:i + 1] if i < prompt_len else tok, i)
        tok = nxt
        if i >= prompt_len - 1:
            out_tokens.append(np.asarray(tok)[:, 0])
    dt = time.perf_counter() - t0
    gen = np.stack(out_tokens, 1)
    return gen, dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()
    gen, dt = serve(args.arch, batch=args.batch, gen_tokens=args.tokens)
    n = gen.size
    print(f"arch={args.arch} generated {gen.shape} tokens in {dt:.2f}s "
          f"({n / dt:.1f} tok/s); sample: {gen[0][:8]}")
    assert np.isfinite(gen).all()


if __name__ == "__main__":
    main()
