"""Step builders: jit-able train / prefill / serve steps with full sharding.

``make_dist`` chooses the parallelism rules per (mesh, shape):
  - batch over ('pod','data') (multi-pod) or ('data',) — replicated if B==1
  - TP on 'model' (heads / ffn / vocab / experts)
  - long-context decode shards the KV cache sequence dim over 'data'
  - optional SP (sequence-parallel residual stream) via rules['seq']='model'
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tfm
from repro.sharding import DEFAULT_RULES, DistContext
from repro.train import optim as opt_lib
from repro.launch import specs as specs_lib


def make_dist(mesh, cfg: ModelConfig, shape: ShapeConfig, *,
              seq_parallel: bool = False,
              parallelism: str = "auto") -> DistContext:
    """``parallelism``: 'auto' (TP on model axis per DEFAULT_RULES) or
    'dp_only' (§Perf lever: batch over ALL mesh axes, no tensor parallelism
    — right for small dense models where TP collectives dominate)."""
    rules = dict(DEFAULT_RULES)
    axes = mesh.axis_names
    if parallelism == "dp_only":
        batch_axes = tuple(a for a in ("pod", "data", "model") if a in axes)
        dp = 1
        for a in batch_axes:
            dp *= mesh.shape[a]
        if shape.global_batch % max(dp, 1) != 0 or shape.global_batch < dp:
            # pure DP needs batch >= mesh size (e.g. 256-seq batch on 512
            # chips would replicate compute 2x) — fall back to TP rules
            return make_dist(mesh, cfg, shape, seq_parallel=seq_parallel,
                             parallelism="auto")
        rules["heads"] = None
        rules["ffn"] = None
        rules["vocab"] = None      # 'model' now carries batch; replicate head
        rules["kv_heads"] = None
        rules["batch"] = batch_axes
        return DistContext(mesh=mesh, rules=rules)
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    if shape.global_batch % max(dp, 1) != 0 or shape.global_batch < dp:
        # un-shardable batch (e.g. long_500k B=1): replicate batch,
        # shard the long KV-cache sequence dim over 'data' instead.
        rules["batch"] = None
        rules["kv_seq"] = "data"
    else:
        rules["batch"] = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    if shape.kind == "decode" and shape.seq_len >= 2 ** 18:
        rules["kv_seq"] = "data"
    if shape.kind == "decode" and (cfg.num_kv_heads % mesh.shape["model"]
                                   or cfg.use_mla):
        # GQA caches with few KV heads can't split on TP, and the MLA
        # compressed cache has no heads dim at all; shard the cache
        # *sequence* over 'model' instead (context-parallel decode) — the
        # cache must not be replicated (e.g. qwen2-7b decode_32k is 240 GB,
        # deepseek MLA decode_32k is 18 GB/chip batch-sharded only).
        rules["kv_heads"] = None
        if rules["kv_seq"] is None:
            rules["kv_seq"] = "model"
    if seq_parallel:
        rules["seq"] = "model"
    if cfg.family == "ssm":
        # mamba2-130m: 24 SSD heads / fused 3352-wide in-proj don't split 16
        # ways, and a 130M model has no business doing TP — pure DP, with the
        # embedding still sharded on 'model' (padded vocab divides evenly).
        rules["heads"] = None
        rules["ffn"] = None
    # Weight-state sharding for the huge MoEs (params exceed TP-sharded HBM:
    # 671B bf16 / 16 = 84 GB/chip).  Experts store sharded over data*model
    # (ZeRO-3-style); SPMD all-gathers each layer's experts over 'data' at
    # use — the standard weight-gathering tradeoff, overlappable.
    if cfg.n_experts and cfg.n_experts % (dp_total(mesh) * mesh.shape["model"]) == 0:
        rules["expert"] = tuple(a for a in ("data", "model")
                                if a in mesh.axis_names)
    elif cfg.n_experts and cfg.d_expert % 128 == 0 and \
            (cfg.n_experts * cfg.d_expert * cfg.d_model * 3
             * cfg.num_layers * 2) > 64e9:      # total expert bytes (bf16)
        rules["expert_ffn"] = "data"     # dbrx: shard expert hidden over data
    return DistContext(mesh=mesh, rules=rules)


def dp_total(mesh) -> int:
    n = 1
    for a in ("data",):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def opt_config_for(cfg: ModelConfig) -> opt_lib.OptConfig:
    # Adam state for the huge MoE configs exceeds single-pod HBM -> Adafactor
    if cfg.name in ("deepseek-v3-671b", "dbrx-132b"):
        return opt_lib.OptConfig(name="adafactor", lr=1e-4)
    return opt_lib.OptConfig(name="adamw", lr=3e-4)


def default_grad_accum(cfg: ModelConfig, shape: ShapeConfig) -> int:
    if shape.kind != "train":
        return 1
    if cfg.d_model >= 6000:
        return 8
    if cfg.d_model >= 4000:
        return 8       # glm4-9b: accum 4 leaves 19.4 GB/chip, 8 fits v5e
    if cfg.d_model >= 3000:
        return 4
    return 2


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, dist: DistContext,
                    opt_cfg: opt_lib.OptConfig, grad_accum: int = 1,
                    kv_chunk: int = 1024, accum_dtype=jnp.float32,
                    grad_shardings=None, remat: bool = True):
    """``grad_shardings``: optional pytree of NamedShardings for the grad
    accumulator (ZeRO-2: shard accumulated grads over 'data' — XLA then
    reduce-scatters each microbatch instead of all-reducing + keeping a
    replicated f32 copy, cutting accumulator HBM by the DP degree)."""
    opt_init, opt_update = opt_lib.OPTIMIZERS[opt_cfg.name]

    def train_step(state, batch):
        params = state["params"]

        def loss_of(p, mb):
            return tfm.loss_fn(p, mb, cfg, dist, kv_chunk=kv_chunk,
                               remat=remat)

        if grad_accum > 1:
            def resplit(x):
                y = x.reshape((grad_accum, x.shape[0] // grad_accum)
                              + x.shape[1:])
                if dist is not None:
                    # keep the batch sharding on the *microbatch* dim — else
                    # SPMD re-gathers every scan step (observed as XLA's
                    # "involuntary full rematerialization" warning)
                    y = dist.constrain(
                        y, P(None, dist.rules["batch"],
                             *([None] * (x.ndim - 1))))
                return y

            mbs = jax.tree.map(resplit, batch)

            def constrain_g(g):
                if grad_shardings is None:
                    return g
                return jax.tree.map(
                    lambda a, s: jax.lax.with_sharding_constraint(a, s),
                    g, grad_shardings)

            def micro(carry, mb):
                loss, g = jax.value_and_grad(loss_of)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), carry[1], g)
                return (carry[0] + loss, constrain_g(gsum)), None

            zero_g = constrain_g(jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params))
            (loss_sum, gsum), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zero_g), mbs)
            loss = loss_sum / grad_accum
            grads = jax.tree.map(
                lambda g: g.astype(jnp.float32) / grad_accum, gsum)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)

        new_params, new_opt, gnorm = opt_update(grads, state["opt"], params,
                                                opt_cfg)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, "gnorm": gnorm}

    return train_step


def train_state_specs(cfg: ModelConfig, dist: DistContext,
                      opt_cfg: opt_lib.OptConfig):
    """(state ShapeDtypeStructs, state NamedShardings, grad accumulator
    NamedShardings) — no allocation.  Grad shardings are the resolved param
    specs extended ZeRO-2-style over the data axis."""
    opt_init, _ = opt_lib.OPTIMIZERS[opt_cfg.name]
    p_sds, p_logical = specs_lib.param_specs(cfg)
    cell = {}

    def mk_opt(p):
        st, st_specs = opt_init(p, p_logical, dist, opt_cfg)
        cell["specs"] = st_specs
        return st

    o_sds = jax.eval_shape(mk_opt, p_sds)
    state_sds = {"params": p_sds, "opt": o_sds,
                 "step": jax.ShapeDtypeStruct((), jnp.int32)}
    logical = {"params": p_logical, "opt": cell["specs"], "step": P()}
    shardings = jax.tree.map(
        lambda sp: dist.sharding(sp), logical,
        is_leaf=lambda x: isinstance(x, P))
    from jax.sharding import NamedSharding
    grad_shardings = jax.tree.map(
        lambda sp, sds: NamedSharding(
            dist.mesh,
            opt_lib._zero1_spec(dist.resolve(sp), sds.shape, "data")),
        p_logical, p_sds, is_leaf=lambda x: isinstance(x, P))
    return state_sds, shardings, grad_shardings


# ---------------------------------------------------------------------------
# prefill / serve steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, dist: DistContext,
                      kv_chunk: int = 1024):
    def prefill_step(params, batch):
        logits = tfm.forward(params, batch, cfg, dist, kv_chunk=kv_chunk,
                             remat=False)
        # realistic prefill output: next-token logits for the last position
        return logits[:, -1, :]

    return prefill_step


def make_serve_step(cfg: ModelConfig, dist: DistContext):
    def serve_step(params, cache, tokens, idx, memory=None):
        logits, new_cache = tfm.decode_step(params, cache, tokens, idx, cfg,
                                            dist, memory=memory)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_cache

    return serve_step
