"""ShapeDtypeStruct stand-ins for every model input — the dry-run's inputs.

Weak-type-correct, shardable, zero allocation.  Training/prefill shapes get a
token (or stub-embedding) batch; decode shapes get (token, cache) where the
cache ShapeDtypeStructs come from ``jax.eval_shape`` over ``init_cache``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tfm

# decode/prefill shapes for enc-dec archs: stub source memory length
SRC_FRAMES = 3072


def batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Training/prefill batch ShapeDtypeStructs + logical shardings."""
    b, s = shape.global_batch, shape.seq_len
    sds, shard = {}, {}
    if cfg.frontend != "none" and not cfg.is_encoder_decoder:
        sds["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        shard["embeds"] = P("batch", None, None)
    sds["inputs"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    shard["inputs"] = P("batch", None)
    if shape.kind == "train":
        sds["targets"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        shard["targets"] = P("batch", None)
    if cfg.is_encoder_decoder:
        sds["src_embeds"] = jax.ShapeDtypeStruct((b, SRC_FRAMES, cfg.d_model),
                                                 jnp.bfloat16)
        shard["src_embeds"] = P("batch", None, None)
    return sds, shard


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Decode cache ShapeDtypeStructs + logical shardings."""
    b, s = shape.global_batch, shape.seq_len
    cache_sds = jax.eval_shape(lambda: tfm.init_cache(cfg, b, s)[0])
    return cache_sds, tfm.cache_specs_only(cfg)


def decode_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(tokens, tok_shard, memory, mem_shard) for serve_step."""
    b = shape.global_batch
    tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tok_shard = P("batch", None)
    mem, mem_shard = None, None
    if cfg.is_encoder_decoder:
        mem = jax.ShapeDtypeStruct((b, SRC_FRAMES, cfg.d_model), jnp.bfloat16)
        mem_shard = P("batch", None, None)
    return tok, tok_shard, mem, mem_shard


def param_specs(cfg: ModelConfig):
    """(param ShapeDtypeStructs, logical shardings) with zero allocation."""
    cell = {}

    def f(key):
        p, s = tfm.init(key, cfg)
        cell["s"] = s
        return p

    sds = jax.eval_shape(f, jax.random.PRNGKey(0))
    return sds, cell["s"]
