"""End-to-end training driver: data pipeline -> sharded train step ->
checkpoint/restart -> straggler monitor.  Runs real steps on small meshes
(CPU integration) and is the template the dry-run lowers for the production
mesh.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --reduced --steps 20 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ShapeConfig
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tfm
from repro.runtime.fault import (FailureInjector, Heartbeat, NodeFailure,
                                 StragglerMonitor, run_with_restarts)
from repro.train import optim as opt_lib
from repro.train.checkpoint import CheckpointManager
from repro.train.data import TokenPipeline


def build_state(cfg, key):
    params, specs = tfm.init(key, cfg)
    opt_cfg = steps_lib.opt_config_for(cfg)
    opt_init, _ = opt_lib.OPTIMIZERS[opt_cfg.name]
    opt_state, _ = opt_init(params, None, None, opt_cfg)
    return {"params": params, "opt": opt_state,
            "step": jnp.zeros((), jnp.int32)}, specs, opt_cfg


def train(arch: str, *, reduced=True, steps=20, batch=8, seq=64,
          ckpt_dir=None, ckpt_every=10, fail_at=(), data=1, model=1,
          log_every=5):
    cfg = registry.get_reduced(arch) if reduced else registry.get_config(arch)
    shape = ShapeConfig("custom", "train", seq, batch)
    dist = None
    mesh_ctx = None
    if data * model > 1:
        mesh = make_host_mesh(data=data, model=model)
        dist = steps_lib.make_dist(mesh, cfg, shape)
        mesh_ctx = mesh

    state, specs, opt_cfg = build_state(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(steps_lib.make_train_step(cfg, dist, opt_cfg,
                                                kv_chunk=max(seq // 4, 16)))
    pipe = TokenPipeline(cfg, batch, seq,
                         src_len=64 if cfg.is_encoder_decoder else 0)
    ckpt = CheckpointManager(ckpt_dir, keep=2) if ckpt_dir else None
    injector = FailureInjector(tuple(fail_at))
    monitor = StragglerMonitor()
    hb = Heartbeat(timeout=3600)
    losses = []

    def restore_latest() -> int:
        # run_with_restarts' explicit restore contract: reload the train
        # state from the latest checkpoint, return the step to resume at
        nonlocal state
        assert ckpt is not None, "failure without checkpointing"
        step0 = ckpt.latest_step() or 0
        state = ckpt.restore(state, step=step0)
        print(f"[restart] restored step {step0}")
        return step0

    def loop(start_step: int) -> int:
        nonlocal state
        s = int(np.asarray(jax.device_get(state["step"])))
        while s < steps:
            batch_np = pipe.batch_at(s)
            t0 = time.monotonic()
            injector.check(s)
            state, metrics = step_fn(state, batch_np)
            loss = float(np.asarray(jax.device_get(metrics["loss"])))
            dt = time.monotonic() - t0
            monitor.record(s, dt)
            hb.beat()
            losses.append(loss)
            if s % log_every == 0:
                print(f"step {s:5d} loss {loss:.4f} "
                      f"gnorm {float(np.asarray(metrics['gnorm'])):.3f} "
                      f"dt {dt * 1e3:.0f}ms")
            s += 1
            if ckpt and s % ckpt_every == 0:
                ckpt.save(s, state)
        if ckpt:
            ckpt.save(steps, state, block=True)
            ckpt.wait()
        return s

    restore = restore_latest if ckpt else None
    if mesh_ctx is not None:
        with mesh_ctx:
            final = run_with_restarts(loop, restore=restore,
                                      on_restart=lambda n, e: print(
                                          f"[fault] restart {n}: {e}"))
    else:
        final = run_with_restarts(loop, restore=restore,
                                  on_restart=lambda n, e: print(
                                      f"[fault] restart {n}: {e}"))
    return losses, final


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    args = ap.parse_args()
    losses, final = train(args.arch, reduced=args.reduced, steps=args.steps,
                          batch=args.batch, seq=args.seq,
                          ckpt_dir=args.ckpt_dir, fail_at=args.fail_at,
                          data=args.data, model=args.model)
    print(f"done at step {final}; loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
