"""Production mesh construction.

A FUNCTION (not module-level state) so importing never touches jax device
initialization.  Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis carries
hierarchical data parallelism (gradient all-reduce staged within-pod first,
then across the slow inter-pod links).
"""
from __future__ import annotations

import jax

# jax 0.4.x has no jax.sharding.AxisType (meshes are Auto by default);
# pass axis_types only on versions that support it
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def _mesh(shape, axes):
    if _AXIS_TYPE is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(_AXIS_TYPE.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small explicit mesh for CPU integration tests."""
    if pod:
        return _mesh((pod, data, model), ("pod", "data", "model"))
    return _mesh((data, model), ("data", "model"))


def make_spatial_mesh(sp_h: int, sp_w: int = 1, data: int = 1):
    """Mesh for plane-parallel conv execution (``core.spatial``): 'sp_h' /
    'sp_w' carry one conv plane's rows/cols (the ``DEFAULT_RULES``
    'plane_h'/'plane_w' targets).  The leading 'data' axis (extent 1 by
    default) keeps batch parallelism alive and lets the serving layer's
    ``image_spec`` constraints resolve on this mesh unchanged.  Axis order
    is (data, sp_h, sp_w) so neighbouring spatial shards land on
    neighbouring devices — the halo ``ppermute`` is a nearest-neighbour
    hop on ring interconnects."""
    return _mesh((data, sp_h, sp_w), ("data", "sp_h", "sp_w"))
