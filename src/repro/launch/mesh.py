"""Production mesh construction.

A FUNCTION (not module-level state) so importing never touches jax device
initialization.  Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis carries
hierarchical data parallelism (gradient all-reduce staged within-pod first,
then across the slow inter-pod links).
"""
from __future__ import annotations

import jax

# jax 0.4.x has no jax.sharding.AxisType (meshes are Auto by default);
# pass axis_types only on versions that support it
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def _mesh(shape, axes):
    if _AXIS_TYPE is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(_AXIS_TYPE.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small explicit mesh for CPU integration tests."""
    if pod:
        return _mesh((pod, data, model), ("pod", "data", "model"))
    return _mesh((data, model), ("data", "model"))
