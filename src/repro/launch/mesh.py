"""Production mesh construction.

A FUNCTION (not module-level state) so importing never touches jax device
initialization.  Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis carries
hierarchical data parallelism (gradient all-reduce staged within-pod first,
then across the slow inter-pod links).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small explicit mesh for CPU integration tests."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,) * 3)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
