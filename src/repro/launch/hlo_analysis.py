"""Loop-aware cost analysis over compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers / grad-accum / remat program under-reports FLOPs, bytes and
collectives by orders of magnitude (we measured 1822x on llama3.2-1b).  This
module re-derives the three roofline inputs by walking the HLO computation
graph with loop trip counts:

  flops        — dot / convolution ops (elementwise excluded, documented)
  hbm bytes    — per codegen unit (fusion boundary): operands + results
  collectives  — ring-traffic bytes per participant (see roofline.py)

Trip counts come from the scan-lowered ``while`` condition (compare against a
constant).  All loops in this codebase are static-bound scans, so this is
exact here.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "opaque": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INST_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*?)\)(.*)$")
# header: "%name (args) -> ret {"; args may contain nested parens (tuples)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_ZERO_COST = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _parse_shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _parse_dims(type_str: str) -> Optional[tuple[str, list[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class Inst:
    name: str
    type_str: str
    opcode: str
    operands: list
    args: str
    attrs: str


def parse_module(text: str) -> dict[str, list[Inst]]:
    comps: dict[str, list[Inst]] = {}
    cur: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INST_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, args, attrs = m.groups()
        operands = _OPERAND_RE.findall(args)
        comps[cur].append(Inst(name, type_str, opcode, operands, args, attrs))
    return comps


class HloCost:
    def __init__(self, text: str, default_group: int):
        self.comps = parse_module(text)
        self.default_group = default_group
        self.shapes: dict[str, dict[str, str]] = {
            c: {i.name: i.type_str for i in insts}
            for c, insts in self.comps.items()
        }
        self._memo: dict[str, tuple] = {}
        self.entry = self._find_entry(text)

    def _find_entry(self, text: str) -> str:
        m = re.search(r"ENTRY\s+%?([\w\.\-]+)", text)
        if m:
            return m.group(1)
        return next(iter(self.comps)) if self.comps else ""

    # -- trip counts ---------------------------------------------------------
    def trip_count(self, cond_comp: str) -> int:
        """Static bound of a scan-lowered while: the integer constant the
        induction variable is compared against (induction starts at 0 for
        every lax.scan here).  Fallback: largest int constant in the cond."""
        insts = self.comps.get(cond_comp, [])
        consts: dict[str, int] = {}
        for i in insts:
            if i.opcode == "constant":
                m = re.match(r"^(-?\d+)$", i.args.strip())
                if m:
                    consts[i.name] = int(m.group(1))
        for i in insts:
            if i.opcode == "compare":
                for op in i.operands:
                    if op in consts and consts[op] > 0:
                        return consts[op]
        pos = [v for v in consts.values() if v > 0]
        return max(pos) if pos else 1

    # -- per-instruction costs ----------------------------------------------
    def _dot_flops(self, comp: str, inst: Inst) -> float:
        out = _parse_dims(inst.type_str)
        if out is None:
            return 0.0
        _, out_dims = out
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.attrs)
        lhs_t = self.shapes[comp].get(inst.operands[0]) if inst.operands else None
        if lhs_t is None or m is None:
            return 0.0
        lhs = _parse_dims(lhs_t)
        if lhs is None:
            return 0.0
        _, lhs_dims = lhs
        k = 1
        for d in m.group(1).split(","):
            if d:
                k *= lhs_dims[int(d)]
        n_out = 1
        for d in out_dims:
            n_out *= d
        return 2.0 * n_out * k

    def _conv_flops(self, comp: str, inst: Inst) -> float:
        out = _parse_dims(inst.type_str)
        rhs_t = self.shapes[comp].get(inst.operands[1]) if len(inst.operands) > 1 else None
        if out is None or rhs_t is None:
            return 0.0
        _, out_dims = out
        rhs = _parse_dims(rhs_t)
        if rhs is None:
            return 0.0
        _, rhs_dims = rhs
        m = re.search(r"dim_labels=([\w\d]+)_([\w\d]+)->", inst.attrs)
        n_out = 1
        for d in out_dims:
            n_out *= d
        # kernel contribution: product of rhs dims except output-feature dim
        if m:
            rhs_labels = m.group(2)
            k = 1
            for lab, dim in zip(rhs_labels, rhs_dims):
                if lab != "o":
                    k *= dim
        else:
            k = 1
            for dim in rhs_dims[:-1]:
                k *= dim
        feat_div = 1
        gm = re.search(r"feature_group_count=(\d+)", inst.attrs)
        if gm:
            feat_div = int(gm.group(1))
        return 2.0 * n_out * k / feat_div

    def _group_size(self, attrs: str) -> int:
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
        if m:
            return int(m.group(2))
        m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
        if m:
            return len(m.group(1).split(","))
        m = re.search(r"source_target_pairs=", attrs)
        if m:
            return 2
        return self.default_group

    def _coll_traffic(self, comp: str, inst: Inst) -> tuple[str, float]:
        kind = inst.opcode.replace("-start", "")
        size = _parse_shape_bytes(inst.type_str)
        if kind == "all-gather" and inst.type_str.startswith("("):
            pass
        n = self._group_size(inst.attrs)
        if n <= 1:
            return kind, 0.0
        if kind == "all-reduce":
            t = 2.0 * size * (n - 1) / n
        elif kind == "all-gather":
            t = size * (n - 1) / n
        elif kind == "reduce-scatter":
            t = float(size) * (n - 1)
        elif kind == "all-to-all":
            t = size * (n - 1) / n
        else:
            t = float(size)
        return kind, t

    def _io_bytes(self, comp: str, inst: Inst) -> float:
        b = _parse_shape_bytes(inst.type_str)
        for op in inst.operands:
            t = self.shapes[comp].get(op)
            if t:
                b += _parse_shape_bytes(t)
        return float(b)

    # -- recursive computation cost -------------------------------------------
    def cost(self, comp: Optional[str] = None) -> dict:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        flops = 0.0
        hbm = 0.0
        by_op: dict[str, float] = {}
        coll: dict[str, float] = {}
        n_coll = 0
        for inst in self.comps.get(comp, []):
            op = inst.opcode
            if op in _ZERO_COST:
                continue
            base = op.replace("-start", "")
            if op == "while":
                body = cond = None
                mb = re.search(r"body=%?([\w\.\-]+)", inst.attrs)
                mc = re.search(r"condition=%?([\w\.\-]+)", inst.attrs)
                if mb:
                    body = mb.group(1)
                if mc:
                    cond = mc.group(1)
                # XLA records the exact bound when it can prove it
                mt = re.search(r'known_trip_count[^0-9]*(\d+)', inst.attrs)
                if mt:
                    trips = int(mt.group(1))
                else:
                    trips = self.trip_count(cond) if cond else 1
                sub = self.cost(body) if body else None
                if sub:
                    flops += sub["flops"] * trips
                    hbm += sub["hbm_bytes"] * trips
                    for k, v in sub["coll"].items():
                        coll[k] = coll.get(k, 0.0) + v * trips
                    for k, v in sub["by_op"].items():
                        by_op[k] = by_op.get(k, 0.0) + v * trips
                    n_coll += sub["n_coll"] * trips
                continue
            if op in ("fusion", "call", "conditional", "custom-call",
                      "reduce", "sort", "map", "scatter", "select-and-scatter"):
                b = self._io_bytes(comp, inst)
                hbm += b
                by_op[op] = by_op.get(op, 0.0) + b
                for cc in _CALLS_RE.findall(inst.attrs):
                    if cc in self.comps and op != "fusion":
                        sub = self.cost(cc)
                        flops += sub["flops"]
                        for k, v in sub["coll"].items():
                            coll[k] = coll.get(k, 0.0) + v
                        n_coll += sub["n_coll"]
                if op == "fusion":
                    # count dots inside fusions (flops only; bytes at boundary)
                    for cc in _CALLS_RE.findall(inst.attrs):
                        if cc in self.comps:
                            flops += self._inner_dot_flops(cc)
                continue
            if base in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                kind, t = self._coll_traffic(comp, inst)
                coll[kind] = coll.get(kind, 0.0) + t
                n_coll += 1
                b = self._io_bytes(comp, inst)
                hbm += b
                by_op[base] = by_op.get(base, 0.0) + b
                continue
            if op == "dot":
                flops += self._dot_flops(comp, inst)
                b = self._io_bytes(comp, inst)
                hbm += b
                by_op["dot"] = by_op.get("dot", 0.0) + b
                continue
            if op == "convolution":
                flops += self._conv_flops(comp, inst)
                b = self._io_bytes(comp, inst)
                hbm += b
                by_op["convolution"] = by_op.get("convolution", 0.0) + b
                continue
            # remaining real ops (copy, dynamic-slice, broadcast, ...)
            b = self._io_bytes(comp, inst)
            hbm += b
            by_op[op] = by_op.get(op, 0.0) + b
        out = {"flops": flops, "hbm_bytes": hbm, "coll": coll,
               "n_coll": n_coll, "by_op": by_op}
        self._memo[comp] = out
        return out

    def _inner_dot_flops(self, comp: str) -> float:
        f = 0.0
        for inst in self.comps.get(comp, []):
            if inst.opcode == "dot":
                f += self._dot_flops(comp, inst)
            elif inst.opcode == "convolution":
                f += self._conv_flops(comp, inst)
        return f


def top_buffers(text: str, k: int = 20) -> list[tuple[float, str, str]]:
    """Largest instruction results (GB, computation, 'opcode type') — the
    bisect tool for memory-dominated cells.  Loop-carried buffers inside a
    while body appear once (they are reused across iterations)."""
    comps = parse_module(text)
    rows = []
    for cname, insts in comps.items():
        for i in insts:
            if i.opcode in ("parameter", "get-tuple-element", "tuple"):
                continue
            b = _parse_shape_bytes(i.type_str)
            if b > 0:
                rows.append((b / 1e9, cname,
                             f"{i.opcode} {i.type_str[:70]}"))
    rows.sort(reverse=True)
    return rows[:k]


def analyze(text: str, default_group: int) -> dict:
    hc = HloCost(text, default_group)
    c = hc.cost()
    return {
        "flops": c["flops"],
        "hbm_bytes": c["hbm_bytes"],
        "coll_per_kind": c["coll"],
        "coll_total": sum(c["coll"].values()),
        "num_collectives": c["n_coll"],
        "hbm_by_op": dict(sorted(c["by_op"].items(),
                                 key=lambda kv: -kv[1])[:12]),
    }
