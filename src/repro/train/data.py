"""Deterministic, restartable data pipeline.

Synthetic-token (and stub-embedding) pipelines keyed by (seed, step) so any
step's batch is reproducible from the checkpointed step counter alone — the
property elastic restarts rely on (no iterator state to persist).  A simple
host-side prefetch thread overlaps batch synthesis with device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class TokenPipeline:
    """Language-model batches: {"inputs","targets": (B, S) int32}."""

    def __init__(self, cfg, batch: int, seq: int, seed: int = 0,
                 frontend_dim: int = 0, src_len: int = 0):
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed
        self.frontend_dim = frontend_dim
        self.src_len = src_len

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.integers(0, self.cfg.vocab_size,
                            (self.batch, self.seq + 1), dtype=np.int32)
        out = {"inputs": toks[:, :-1], "targets": toks[:, 1:]}
        if self.cfg.frontend != "none" and not self.cfg.is_encoder_decoder:
            out["embeds"] = rng.standard_normal(
                (self.batch, self.seq, self.cfg.d_model),
                dtype=np.float32).astype(np.float32)
        if self.cfg.is_encoder_decoder:
            out["src_embeds"] = rng.standard_normal(
                (self.batch, self.src_len or 64, self.cfg.d_model),
                dtype=np.float32)
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class GANPipeline:
    """(z, real image) pairs for GAN training; CIFAR-like 3-channel images."""

    def __init__(self, gan_cfg, batch: int, image_hw: int, seed: int = 0):
        self.cfg, self.batch, self.hw, self.seed = gan_cfg, batch, image_hw, seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        return {
            "z": rng.standard_normal((self.batch, self.cfg.z_dim),
                                     dtype=np.float32),
            "real": rng.uniform(-1, 1, (self.batch, self.hw, self.hw, 3)
                                ).astype(np.float32),
        }


class FileTokenPipeline:
    """Production data path: memory-mapped token file (uint32 flat stream).

    Deterministically maps (seed, step) -> disjoint strided windows of the
    file, so restart-by-step is exact (same property as the synthetic
    pipeline) and epoch boundaries wrap with a reshuffled offset.
    """

    def __init__(self, path: str, cfg, batch: int, seq: int, seed: int = 0):
        self.tokens = np.memmap(path, dtype=np.uint32, mode="r")
        if len(self.tokens) < (seq + 1) * batch:
            raise ValueError("token file too small for one batch")
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed
        self.windows = (len(self.tokens) - 1) // seq

    @staticmethod
    def write_token_file(path: str, tokens: np.ndarray):
        np.asarray(tokens, np.uint32).tofile(path)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step // max(
            self.windows // self.batch, 1)))
        perm = rng.permutation(self.windows)
        base = (step * self.batch) % max(self.windows - self.batch, 1)
        idx = perm[base:base + self.batch]
        if len(idx) < self.batch:
            idx = np.concatenate([idx, perm[:self.batch - len(idx)]])
        rows = np.stack([
            self.tokens[i * self.seq:i * self.seq + self.seq + 1]
            for i in idx]).astype(np.int32)
        rows = rows % self.cfg.vocab_size
        return {"inputs": rows[:, :-1], "targets": rows[:, 1:]}


class Prefetcher:
    """Host-side prefetch: overlaps next-batch synthesis with device step."""

    def __init__(self, pipeline, start_step: int = 0, depth: int = 2):
        self.pipeline = pipeline
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._work, daemon=True)
        self.thread.start()

    def _work(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self.q.put(self.pipeline.batch_at(s), timeout=0.5)
                s += 1
            except queue.Full:
                continue

    def next(self) -> dict:
        return self.q.get()

    def close(self):
        self._stop.set()
