"""Learning-rate schedules (warmup + cosine/linear decay) — pure functions
of the step counter so they live inside the jitted train step."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 2000
    total_steps: int = 100_000
    final_frac: float = 0.1          # floor as a fraction of peak
    kind: str = "cosine"             # cosine | linear | constant


def lr_at(step, cfg: ScheduleConfig):
    """step: int32 scalar (traced ok) -> f32 learning rate."""
    s = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.kind == "constant":
        decay = 1.0
    else:
        frac = jnp.clip((s - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        if cfg.kind == "cosine":
            decay = cfg.final_frac + (1 - cfg.final_frac) * 0.5 * (
                1 + jnp.cos(jnp.pi * frac))
        else:
            decay = cfg.final_frac + (1 - cfg.final_frac) * (1 - frac)
    return cfg.peak_lr * warm * decay
