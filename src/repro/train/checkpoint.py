"""Fault-tolerant checkpointing: atomic directory swap, async save thread,
``latest``-pointer resume, keep-k GC.  The on-disk layout is mesh-independent
(flat {path: np.ndarray} npz + a JSON manifest), so a checkpoint written on a
512-chip mesh restores onto any other mesh (elastic restart) — resharding is
just ``jax.device_put(value, new_sharding)`` at load.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":     # npz can't store ml_dtypes;
            arr = arr.astype(np.float32)     # bf16 -> f32 is lossless and
        flat[key] = arr                      # restore() casts back
    return flat


class CheckpointManager:
    """Directory layout::

        dir/step_000100/arrays.npz        (atomic: written to .tmp, renamed)
        dir/step_000100/manifest.json     {"step": 100, "meta": {...}}
        dir/latest                        -> "step_000100"
    """

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Any, meta: Optional[dict] = None,
             block: bool = False):
        # snapshot on the caller's thread (device_get), serialize off-thread.
        # Always join the previous writer first: two writers on one step's
        # tmp dir (async periodic + final sync save) would race.
        self.wait()
        flat = _flatten(jax.device_get(state))
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, meta or {}), daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, meta or {})

    def _write(self, step: int, flat: dict, meta: dict):
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "meta": meta,
                       "time": time.time()}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic publish
        with open(os.path.join(self.dir, "latest.tmp"), "w") as f:
            f.write(name)
        os.replace(os.path.join(self.dir, "latest.tmp"),
                   os.path.join(self.dir, "latest"))
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "latest")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            name = f.read().strip()
        man = os.path.join(self.dir, name, "manifest.json")
        if not os.path.exists(man):
            return None
        with open(man) as f:
            return json.load(f)["step"]

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``template``; optionally place each
        leaf with ``shardings`` (a matching pytree) — this is how an elastic
        restart re-shards onto a different mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoint found")
        name = f"step_{step:08d}"
        with np.load(os.path.join(self.dir, name, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}

        paths = jax.tree_util.tree_flatten_with_path(template)[0]
        tdef = jax.tree_util.tree_structure(template)
        shard_leaves = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "mesh"))
            if shardings is not None else [None] * len(paths))
        leaves = []
        for (path, leaf), shard in zip(paths, shard_leaves):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = flat[key]
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            if shard is not None:
                arr = jax.device_put(arr, shard)
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(tdef, leaves)
