"""Optimizers with sharding-aware state: AdamW (fp32 m/v, ZeRO-1 over the
data axis) and Adafactor (factored second moment — the memory-feasible choice
for the 671B/132B MoE configs; Adam state for 671B needs >6.7 TB, more than a
single 256x16GB pod's HBM).

Each optimizer exposes::

    init(params, specs, dist)  -> (state, state_specs)
    update(grads, state, params) -> (new_params, new_state)

``state_specs`` carry the ZeRO-1 sharding: m/v inherit the param spec, and
when a param is replicated on the mesh's data axis the optimizer state is
*additionally* sharded over it (first shardable dim), so total state memory
scales 1/(data * model).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"              # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    zero1: bool = True               # shard optimizer state over data axis
    schedule: Optional[Any] = None   # train.schedule.ScheduleConfig


def _lr(cfg: "OptConfig", step):
    if cfg.schedule is None:
        return cfg.lr
    from repro.train.schedule import lr_at
    return lr_at(step, cfg.schedule)


def _zero1_spec(spec: P, shape, data_axes) -> P:
    """Shard optimizer state over the data axis on the first dim that is
    unsharded and divisible (ZeRO-1).  No-op if 'data' already appears in
    the spec (e.g. fully-sharded expert weights)."""
    axes = list(spec) + [None] * (len(shape) - len(spec))
    for ax in axes:
        used = ax if isinstance(ax, tuple) else (ax,)
        if "data" in used:
            return P(*axes)
    for i, (ax, dim) in enumerate(zip(axes, shape)):
        if ax is None and dim % 16 == 0 and dim >= 16:
            axes[i] = data_axes if isinstance(data_axes, str) else "data"
            return P(*axes)
    return P(*axes)


def global_norm(tree):
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params, specs=None, dist=None, cfg: OptConfig = OptConfig()):
    m = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    v = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state = {"m": m, "v": v, "step": jnp.zeros((), jnp.int32)}
    state_specs = None
    if specs is not None:
        zspec = jax.tree.map(
            lambda sp, p: _zero1_spec(sp, p.shape, "data") if cfg.zero1 else sp,
            specs, params, is_leaf=lambda x: isinstance(x, P))
        state_specs = {"m": zspec, "v": zspec, "step": P()}
    return state, state_specs


def adamw_update(grads, state, params, cfg: OptConfig = OptConfig()):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    lr = _lr(cfg, step)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    newp = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    newm = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    newv = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return newp, {"m": newm, "v": newv, "step": step}, gnorm


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no first moment)
# ---------------------------------------------------------------------------

def _factored(shape):
    return len(shape) >= 2 and shape[-1] >= 8 and shape[-2] >= 8


def adafactor_init(params, specs=None, dist=None,
                   cfg: OptConfig = OptConfig(name="adafactor")):
    def mk(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    state = {"f": jax.tree.map(mk, params), "step": jnp.zeros((), jnp.int32)}
    state_specs = None
    if specs is not None:
        def mk_spec(sp, p):
            axes = list(sp) + [None] * (p.ndim - len(sp))
            if _factored(p.shape):
                return {"vr": P(*axes[:-1]), "vc": P(*(axes[:-2] + axes[-1:]))}
            return {"v": P(*axes)}
        state_specs = {"f": jax.tree.map(
            mk_spec, specs, params, is_leaf=lambda x: isinstance(x, P)),
            "step": P()}
    return state, state_specs


def adafactor_update(grads, state, params,
                     cfg: OptConfig = OptConfig(name="adafactor")):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    lr = _lr(cfg, step)
    beta2 = 1.0 - t ** -0.8

    def upd(p, g, f):
        g2 = g * g + 1e-30
        if _factored(p.shape):
            vr = beta2 * f["vr"] + (1 - beta2) * g2.mean(-1)
            vc = beta2 * f["vc"] + (1 - beta2) * g2.mean(-2)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(vr.mean(-1, keepdims=True)[..., None], 1e-30))
            u = g * jax.lax.rsqrt(denom + 1e-30)
            nf = {"vr": vr, "vc": vc}
        else:
            v = beta2 * f["v"] + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(v + 1e-30)
            nf = {"v": v}
        # update clipping (Shazeer & Stern)
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u)
        newp = p.astype(jnp.float32) - lr * u
        if p.ndim >= 2:
            newp = newp - lr * cfg.weight_decay * p.astype(jnp.float32)
        return newp.astype(p.dtype), nf

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_f = tdef.flatten_up_to(state["f"])
    new_p, new_f = [], []
    for p, g, f in zip(flat_p, flat_g, flat_f):
        np_, nf = upd(p, g, f)
        new_p.append(np_)
        new_f.append(nf)
    return (jax.tree.unflatten(tdef, new_p),
            {"f": jax.tree.unflatten(tdef, new_f), "step": step}, gnorm)


OPTIMIZERS = {
    "adamw": (adamw_init, adamw_update),
    "adafactor": (adafactor_init, adafactor_update),
}
