"""Sharding rules: logical param axes -> mesh axes, activation constraints.

The model code annotates parameters with *logical* axis names ("heads",
"ffn", "vocab", "expert", ...).  ``DistContext`` owns the mapping from
logical axes to physical mesh axes — changing a parallelism strategy (the
§Perf hillclimb lever) means editing RULES, not models.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# default logical->mesh translation (megatron TP on 'model', experts EP'd)
DEFAULT_RULES: dict[str, Any] = {
    "heads": "model",
    "kv_heads": "model",         # cleared when num_kv_heads % TP != 0
    "ffn": "model",
    "vocab": "model",
    "expert": "model",
    "expert_ffn": None,
    "batch": ("data",),          # overridden to ('pod','data') multi-pod
    "seq": None,                 # set to 'model' to turn on SP residuals
    "kv_seq": None,              # decode cache sequence dim (long-context)
    # superpacked conv weights (core.plan): one tap-major (ΣT·C, N) buffer
    # per site.  Row dim mixes taps and input channels (plan-time offsets
    # index into it), so the default shards only the out-channel dim —
    # flip "conv_taps" to 'model' for row-parallel superpacks instead.
    "conv_taps": None,
    "conv_out": "model",
    # plane-parallel execution (core.spatial): one conv plane's spatial
    # dims sharded over the mesh, halo exchange at tile boundaries.  The
    # logical axes name the *image* rows/cols; ``make_spatial_mesh``
    # provides the physical 'sp_h'/'sp_w' axes.
    "plane_h": "sp_h",
    "plane_w": "sp_w",
}

# logical spec of every superpacked conv weight buffer
SUPERPACK_SPEC = P("conv_taps", "conv_out")

# logical spec of a plane-parallel (B, H, W, C) activation
PLANE_SPEC = P("batch", "plane_h", "plane_w")


def shard_map_compat(f, mesh, in_specs, out_specs, check=False):
    """``shard_map`` across the jax versions this repo supports: new
    releases expose ``jax.shard_map`` with ``check_vma``; 0.4.x has
    ``jax.experimental.shard_map.shard_map`` with ``check_rep``.  The
    replication check defaults off — the plane-parallel bodies return
    device-varying tiles and psum weight cotangents through the
    ``shard_map`` transpose, which the 0.4.x checker cannot type."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


# (param-path, axis) pairs already warned about by ``shard_params`` — the
# best-effort replication fallback is silent-by-design per call site, but
# the *first* hit for a given param deserves a visible trace.
_REPLICATION_WARNED: set = set()


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: Mesh
    rules: dict = dataclasses.field(default_factory=lambda: dict(DEFAULT_RULES))

    @property
    def batch_axes(self):
        return self.rules["batch"]

    @property
    def model_axis(self):
        return "model"

    def resolve(self, spec: P) -> P:
        """Translate a logical PartitionSpec into a mesh PartitionSpec."""
        out = []
        for ax in spec:
            if ax is None:
                out.append(None)
            elif isinstance(ax, str) and ax in self.rules:
                out.append(self.rules[ax])
            else:
                out.append(ax)
        return P(*out)

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, self.resolve(spec))

    def param_shardings(self, specs_tree):
        return jax.tree.map(
            lambda sp: self.sharding(sp), specs_tree,
            is_leaf=lambda x: isinstance(x, P))

    # ---- activation constraints -------------------------------------------
    def act_spec(self, *, seq_dim: bool = True) -> P:
        """(B, S, D) residual-stream spec: batch over DP axes, optional SP."""
        if seq_dim:
            return P(self.rules["batch"], self.rules["seq"], None)
        return P(self.rules["batch"], None)

    def image_spec(self) -> P:
        """(B, H, W, C) image/latent batch spec: data-parallel over the
        batch dim, spatial/channel replicated (trailing dims implicit)."""
        return P(self.rules["batch"])

    def plane_spec(self) -> P:
        """(B, H, W, C) plane-parallel spec: batch over DP axes, the plane's
        rows/cols over the spatial mesh axes (``core.spatial`` executor)."""
        return self.resolve(PLANE_SPEC)

    def spatial_tiles(self) -> tuple[int, int]:
        """(D_h, D_w) device-tiling extents this mesh offers a conv plane:
        the sizes of the mesh axes the 'plane_h'/'plane_w' logical axes
        resolve to (1 when unmapped or absent from the mesh) — what model
        configs feed into ``ConvSpec.spatial``."""
        if self.mesh is None:
            return (1, 1)
        out = []
        for logical in ("plane_h", "plane_w"):
            ax = self.rules.get(logical)
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                if a is not None and a in self.mesh.shape:
                    n *= int(self.mesh.shape[a])
            out.append(n)
        return tuple(out)

    def shard_params(self, params, specs):
        """Place a param tree onto the mesh per its logical spec tree — the
        DistContext-aware half of every planned model's ``*_init``.  A dim
        whose size doesn't divide its mesh axes replicates instead (the
        same rule as ``kv_heads``: sharding is best-effort, never a crash —
        e.g. a 3-channel image head stays replicated under TP=2).  Like
        ``constrain``, a mesh-less context is a no-op."""
        if self.mesh is None:
            return params
        from repro.core.plan import QuantizedSuperpack

        def put(path, p, sp):
            if isinstance(p, QuantizedSuperpack):
                # quantized superpack: the int8 codes shard exactly like the
                # dense buffer; the (rows, 1) scale column follows the row
                # axis only (its singleton N dim is never split)
                row_sp = P(*tuple(sp)[:1])
                return QuantizedSuperpack(put(path, p.q, sp),
                                          put(path, p.scale, row_sp))
            resolved = tuple(self.resolve(sp))
            resolved += (None,) * (len(p.shape) - len(resolved))
            out = []
            for i, (dim, ax) in enumerate(zip(p.shape, resolved)):
                if ax is None:
                    out.append(None)
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = 1
                for a in axes:
                    n *= int(self.mesh.shape[a])
                if dim % n:
                    name = jax.tree_util.keystr(path)
                    if (name, i, ax) not in _REPLICATION_WARNED:
                        _REPLICATION_WARNED.add((name, i, ax))
                        warnings.warn(
                            f"shard_params: param {name} dim {i} (size "
                            f"{dim}) does not divide mesh axis {ax!r} "
                            f"(extent {n}) — replicating that dim instead",
                            RuntimeWarning, stacklevel=2)
                    out.append(None)
                    continue
                out.append(ax)
            return jax.device_put(p, NamedSharding(self.mesh, P(*out)))

        return jax.tree_util.tree_map_with_path(
            put, params, specs,
            is_leaf=lambda x: isinstance(x, QuantizedSuperpack))

    def constrain(self, x, spec: Optional[P] = None):
        if self.mesh is None:
            return x
        spec = spec if spec is not None else self.act_spec()
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.resolve(spec)))


def single_device_dist() -> Optional[DistContext]:
    """None-context for smoke tests (no mesh, constraints are no-ops)."""
    return None


def stack_specs(specs_tree, n_lead: int = 1):
    """Prepend ``n_lead`` None axes to every PartitionSpec (stacked stages)."""
    return jax.tree.map(
        lambda sp: P(*((None,) * n_lead + tuple(sp))), specs_tree,
        is_leaf=lambda x: isinstance(x, P))
