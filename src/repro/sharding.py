"""Sharding rules: logical param axes -> mesh axes, activation constraints.

The model code annotates parameters with *logical* axis names ("heads",
"ffn", "vocab", "expert", ...).  ``DistContext`` owns the mapping from
logical axes to physical mesh axes — changing a parallelism strategy (the
§Perf hillclimb lever) means editing RULES, not models.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# default logical->mesh translation (megatron TP on 'model', experts EP'd)
DEFAULT_RULES: dict[str, Any] = {
    "heads": "model",
    "kv_heads": "model",         # cleared when num_kv_heads % TP != 0
    "ffn": "model",
    "vocab": "model",
    "expert": "model",
    "expert_ffn": None,
    "batch": ("data",),          # overridden to ('pod','data') multi-pod
    "seq": None,                 # set to 'model' to turn on SP residuals
    "kv_seq": None,              # decode cache sequence dim (long-context)
}


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: Mesh
    rules: dict = dataclasses.field(default_factory=lambda: dict(DEFAULT_RULES))

    @property
    def batch_axes(self):
        return self.rules["batch"]

    @property
    def model_axis(self):
        return "model"

    def resolve(self, spec: P) -> P:
        """Translate a logical PartitionSpec into a mesh PartitionSpec."""
        out = []
        for ax in spec:
            if ax is None:
                out.append(None)
            elif isinstance(ax, str) and ax in self.rules:
                out.append(self.rules[ax])
            else:
                out.append(ax)
        return P(*out)

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, self.resolve(spec))

    def param_shardings(self, specs_tree):
        return jax.tree.map(
            lambda sp: self.sharding(sp), specs_tree,
            is_leaf=lambda x: isinstance(x, P))

    # ---- activation constraints -------------------------------------------
    def act_spec(self, *, seq_dim: bool = True) -> P:
        """(B, S, D) residual-stream spec: batch over DP axes, optional SP."""
        if seq_dim:
            return P(self.rules["batch"], self.rules["seq"], None)
        return P(self.rules["batch"], None)

    def constrain(self, x, spec: Optional[P] = None):
        if self.mesh is None:
            return x
        spec = spec if spec is not None else self.act_spec()
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.resolve(spec)))


def single_device_dist() -> Optional[DistContext]:
    """None-context for smoke tests (no mesh, constraints are no-ops)."""
    return None


def stack_specs(specs_tree, n_lead: int = 1):
    """Prepend ``n_lead`` None axes to every PartitionSpec (stacked stages)."""
    return jax.tree.map(
        lambda sp: P(*((None,) * n_lead + tuple(sp))), specs_tree,
        is_leaf=lambda x: isinstance(x, P))
